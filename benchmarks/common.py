"""Shared benchmark helpers: wall-clock timing of jitted callables and the
TPU-v5e analytic latency model used to project paper figures from dry-run
artifacts (this container has no TPU; wall-time benches run CPU-scale
proxies, latency projections use the roofline constants)."""
from __future__ import annotations

import time
from typing import Callable

import jax

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16e9  # v5e


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


# ---------------------------------------------------------------------------
# Analytic decode-latency model (paper §5: inference is memory-bandwidth
# bound; latency ≈ critical-path bytes / aggregate achievable bandwidth +
# collective latency).
# ---------------------------------------------------------------------------


def _split_params(cfg):
    from repro.configs.base import count_params, ffn_param_count

    total = count_params(cfg)
    expert_params = 0
    n_moe_layers = 0
    for seg in cfg.segments:
        for ls in seg.pattern:
            if ls.ffn.kind == "moe":
                expert_params += (
                    ffn_param_count(cfg, ls.ffn, active=False)
                    - ffn_param_count(cfg, ls.ffn, active=True)
                ) * seg.repeats
                n_moe_layers += seg.repeats
    return total - expert_params, expert_params, n_moe_layers


def decode_latency_model(
    cfg,
    n_gpus: int,
    *,
    optimized: bool,
    tokens_per_gpu: int = 16,
    bytes_per_param: int = 2,
    expert_bytes_per_param: float | None = None,  # weight-only expert PTQ
) -> float:
    """Seconds per decode step, weak-scaling serving load (B = 16·g tokens —
    with a production batch every expert is touched, so each GPU reads its
    local expert shard once: expert bytes/GPU = expert_params/g, which is
    the §5.5.1 data-locality effect behind super-linear throughput).

    Both layouts get expert parallelism and TP≤8 (the paper's PyTorch
    baseline has both); the *differences* are the measured structural ones:
      * MoE kernel path: baseline pays the §5.4 sparse-einsum factor (6x)
      * non-expert kernels: DS inference kernels ≈1.5x better bandwidth
      * a2a: flat O(p) hops vs parallelism-coordinated O(p/L)+O(L) (§5.3)
    """
    nonexpert, expert_params, n_moe = _split_params(cfg)
    g = n_gpus
    # dense models use 16-way tensor slicing; MoE runs at half the TS degree
    # (paper §5.5.4: "8-way vs. 16-way")
    tp = min(g, 16 if n_moe == 0 else 8)
    B = tokens_per_gpu * g
    hop_lat = 5e-6
    tok_bytes = cfg.d_model * bytes_per_param * tokens_per_gpu

    ebp = bytes_per_param if expert_bytes_per_param is None else expert_bytes_per_param
    t_expert = (expert_params * ebp / g) / HBM_BW
    t_nonexpert = (nonexpert * bytes_per_param / tp) / HBM_BW
    # tensor-slicing all-reduces: 2 per layer; baseline NCCL small-message
    # overhead ~50us vs optimized (SCCL + fused) ~5us (§5.3)
    n_layers = cfg.num_layers
    if optimized:
        t_tp = 0.0 if tp == 1 else 2 * n_layers * (5e-6 + tok_bytes / ICI_BW)
        a2a = n_moe * 2 * (hop_lat * max(g // tp, 1) + tok_bytes / ICI_BW)
        return t_expert + t_nonexpert + a2a + t_tp
    else:
        t_tp = 0.0 if tp == 1 else 2 * n_layers * (50e-6 + tok_bytes / ICI_BW)
        # sparse-einsum MoE kernels (≈6x, §5.4) + slower dense kernels (1.5x)
        a2a = n_moe * 2 * (hop_lat * g + tok_bytes / ICI_BW)
        return 6.0 * t_expert + 1.5 * t_nonexpert + a2a + t_tp


def min_gpus_to_fit(cfg, bytes_per_param: int = 2, hbm: float = 40e9) -> int:
    """Fig. 12 used A100-40GB; default hbm matches the paper's hardware."""
    from repro.configs.base import count_params

    need = count_params(cfg) * bytes_per_param * 1.2  # +20% activations/workspace
    g = 1
    while g * hbm < need:
        g *= 2
    return g
