"""Benchmark harness — one section per DeepSpeed-MoE table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  table3   — training cost: MoE-at-base-cost vs quality-equivalent dense (5x)
  fig10    — 52B MoE scaling 8→64 GPUs: latency + per-GPU throughput
             (super-linear), baseline vs DS-MoE
  fig11    — 107B→2T models: baseline vs DS-MoE latency (≤7.3x)
  fig12    — min GPUs to serve: standard vs PR-MoE vs PR-MoE+MoS (2x fewer)
  fig13    — PR-MoE/MoS latency at fixed GPUs
  fig14_15 — MoE vs quality-equivalent dense serving latency/cost
  kernel6x — sparse-einsum vs fused dense-mapping MoE kernels (>6x, §5.4)
  moe_impl — full MoE layer wall-clock, einsum vs dense dispatch (CPU)
  quant    — MoQ expert PTQ: bytes int8/int4 vs fp32, CPU overhead, and the
             projected decode-latency win at 1 byte/param (§4)
  kv_quant — int8 KV cache: cache bytes/token fp vs quantized, decode-step
             wall-clock with fp vs int8 caches (CPU ref path), batch-size
             headroom at a fixed cache-memory budget
  paged    — paged KV block pool: cache bytes + effective sequences/GiB vs
             contiguous slots (fp and int8 pages), decode-tick wall-clock,
             and a traffic-mix run with per-tick scheduler metrics (JSON)
  prefix   — prefix-sharing / copy-on-write pages: physical pages for
             shared-system-prompt traffic with vs without sharing, the
             effective sequences/GiB multiplier on top of the paged
             baseline, n-sample parallel sampling page cost, and a measured
             run with shared_pages / cow_copies telemetry (JSON)
  chunked_prefill — chunked prefill-into-pages: temp contiguous admission
             buffer eliminated (bytes), long-prompt admission wall-clock and
             TTFT head-of-line blocking chunked vs scatter under mixed
             traffic (decode progress while the long prompt prefills),
             measured prefill FLOPs saved on shared-preamble traffic, and
             per-tick prefill/decode token telemetry (JSON)
  obs      — observability layer: decode-tick overhead with instrumentation
             fully off vs default (metrics, tracer disabled) vs everything
             on (tracer + per-tick routing stats) — ASSERTS the default
             path adds <1%; raw tracer emit cost on/off; MoE routing
             telemetry from one training step and one decode tick; retrace
             watchdog warmup-vs-steady compile counts; final metrics
             snapshot as JSON
  fused_tick — one fused tick (grouped dropless MoE + batched multi-slot
             chunk prefill): >=3 concurrent admissions in ONE jitted prefill
             call (jitted calls/tick <= 2), predicted==observed compile
             counts with the batched entry compiling once, tick p50/p99
             batched vs chunked, and capacity-padding vs grouped tile-padding
             dead expert FLOPs (JSON)
  ep_serving — expert-parallel serving mesh: measured per-device parameter
             bytes with experts sharded (4x2) vs single-device, the
             aggregate expert-bandwidth multiplier, per-layer all-to-all /
             all_gather exchange volume, and flat vs hierarchical two-hop
             message counts (JSON)
  spec     — draft-then-verify speculative decoding over CoW page forks:
             accepted tokens per verify pass with a same-family drafter
             (ASSERTS > 1), the fresh-init low-accept rollback contrast
             (token-exact either way), target forward passes per emitted
             token vs the non-speculative baseline, decode-tick p50/p99
             for all three engines, and the fork-page commit/rollback
             ledger (JSON)

Run: PYTHONPATH=src python -m benchmarks.run [section ...]
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from benchmarks.common import decode_latency_model, emit, min_gpus_to_fit, time_fn
from repro.configs.base import count_active_params, count_params
from repro.configs.registry import all_configs


def table3() -> None:
    """Table 3: same quality, ~5x cheaper training.  Training cost ∝
    activated params/token; also measured wall-clock on scaled CPU proxies."""
    cfgs = all_configs()
    moe = cfgs["nlg-1.3b-moe128"]
    dense = cfgs["nlg-6.7b"]
    ratio = count_params(dense) / count_active_params(moe)
    emit("table3_flops_ratio_6.7Bdense_over_1.3B+MoE128", 0.0, f"{ratio:.2f}x_cheaper_training(paper:5x)")

    from repro.core.prmoe import nlg_dense, nlg_moe
    from repro.data.pipeline import data_stream
    from repro.models.model import init_params
    from repro.training.optimizer import init_adamw
    from repro.training.trainer import TrainConfig, make_train_step

    proxy_moe = nlg_moe("proxy-moe", 4, 256, 4, 16, vocab=2048).replace(
        param_dtype="float32", compute_dtype="float32")
    proxy_dense = nlg_dense("proxy-dense", 6, 512, 8, vocab=2048).replace(
        param_dtype="float32", compute_dtype="float32")
    it = data_stream(2048, 8, 128)
    tokens, labels = next(it)
    rows = {}
    for name, cfg in [("moe_base", proxy_moe), ("dense_equiv", proxy_dense)]:
        p = init_params(cfg, jax.random.PRNGKey(0))
        o = init_adamw(p)
        step = jax.jit(make_train_step(cfg, TrainConfig(lr=1e-3, warmup_steps=1, decay_steps=10)))
        us = time_fn(lambda p=p, o=o: step(p, o, tokens, labels), iters=5, warmup=2)
        rows[name] = us
        emit(f"table3_proxy_step_{name}", us, f"params={count_params(cfg)/1e6:.0f}M")
    emit("table3_proxy_measured_speedup", 0.0, f"{rows['dense_equiv']/rows['moe_base']:.2f}x")


def fig10() -> None:
    cfg = all_configs()["nlg-1.3b-moe128"]  # the 52B model of Fig. 10
    base_tput = None
    for g in (8, 16, 32, 64):
        lat_opt = decode_latency_model(cfg, g, optimized=True)
        lat_base = decode_latency_model(cfg, g, optimized=False)
        # weak-scaling serving: 16 tokens/GPU -> per-GPU throughput rises as
        # experts-per-GPU (and thus expert bytes) shrink — §5.5.1 locality
        tput = 16.0 / lat_opt  # tokens/s per GPU
        if base_tput is None:
            base_tput = tput
        emit(f"fig10_52B_{g}gpu_dsmoe", lat_opt * 1e6,
             f"speedup_vs_baseline={lat_base/lat_opt:.2f}x")
        emit(f"fig10_52B_{g}gpu_perGPU_tput", 0.0,
             f"superlinear_factor={tput/base_tput:.2f}(>1=superlinear)")


def fig11() -> None:
    for name in ("nlg-2.4b-moe128", "nlg-8b-moe128", "nlg-24b-moe128", "nlg-47b-moe128"):
        cfg = all_configs()[name]
        g = 256 if count_params(cfg) > 6e11 else 128
        lat_opt = decode_latency_model(cfg, g, optimized=True)
        lat_base = decode_latency_model(cfg, 128, optimized=False)
        emit(f"fig11_{name}_{g}gpu", lat_opt * 1e6,
             f"size={count_params(cfg)/1e9:.0f}B,improvement={lat_base/lat_opt:.1f}x(paper:<=7.3x)")


def fig12() -> None:
    cfgs = all_configs()
    for std, pr, mos, tag in [
        ("nlg-350m-moe128", "nlg-350m-prmoe-32-64", "nlg-350m-prmoe-mos", "13B"),
        ("nlg-1.3b-moe128", "nlg-1.3b-prmoe-64-128", "nlg-1.3b-prmoe-mos", "52B"),
    ]:
        g_std = min_gpus_to_fit(cfgs[std])
        g_mos = min_gpus_to_fit(cfgs[mos])
        emit(f"fig12_min_gpus_{tag}", 0.0,
             f"standard={g_std},prmoe={min_gpus_to_fit(cfgs[pr])},prmoe+mos={g_mos},"
             f"reduction={g_std/g_mos:.1f}x(paper:2x)")


def fig13() -> None:
    cfgs = all_configs()
    for std, pr, mos, g in [
        ("nlg-350m-moe128", "nlg-350m-prmoe-32-64", "nlg-350m-prmoe-mos", 16),
        ("nlg-1.3b-moe128", "nlg-1.3b-prmoe-64-128", "nlg-1.3b-prmoe-mos", 64),
    ]:
        l_std = decode_latency_model(cfgs[std], g, optimized=True)
        l_pr = decode_latency_model(cfgs[pr], g, optimized=True)
        l_mos = decode_latency_model(cfgs[mos], g, optimized=True)
        emit(f"fig13_{std}_{g}gpu", l_std * 1e6,
             f"prmoe={l_pr*1e6:.0f}us,prmoe+mos={l_mos*1e6:.0f}us,gain={l_std/l_mos:.2f}x")


def fig14_15() -> None:
    """Figs 14-15 compare DS-MoE-served MoE against *PyTorch-served* dense
    (that is the paper's setup), per-token GPU-seconds for the cost claim."""
    cfgs = all_configs()
    moe, dense = cfgs["nlg-1.3b-moe128"], cfgs["nlg-6.7b"]
    l_moe = decode_latency_model(moe, 128, optimized=True)
    l_dense = decode_latency_model(dense, 8, optimized=False)
    emit("fig14_52B_moe_vs_6.7B_dense", l_moe * 1e6,
         f"dense={l_dense*1e6:.0f}us,speedup={l_dense/l_moe:.2f}x(paper:2.4x+)")
    from repro.core.prmoe import nlg_dense, nlg_moe

    d175 = nlg_dense("nlg-175b", 96, 12288, 96)
    moe2t = cfgs["nlg-47b-moe128"]
    mos2t = nlg_moe("nlg-47b-prmoe-mos", 58, 8192, 64, (64, 128), residual=True,
                    student_layers=51)
    l_moe = decode_latency_model(moe2t, 256, optimized=True)
    l_mos = decode_latency_model(mos2t, 256, optimized=True)
    l_dense = decode_latency_model(d175, 16, optimized=False)
    emit("fig15_2T_moe_vs_175B_dense", l_moe * 1e6,
         f"dense={l_dense*1e6:.0f}us,speedup={l_dense/l_moe:.2f}x")
    emit("fig15_2T_prmoe_mos_vs_175B_dense", l_mos * 1e6,
         f"dense={l_dense*1e6:.0f}us,speedup={l_dense/l_mos:.2f}x(paper:4.5x)")
    # cost: GPU-seconds per token at 16 tokens/GPU weak-scaling load
    cost_dense = l_dense * 16 / (16 * 16)
    cost_mos = l_mos * 256 / (16 * 256)
    emit("fig15_cost_per_token_ratio", 0.0,
         f"dense_over_moe={cost_dense/cost_mos:.2f}x_cheaper(paper:9x)")


def kernel6x() -> None:
    """§5.4: dense mapping-table dispatch vs sparse one-hot einsum dispatch,
    wall-clock on CPU at paper-ish shape (E=128, top-1)."""
    from repro.core.dispatch import moe_dense
    from repro.core.dispatch_einsum import moe_einsum
    from repro.core.gating import expert_capacity, top_k_gating

    T, E, D = 2048, 128, 512
    cap = expert_capacity(T, E, 1, 1.25)
    logits = jax.random.normal(jax.random.PRNGKey(0), (T, E))
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D))
    ident = lambda b: b  # isolate dispatch cost (identity experts)

    f_einsum = jax.jit(lambda x, lg: moe_einsum(x, top_k_gating(lg, 1, cap, method="cumsum"), cap, ident))
    f_dense = jax.jit(lambda x, lg: moe_dense(x, top_k_gating(lg, 1, cap, method="sort"), cap, E, ident))
    us_e = time_fn(f_einsum, x, logits, iters=10)
    us_d = time_fn(f_dense, x, logits, iters=10)
    emit("kernel_sparse_einsum_dispatch", us_e, f"T={T},E={E},D={D}")
    emit("kernel_dense_mapping_dispatch", us_d, f"speedup={us_e/us_d:.2f}x(paper:>6x)")


def moe_impl() -> None:
    from repro.configs.base import FFNSpec, ModelConfig
    from repro.core.moe import init_moe, moe_layer

    cfg = ModelConfig(name="b", family="moe", source="x", d_model=256, num_heads=4,
                      num_kv_heads=4, head_dim=64, vocab_size=1024, segments=(),
                      param_dtype="float32", compute_dtype="float32")
    spec = FFNSpec(kind="moe", d_ff=512, num_experts=32, top_k=1, capacity_factor=1.25)
    params = init_moe(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256, 256))
    us = {}
    for impl in ("einsum", "dense"):
        f = jax.jit(lambda p, x, impl=impl: moe_layer(cfg, spec, p, x, impl=impl)[0])
        us[impl] = time_fn(f, params, x, iters=10)
        emit(f"moe_layer_{impl}", us[impl], "E=32,T=1024,D=256")
    emit("moe_layer_full_speedup", 0.0, f"{us['einsum']/us['dense']:.2f}x")


def quant() -> None:
    """MoQ (§4, "up to 3.7x" smaller): expert-weight PTQ.  Reports (a) expert
    parameter bytes fp32 vs int8/int4 (+scales), (b) expert-MLP wall-clock on
    the CPU dequant-einsum path, (c) projected decode latency with 1-byte
    weights through the paper's analytic memory-bound latency model."""
    from repro.configs.base import FFNSpec, ModelConfig, QuantConfig
    from repro.core.moe import experts_ffn, init_moe
    from repro.quant import quantize_params, tree_bytes

    cfg = ModelConfig(name="q", family="moe", source="x", d_model=256, num_heads=4,
                      num_kv_heads=4, head_dim=64, vocab_size=1024, segments=(),
                      param_dtype="float32", compute_dtype="float32")
    spec = FFNSpec(kind="moe", d_ff=1024, num_experts=16, top_k=1, act="swiglu")
    params = init_moe(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
    expert = {k: params[k] for k in ("wi", "wg", "wo")}
    fp_bytes = tree_bytes(expert)

    quantized = {}
    for bits, gs in ((8, 0), (4, 64)):
        qp = quantize_params({"moe": expert}, QuantConfig(bits=bits, group_size=gs))["moe"]
        quantized[bits] = qp
        qb = tree_bytes(qp)
        emit(f"quant_expert_bytes_int{bits}", 0.0,
             f"fp32={fp_bytes},int{bits}+scales={qb},reduction={fp_bytes/qb:.2f}x(paper:3.7x_model)")

    E, C, D = spec.num_experts, 128, cfg.d_model
    xe = jax.random.normal(jax.random.PRNGKey(1), (E, C, D), jnp.float32)
    f_fp = jax.jit(lambda p, xe: experts_ffn(p, xe, "swiglu"))
    us_fp = time_fn(f_fp, params, xe, iters=10)
    emit("quant_expert_mlp_fp32", us_fp, f"E={E},C={C},D={D},F={spec.d_ff}")
    for bits in (8, 4):
        us_q = time_fn(f_fp, quantized[bits], xe, iters=10)
        emit(f"quant_expert_mlp_int{bits}_dequant_einsum", us_q,
             f"overhead_vs_fp={us_q/us_fp:.2f}x(CPU_ref_path;TPU_uses_dequant-in-kernel)")

    # Projected decode latency: experts-only int8 halves ONLY the expert
    # bytes streamed from HBM (dense weights and activation/a2a traffic stay
    # bf16) — the term that dominates the paper's fig. 10/11 at low GPU
    # counts, where experts are the bulk of per-GPU bytes.
    cfg52 = all_configs()["nlg-1.3b-moe128"]
    for g in (8, 32):
        l_bf16 = decode_latency_model(cfg52, g, optimized=True)
        l_int8 = decode_latency_model(cfg52, g, optimized=True, expert_bytes_per_param=1)
        emit(f"quant_52B_{g}gpu_decode_projection", l_int8 * 1e6,
             f"bf16={l_bf16*1e6:.0f}us,experts_int8_speedup={l_bf16/l_int8:.2f}x")


def kv_quant() -> None:
    """Quantized KV cache (serving): (a) cache bytes/token fp32 vs int8 +
    per-(head, timestep) scales, (b) measured decode-step wall-clock with
    fp vs int8 caches on the CPU dequant path (TPU uses the Pallas
    dequant-in-kernel decode attention), (c) the batch-headroom implication
    at a fixed cache-memory budget — decode batch ∝ 1/cache-bytes when the
    §5 memory-bound regime is cache-dominated."""
    from repro.core.prmoe import nlg_moe
    from repro.models.model import decode_step, init_caches, init_params, prefill
    from repro.quant import kv_cache_bytes

    cfg = nlg_moe("kv-bench", 4, 256, 4, 16, vocab=1024).replace(
        param_dtype="float32", compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, cap = 8, 64, 128
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)

    rows = {}
    for bits in (0, 8):
        tag = f"int{bits}" if bits else "fp32"
        caches = init_caches(cfg, B, cap, kv_bits=bits)
        nbytes = kv_cache_bytes(caches)
        per_tok = nbytes / (B * cap)
        emit(f"kv_quant_cache_bytes_{tag}", 0.0,
             f"total={nbytes},per_slot_token={per_tok:.1f}B")
        rows[bits] = nbytes

        _, filled = jax.jit(lambda p, t, c: prefill(cfg, p, t, c))(params, toks[:, :S], caches)
        f_dec = jax.jit(lambda p, t, i, c: decode_step(cfg, p, t, i, c))
        us = time_fn(lambda: f_dec(params, toks[:, S:], jnp.asarray(S, jnp.int32), filled),
                     iters=10, warmup=3)
        emit(f"kv_quant_decode_step_{tag}", us, f"B={B},cap={cap}")

    red = rows[0] / rows[8]
    emit("kv_quant_byte_reduction", 0.0,
         f"{red:.2f}x_fewer_cache_bytes,batch_headroom_at_fixed_budget={red:.2f}x")


def paged() -> None:
    """Paged KV block pool (serving/kv_pool.py): (a) cache bytes for the same
    live traffic, contiguous slots x capacity vs a pool provisioned for the
    actual sequence lengths; (b) effective concurrent sequences per GiB of
    cache — the number that multiplies with int8 KV; (c) measured decode-tick
    wall-clock paged vs contiguous through the ContinuousEngine (CPU ref
    path; TPU uses the scalar-prefetch Pallas page-gather kernel); (d) a
    short traffic mix with per-tick scheduler metrics emitted as JSON."""
    import json

    from repro.core.prmoe import nlg_moe
    from repro.models.model import init_caches, init_paged_caches, init_params
    from repro.quant import kv_cache_bytes
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.engine import Request

    cfg = nlg_moe("paged-bench", 4, 256, 4, 16, vocab=1024).replace(
        param_dtype="float32", compute_dtype="float32")
    slots, capacity, ps = 8, 256, 16
    avg_len = 48  # demo traffic: 32-token prompts + 16 new tokens
    pages_per_seq = -(-avg_len // ps)

    for kv_bits in (0, 8):
        tag = f"int{kv_bits}" if kv_bits else "fp32"
        contig = kv_cache_bytes(jax.eval_shape(
            lambda b=kv_bits: init_caches(cfg, slots, capacity, kv_bits=b)))
        n_pages = slots * pages_per_seq  # provisioned for the traffic, not worst case
        pool = kv_cache_bytes(jax.eval_shape(
            lambda b=kv_bits: init_paged_caches(
                cfg, slots, capacity, n_pages=n_pages, page_size=ps, kv_bits=b)))
        emit(f"paged_cache_bytes_{tag}", 0.0,
             f"contiguous={contig},pool={pool}({n_pages}x{ps}pages),"
             f"reduction={contig/pool:.2f}x")
        # effective concurrent sequences per GiB: contiguous reserves
        # `capacity` cache tokens per sequence; paged reserves only the pages
        # a sequence actually occupies
        per_tok_contig = contig / (slots * capacity)
        # denominator = ALLOCATABLE tokens only — the trash page's bytes are
        # pure overhead and stay in the numerator
        per_tok_paged = pool / (n_pages * ps)
        seqs_contig = 2**30 / (capacity * per_tok_contig)
        seqs_paged = 2**30 / (pages_per_seq * ps * per_tok_paged)
        emit(f"paged_effective_seqs_per_GiB_{tag}", 0.0,
             f"contiguous={seqs_contig:.0f},paged={seqs_paged:.0f},"
             f"gain={seqs_paged/seqs_contig:.2f}x(target:>=2x)")

    params = init_params(cfg, jax.random.PRNGKey(0))
    t_slots, t_cap = 4, 128
    rng = jax.random.PRNGKey(1)
    prompts = [jax.random.randint(jax.random.fold_in(rng, i), (32,), 0,
                                  cfg.vocab_size).tolist() for i in range(t_slots)]
    rows = {}
    for mode in ("contiguous", "paged"):
        eng = ContinuousEngine(
            cfg, params, slots=t_slots, capacity=t_cap,
            paged=(mode == "paged"), page_size=ps,
        )
        for p in prompts:
            eng.submit(Request(prompt=p, max_new_tokens=t_cap - 33))
        eng.step()  # compile
        us = time_fn(eng.step, iters=10, warmup=2)
        rows[mode] = us
        emit(f"paged_decode_tick_{mode}", us, f"slots={t_slots},cap={t_cap}")
    emit("paged_decode_tick_overhead", 0.0,
         f"{rows['paged']/rows['contiguous']:.2f}x_vs_contiguous(CPU_ref_gather)")

    # traffic mix: many short + a few long, pool at half the contiguous
    # reservation — per-tick scheduler telemetry straight from step()
    eng = ContinuousEngine(cfg, params, slots=6, capacity=128, paged=True,
                           page_size=ps, n_pages=6 * 4)
    for i in range(10):
        n = 12 if i % 3 else 48
        eng.submit(Request(prompt=prompts[i % t_slots][: 8 + (i % 3) * 8],
                           max_new_tokens=n))
    eng.run_until_done()
    occ = [m["page_occupancy"] for m in eng.metrics_log]
    emit("paged_scheduler_traffic_mix", 0.0,
         f"ticks={len(eng.metrics_log)},peak_occupancy={max(occ):.2f},"
         f"preemptions={eng.preemptions}")
    print("# paged_metrics_json:", json.dumps({
        "config": {"slots": 6, "capacity": 128, "page_size": ps, "n_pages": 24},
        "preemptions": eng.preemptions,
        "ticks": eng.metrics_log,
    }))


def prefix() -> None:
    """Prefix sharing / copy-on-write pages (serving/prefix_index.py): heavy
    shared-system-prompt traffic stores the preamble's pages ONCE.  Reports
    (a) analytic per-sequence page cost and the effective sequences/GiB
    multiplier over the PR 3 paged baseline; (b) a measured run — identical
    traffic through the paged engine with and without sharing, comparing
    peak physical pages, with per-tick shared_pages / cow_copies telemetry
    as JSON; (c) the n-sample parallel sampling page cost (all prompt pages
    shared, divergence via CoW)."""
    import json

    from repro.core.prmoe import nlg_moe
    from repro.models.model import init_params
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.engine import Request

    ps = 16
    # analytic: 32-token shared preamble (2 pages), 16-token unique tail +
    # generation (1 page) per sequence, N concurrent sequences
    pre_pages, tail_pages = 2, 1
    for n_seqs in (8, 64):
        base = pre_pages + tail_pages  # PR 3 paged: every seq pays the preamble
        shared = tail_pages + pre_pages / n_seqs  # preamble amortized
        emit(f"prefix_pages_per_seq_{n_seqs}seqs", 0.0,
             f"paged={base},shared={shared:.2f},"
             f"seqs_per_GiB_multiplier={base/shared:.2f}x_on_top_of_paged")

    cfg = nlg_moe("prefix-bench", 4, 256, 4, 16, vocab=1024).replace(
        param_dtype="float32", compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    preamble = jax.random.randint(rng, (32,), 0, cfg.vocab_size).tolist()
    tails = [jax.random.randint(jax.random.fold_in(rng, i), (8,), 0,
                                cfg.vocab_size).tolist() for i in range(6)]
    reqs = [Request(prompt=preamble + t, max_new_tokens=8) for t in tails]

    rows = {}
    peng = None
    for mode in ("paged", "prefix"):
        eng = ContinuousEngine(cfg, params, slots=6, capacity=128, paged=True,
                               page_size=ps, n_pages=36,
                               prefix_sharing=(mode == "prefix"))
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        peak_used = eng.n_pages - min(m["free_pages"] for m in eng.metrics_log)
        rows[mode] = peak_used  # counters only — don't keep both engines' caches alive
        if mode == "prefix":
            peng = eng
        emit(f"prefix_peak_pages_{mode}", 0.0,
             f"peak_used={peak_used}/{eng.n_pages},min_free={eng.n_pages - peak_used}")
    used_paged, used_prefix = rows["paged"], rows["prefix"]
    emit("prefix_page_reduction", 0.0,
         f"{used_paged}/{used_prefix}={used_paged/max(used_prefix,1):.2f}x_fewer_live_pages,"
         f"hits={peng.prefix_hits},shared_tokens={peng.prefix_hit_tokens},"
         f"cow_copies={peng.cow_copies}")

    # parallel sampling: n samples off one prompt share ALL its pages
    n = 4
    eng = ContinuousEngine(cfg, params, slots=n, capacity=128, paged=True,
                           page_size=ps, n_pages=32, prefix_sharing=True)
    eng.submit_n(Request(prompt=preamble + tails[0], max_new_tokens=8), n)
    fork_pages = eng.pool.used_count
    solo_pages = eng.pool.pages_for(len(preamble) + len(tails[0]))
    eng.run_until_done()
    emit("prefix_n_sample_fork_pages", 0.0,
         f"n={n},pages_at_admission={fork_pages}(vs_independent={n * solo_pages}),"
         f"cow_copies={eng.cow_copies}")
    print("# prefix_metrics_json:", json.dumps({
        "config": {"slots": 6, "capacity": 128, "page_size": ps, "n_pages": 36},
        "prefix_hits": peng.prefix_hits,
        "prefix_hit_tokens": peng.prefix_hit_tokens,
        "cow_copies": peng.cow_copies,
        "ticks": peng.metrics_log,
    }))


def chunked_prefill() -> None:
    """Chunked prefill-into-pages (serving admission path): (a) the temp
    contiguous prefill cache the scatter path allocated per admission is
    gone — its bytes were pure double-buffering of the prompt's K/V; (b)
    head-of-line blocking under mixed traffic — a long-prompt admission's
    submit wall-clock (the blocking compute before control returns) and the
    decode tokens running slots produce while the long prompt is still
    prefilling, scatter vs chunked; (c) measured prefill-FLOPs savings on
    shared-preamble traffic (a prefix-sharing admission starts its chunks
    after the shared pages — savings = prefix_len / prompt_len); (d) per-tick
    prefill/decode token telemetry as JSON."""
    import json
    import time as _time

    from repro.core.prmoe import nlg_moe
    from repro.models.model import init_caches, init_params
    from repro.quant import kv_cache_bytes
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.engine import Request

    cfg = nlg_moe("chunked-bench", 4, 256, 4, 16, vocab=1024).replace(
        param_dtype="float32", compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    slots, capacity, ps, chunk = 4, 192, 16, 32

    # (a) temp admission buffer: scatter runs the prompt through a fresh
    # [1, capacity] contiguous cache before scattering into pages; chunked
    # writes pages directly, so those bytes vanish from the admission path
    for kv_bits in (0, 8):
        tag = f"int{kv_bits}" if kv_bits else "fp32"
        tmp = kv_cache_bytes(jax.eval_shape(
            lambda b=kv_bits: init_caches(cfg, 1, capacity, kv_bits=b)))
        emit(f"chunked_prefill_temp_buffer_bytes_{tag}", 0.0,
             f"scatter_per_admission={tmp},chunked=0,eliminated={tmp}")

    # (b) mixed traffic: short requests decoding, one long prompt arrives
    rng = jax.random.PRNGKey(1)
    shorts = [jax.random.randint(jax.random.fold_in(rng, i), (8,), 0,
                                 cfg.vocab_size).tolist() for i in range(2)]
    long_p = jax.random.randint(jax.random.fold_in(rng, 9), (128,), 0,
                                cfg.vocab_size).tolist()
    rows = {}
    for mode in ("scatter", "chunked"):
        eng = ContinuousEngine(cfg, params, slots=slots, capacity=capacity,
                               paged=True, page_size=ps, prefill_mode=mode,
                               prefill_chunk=chunk)
        # warm the compile caches so submit() timing is compute, not tracing
        w = eng.submit(Request(prompt=long_p, max_new_tokens=1))
        eng.run_until_done()
        sids = [eng.submit(Request(prompt=p, max_new_tokens=64)) for p in shorts]
        eng.step()
        t0 = _time.perf_counter()
        lid = eng.submit(Request(prompt=long_p, max_new_tokens=4))
        submit_us = (_time.perf_counter() - t0) * 1e6
        li = next(i for i, s in enumerate(eng.slots) if s.request_id == lid)
        decoded_during = 0
        ticks_to_first = 0
        while eng.slots[li].active and (eng.slots[li].prefilling
                                        or not eng.slots[li].generated):
            before = sum(len(eng.slots[i].generated) for i in range(slots) if i != li)
            eng.step()
            ticks_to_first += 1
            decoded_during += sum(
                len(eng.slots[i].generated) for i in range(slots) if i != li) - before
        eng.run_until_done()
        rows[mode] = submit_us
        emit(f"chunked_prefill_long_admit_{mode}", submit_us,
             f"prompt=128tok,decode_tokens_while_prefilling={decoded_during},"
             f"ticks_to_first_token={ticks_to_first}")
    emit("chunked_prefill_admit_blocking_reduction", 0.0,
         f"{rows['scatter']/max(rows['chunked'], 1e-9):.2f}x_shorter_submit_block"
         f"(bounded_by_chunk={chunk}tok_per_tick)")

    # (c) shared-preamble FLOPs savings: serve the preamble once, then N
    # requests that repeat it — chunked+sharing never recomputes it
    preamble = jax.random.randint(rng, (64,), 0, cfg.vocab_size).tolist()
    tails = [jax.random.randint(jax.random.fold_in(rng, 20 + i), (16,), 0,
                                cfg.vocab_size).tolist() for i in range(6)]
    stats = {}
    peng = None
    for sharing in (False, True):
        eng = ContinuousEngine(cfg, params, slots=slots, capacity=capacity,
                               paged=True, page_size=ps, prefill_chunk=chunk,
                               prefix_sharing=sharing)
        first = eng.submit(Request(prompt=preamble + tails[0], max_new_tokens=8))
        while any(s.active and s.prefilling for s in eng.slots):
            eng.step()
        for t in tails[1:]:
            eng.submit(Request(prompt=preamble + t, max_new_tokens=8))
        eng.run_until_done()
        stats[sharing] = (eng.prefill_tokens_total, eng.prefill_tokens_skipped)
        if sharing:
            peng = eng
    total_ns, _ = stats[False]
    total_s, skipped = stats[True]
    emit("chunked_prefill_shared_flops_saved", 0.0,
         f"prefill_tokens:no_sharing={total_ns},sharing={total_s},"
         f"skipped={skipped},saved={skipped/total_ns:.2%}"
         f"(analytic_prefix/prompt={len(preamble)/(len(preamble)+16):.2%}_per_hit)")
    print("# chunked_prefill_metrics_json:", json.dumps({
        "config": {"slots": slots, "capacity": capacity, "page_size": ps,
                   "prefill_chunk": chunk},
        "prefill_tokens_total": peng.prefill_tokens_total,
        "prefill_tokens_skipped": peng.prefill_tokens_skipped,
        "prefix_hits": peng.prefix_hits,
        "ticks": peng.metrics_log[-64:],
    }))


def obs() -> None:
    """Observability layer (src/repro/obs/): the contract is that telemetry
    compiled into the serving hot path is free when off.  (a) steady-state
    decode-tick wall-clock through ContinuousEngine under three Obs levels —
    ``Obs.disabled()`` (baseline), the default ``Obs()`` (metrics on, tracer
    off — what every engine runs with), and everything on (tracer + per-tick
    routing stats); asserts default-vs-disabled overhead <1%; (b) raw tracer
    emit cost per begin/end pair, enabled vs the no-op path; (c) routing
    telemetry (dropped fraction, gate entropy, f·P imbalance) from one
    jitted training step and one decode tick of the SAME model family; (d)
    retrace-watchdog compile accounting, warmup vs steady (steady retraces
    must be zero); (e) the full metrics snapshot as JSON."""
    import json
    import time as _time

    from repro.core.prmoe import nlg_moe
    from repro.models.model import init_params
    from repro.obs import Obs, Tracer
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.engine import Request

    cfg = nlg_moe("obs-bench", 4, 256, 4, 16, vocab=1024).replace(
        param_dtype="float32", compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    slots, capacity, ps = 4, 256, 16
    rng = jax.random.PRNGKey(1)
    prompts = [jax.random.randint(jax.random.fold_in(rng, i), (16,), 0,
                                  cfg.vocab_size).tolist() for i in range(slots)]

    def build(o):
        eng = ContinuousEngine(cfg, params, slots=slots, capacity=capacity,
                               paged=True, page_size=ps, obs=o)
        for p in prompts:  # long decodes: every measured tick is pure decode
            eng.submit(Request(prompt=p, max_new_tokens=capacity - 20))
        for _ in range(6):  # warmup: compile + reach watchdog steady state
            eng.step()
        return eng

    modes = {
        "disabled": build(Obs.disabled()),
        "default": build(Obs()),
        "full": build(Obs(trace=True, routing=True)),
    }
    # interleave measurement rounds so clock drift hits all modes equally;
    # min-of-ticks isolates the instrumentation cost from scheduler noise
    mins = {k: float("inf") for k in modes}
    for _ in range(5):
        for k, eng in modes.items():
            for _ in range(8):
                t0 = _time.perf_counter()
                eng.step()  # blocks on the donated caches before returning
                mins[k] = min(mins[k], _time.perf_counter() - t0)
    base = mins["disabled"] * 1e6
    for k in ("disabled", "default", "full"):
        us = mins[k] * 1e6
        emit(f"obs_decode_tick_{k}", us,
             f"overhead_vs_disabled={us/base - 1:+.2%}")
    overhead = mins["default"] / mins["disabled"] - 1
    assert overhead < 0.01, (
        f"default Obs (metrics on, tracer off) added {overhead:.2%} to the "
        "decode tick — the <1% no-op-path contract is broken")
    emit("obs_overhead_guard", 0.0, f"default_vs_disabled={overhead:+.2%}(<1%_OK)")

    # (b) raw tracer emit cost, on vs off
    for enabled in (True, False):
        tr = Tracer(enabled=enabled)
        n = 20000
        t0 = _time.perf_counter()
        for i in range(n):
            tr.begin(("bench", 0), "s")
            tr.end(("bench", 0))
        per = (_time.perf_counter() - t0) / n * 1e6
        emit(f"obs_tracer_span_pair_{'on' if enabled else 'off'}", per,
             f"events={tr.n_events}")

    # (c) routing telemetry: one training step and one decode tick
    from repro.core.gating import summarize_routing
    from repro.training.optimizer import init_adamw
    from repro.training.trainer import TrainConfig, make_train_step

    opt = init_adamw(params)
    step = jax.jit(make_train_step(cfg, TrainConfig(lr=1e-3, warmup_steps=1,
                                                    decay_steps=10),
                                   with_routing=True))
    toks = jax.random.randint(jax.random.fold_in(rng, 99), (2, 64), 0,
                              cfg.vocab_size)
    _, _, metrics = step(params, opt, toks[:, :-1], toks[:, 1:])
    train_r = summarize_routing(metrics["routing"])
    emit("obs_routing_train_step", 0.0,
         f"moe_layers={train_r['moe_layers']},drop={train_r['dropped_frac']:.3f},"
         f"entropy={train_r['entropy']:.3f},imbalance={train_r['imbalance']:.3f}")
    full = modes["full"]
    full.step()
    decode_r = full.last_metrics.get("routing")
    emit("obs_routing_decode_tick", 0.0,
         f"moe_layers={decode_r['moe_layers']},drop={decode_r['dropped_frac']:.3f},"
         f"entropy={decode_r['entropy']:.3f},imbalance={decode_r['imbalance']:.3f}")

    # (d) watchdog: warmup compiles happened, steady state holds, and the
    # measured ticks above never retraced
    wd = full.obs.watchdog.snapshot()
    assert wd["steady"] and wd["steady_retraces"] == 0, wd
    emit("obs_retrace_watchdog", 0.0,
         f"warmup_compiles={wd['total_compiles']},steady={wd['steady']},"
         f"steady_retraces={wd['steady_retraces']}(must_be_0)")

    # the static contract checker (repro.analysis) must predict exactly the
    # compiles the watchdog observed — the trace-time and runtime halves of
    # the instrument agreeing on the number
    from repro.analysis import Workload, predict_compiles

    ticks = 6 + 5 * 8 + 1  # warmup + measurement rounds + the routing tick
    pred = predict_compiles(
        slots=slots, capacity=capacity, page_size=ps,
        prefill_chunk=full.prefill_chunk,
        workload=Workload(tuple(len(p) for p in prompts),
                          capacity - 20, ticks))
    observed = {k: v for k, v in wd["per_fn"].items() if k in pred}
    assert observed == pred, (observed, pred)
    emit("obs_predicted_compiles", float(sum(pred.values())),
         "static_contract_prediction==watchdog_observation")

    print("# obs_metrics_json:", json.dumps({
        "config": {"slots": slots, "capacity": capacity, "page_size": ps},
        "tick_overhead_default_vs_disabled": overhead,
        "watchdog": wd,
        "snapshot": full.obs.metrics.snapshot(),
    }))


def fused_tick() -> None:
    """One fused tick (PR 8): grouped dropless expert dispatch + batched
    multi-slot chunk prefill.  (a) >= 3 concurrent mid-prefill admissions
    served by ONE fixed-shape jitted prefill call per tick — jitted calls
    per tick and batched-call occupancy from the engine's own metrics;
    (b) the retrace-watchdog acceptance: predicted compile counts
    (``predict_compiles(prefill_mode="batched")``) == observed per-fn counts,
    with the batched entry compiling exactly once; (c) tick p50/p99 batched
    vs per-slot chunked on the same traffic (batched must not regress p50);
    (d) dead expert FLOPs: capacity-factor padding (``[E, C]`` slots gating
    left empty) vs the grouped layout's worst-case tile padding on the same
    token counts."""
    import json
    import numpy as np

    from repro.analysis import Workload, predict_compiles
    from repro.analysis.graph import capacity_dead_compute
    from repro.core.dispatch_grouped import GROUPED_TILE, grouped_rows
    from repro.core.prmoe import nlg_moe
    from repro.models.model import init_params
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.engine import Request

    cfg = nlg_moe("fused-bench", 4, 256, 4, 16, vocab=1024).replace(
        param_dtype="float32", compute_dtype="float32", moe_impl="grouped")
    params = init_params(cfg, jax.random.PRNGKey(0))
    slots, capacity, ps, chunk = 4, 256, 16, 64
    rng = jax.random.PRNGKey(1)
    # 4 long prompts admitted together: every slot stays mid-prefill for 3
    # ticks, so the batched call runs at full occupancy before decode starts
    plens = (192, 192, 160, 128)
    prompts = [jax.random.randint(jax.random.fold_in(rng, i), (n,), 0,
                                  cfg.vocab_size).tolist()
               for i, n in enumerate(plens)]
    n_new = 24

    def run(mode):
        eng = ContinuousEngine(cfg, params, slots=slots, capacity=capacity,
                               paged=True, page_size=ps, prefill_chunk=chunk,
                               prefill_mode=mode)
        for p in prompts:
            eng.submit(Request(prompt=p, max_new_tokens=n_new))
        eng.run_until_done()
        # second identical wave, fully warm: these are the measured ticks
        eng.metrics_log.clear()
        for p in prompts:
            eng.submit(Request(prompt=p, max_new_tokens=n_new))
        eng.run_until_done()
        return eng

    engines = {m: run(m) for m in ("chunked", "batched")}

    # (a) fused-tick dispatch accounting from the engine's own telemetry
    eb = engines["batched"]
    pre = [m for m in eb.metrics_log if m.get("prefill_tokens", 0)]
    occ = max(m["batched_prefill_occupancy"] for m in pre)
    calls = max(m["jitted_calls"] for m in pre)
    assert occ >= 3 / slots, f"want >=3 concurrent mid-prefill rows, occ={occ}"
    assert calls <= 2, f"fused tick issued {calls} jitted calls"
    emit("fused_tick_batched_occupancy", 0.0,
         f"peak={occ:.2f},rows={int(occ * slots)}_of_{slots}")
    emit("fused_tick_jitted_calls", float(calls),
         "max_per_prefill_tick(<=2:batched_prefill+decode)")

    # (b) predicted == observed compile counts (both waves in one workload
    # is wrong — the second wave adds no compiles, so predict the first)
    wd = eb.obs.watchdog.snapshot()
    assert wd["steady_retraces"] == 0, wd
    pred = predict_compiles(slots=slots, capacity=capacity, page_size=ps,
                            prefill_chunk=chunk, prefill_mode="batched",
                            workload=Workload(plens, n_new, 64))
    observed = {k: v for k, v in wd["per_fn"].items() if k in pred}
    assert observed == pred, (observed, pred)
    assert pred["prefill_chunk_batched"] == 1
    emit("fused_tick_predicted_compiles", float(sum(pred.values())),
         "static_prediction==watchdog_observation,batched_entry_compiles_once")

    # (c) tick latency, batched vs chunked, same traffic
    stats = {}
    for mode, eng in engines.items():
        ts = np.asarray([m["tick_s"] for m in eng.metrics_log]) * 1e6
        stats[mode] = (float(np.percentile(ts, 50)), float(np.percentile(ts, 99)))
        emit(f"fused_tick_p50_{mode}", stats[mode][0],
             f"p99={stats[mode][1]:.0f}us,ticks={len(ts)}")
    assert stats["batched"][0] <= stats["chunked"][0] * 1.25, (
        "batched tick p50 regressed vs per-slot chunked", stats)

    # (d) dead expert FLOPs on one full batched prefill call's tokens, at a
    # REALIZED routing of the same gating spec (not the analytic worst case:
    # actual tile padding is data-dependent and far below it).  Useful work
    # differs too — capacity DROPS overflowing assignments, dropless keeps
    # every one — so compare dead fraction per expert-MLP row actually run.
    from repro.core.gating import top_k_gating
    from repro.core.moe import init_moe

    f = next(ls.ffn for seg in cfg.segments for ls in seg.pattern
             if getattr(ls.ffn, "num_experts", 0))
    nt, tk = slots * chunk, slots * chunk * f.top_k
    moe_p = init_moe(jax.random.fold_in(rng, 7), cfg, f, jnp.float32)
    xs = jax.random.normal(jax.random.fold_in(rng, 8), (nt, cfg.d_model))
    g = top_k_gating(xs @ moe_p["router"], f.top_k, tk)
    counts = np.bincount(np.asarray(g.expert_idx).reshape(-1),
                         minlength=f.num_experts)
    cap = capacity_dead_compute(nt, f.num_experts, f.top_k, f.capacity_factor)
    kept = int(np.minimum(counts, cap["capacity"]).sum())
    cap_dead = 1.0 - kept / cap["slots"]
    t = GROUPED_TILE
    ct_actual = int(((counts + t - 1) // t * t).sum())
    ct_worst = grouped_rows(nt, f.top_k, f.num_experts, t)
    g_dead = 1.0 - tk / ct_actual
    emit("fused_tick_dead_flops_capacity", 0.0,
         f"dead_row_fraction={cap_dead:.1%}(E={f.num_experts},"
         f"C={cap['capacity']},dropped={tk - kept}_of_{tk})")
    emit("fused_tick_dead_flops_grouped", 0.0,
         f"dead_row_fraction={g_dead:.1%}(Ct={ct_actual},"
         f"worst_case={ct_worst},dropped=0_of_{tk})")
    assert ct_actual <= ct_worst
    assert g_dead < cap_dead, (g_dead, cap_dead)

    print("# fused_tick_metrics_json:", json.dumps({
        "config": {"slots": slots, "capacity": capacity, "page_size": ps,
                   "prefill_chunk": chunk, "moe_impl": cfg.moe_impl,
                   "prompt_lens": list(plens)},
        "batched_occupancy_peak": occ,
        "jitted_calls_max_prefill_tick": calls,
        "predicted_compiles": pred,
        "tick_us": {m: {"p50": s[0], "p99": s[1]} for m, s in stats.items()},
        "dead_flops_fraction": {"capacity": cap_dead, "grouped": g_dead,
                                "capacity_dropped": tk - kept},
        "watchdog": wd,
    }))


def ep_serving() -> None:
    """Expert-parallel serving topology (PR 9): what sharding the experts
    over a serving mesh buys vs single-device.  (a) MEASURED per-device
    parameter bytes on a (4, 2) ("pod", data) mesh — expert stacks sharded
    ep-ways, attention/router replicated — via the real placement path
    (subprocess under 8 fake CPU devices, `serving/ep.py`); (b) the
    aggregate-bandwidth ledger: expert bytes each device reads per tick,
    sharded vs single-device (the paper's §5 latency lever); (c) the
    all-to-all exchange volume the sharding costs per MoE layer — decode's
    replicated-token all_gather, prefill's token-sharded a2a — and the
    flat vs hierarchical two-hop (Fig. 8) message count per device."""
    import json
    import os
    import subprocess
    import sys as _sys

    from repro.core.gating import expert_capacity

    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, json
from repro.configs.registry import all_configs, make_reduced, with_moe_ffn
from repro.models.model import init_params
from repro.serving.ep import build_serving_mesh, place_params, placed_param_bytes
from repro.parallel.sharding import use_mesh

E = 8
cfg = with_moe_ffn(make_reduced(all_configs()["nlg-350m-moe128"]), num_experts=E)
params = init_params(cfg, jax.random.PRNGKey(0))
flat = jax.tree_util.tree_flatten_with_path(params)[0]
total = sum(l.size * l.dtype.itemsize for _, l in flat)
# expert stacks are the layer-stacked [L, E, d, f] moe mlp weights
expert = sum(l.size * l.dtype.itemsize for kp, l in flat
             if "moe" in jax.tree_util.keystr(kp)
             and jax.tree_util.keystr(kp).split("'")[-2] in ("wi", "wg", "wo"))
mesh, rules = build_serving_mesh((4, 2))
with use_mesh(mesh, rules):
    placed = place_params(mesh, rules, params)
print(json.dumps({"total": total, "expert": expert,
                  "per_dev": placed_param_bytes(placed)}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([_sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    m = json.loads(r.stdout.strip().splitlines()[-1])
    ep = 8
    expect = (m["total"] - m["expert"]) + m["expert"] // ep
    assert m["per_dev"] == expect, (m, expect)
    emit("ep_serving_params_per_device", 0.0,
         f"mesh=(4x2),{m['per_dev'] / 1e6:.2f}MB_of_{m['total'] / 1e6:.2f}MB,"
         f"expert_shard={m['expert'] // ep / 1e6:.2f}MB(1/{ep})")
    emit("ep_serving_expert_read_per_tick", 0.0,
         f"sharded={m['expert'] // ep / 1e6:.2f}MB/device,"
         f"single={m['expert'] / 1e6:.2f}MB:aggregate_bandwidth_x{ep}")

    # (c) exchange volume per MoE layer per device, f32 reduced config
    #     (E=8, K=2, d=128): decode = all_gather of the [E, C, d] output
    #     buffer (each device contributes its E/ep slice); prefill chunk of
    #     64 tokens = dispatch a2a out + combine a2a back
    E, K, d, bytes_el = 8, 2, 128, 4
    for T, phase in ((4, "decode_allgather"), (64, "prefill_a2a")):
        if phase == "decode_allgather":
            cap = expert_capacity(T, E, K, 8.0)
            vol = (E - E // ep) * cap * d * bytes_el  # received per device
        else:
            cap = expert_capacity(T // ep, E, K, 8.0)  # per-shard gating
            vol = 2 * (ep - 1) * (E // ep) * cap * d * bytes_el
        emit(f"ep_serving_{phase}_volume", 0.0,
             f"T={T},cap={cap},{vol / 1e3:.1f}KB/device/layer,single_device=0KB")
    for shape in ((8,), (4, 2), (2, 4)):
        n = 1
        for s in shape:
            n *= s
        flat_msgs = n - 1
        hier_msgs = sum(s - 1 for s in shape)
        emit("ep_serving_a2a_messages", 0.0,
             f"mesh={'x'.join(map(str, shape))},flat={flat_msgs},"
             f"hierarchical={hier_msgs}_per_device(Fig8_two_hop)")

    print("# ep_serving_metrics_json:", json.dumps({
        "mesh": [4, 2], "ep_degree": ep,
        "params_bytes": {"total": m["total"], "expert": m["expert"],
                         "per_device": m["per_dev"]},
    }))


def spec() -> None:
    """Draft-then-verify speculative decoding over CoW page forks (PR 10):
    (a) accepted tokens per verify pass with a same-family (self) drafter —
    ASSERTS > 1.0, i.e. each batched target forward emits more than one
    token; (b) the fresh-init drafter contrast — near-zero accept, every
    window's fork pages rolled back, output still token-exact greedy;
    (c) target forward passes per emitted token, speculative vs the
    non-speculative baseline on the same traffic (the paper-level win:
    the expensive MoE model runs once per window, not once per token);
    (d) decode-tick wall-clock p50/p99 for all three engines plus the
    fork-page commit/rollback ledger from the metrics registry (JSON)."""
    import json
    import numpy as np

    from repro.core.prmoe import nlg_moe
    from repro.models.model import init_params
    from repro.obs import Obs
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.engine import Request

    # dropless grouped dispatch: capacity-factor dropping is batch-size
    # dependent (a k+1-token verify pass would route differently from the
    # baseline's one-token decode), so greedy parity needs moe_impl=grouped
    cfg = nlg_moe("spec-bench", 4, 256, 4, 16, vocab=1024).replace(
        param_dtype="float32", compute_dtype="float32", moe_impl="grouped")
    params = init_params(cfg, jax.random.PRNGKey(0))
    fresh = init_params(cfg, jax.random.PRNGKey(1))
    k, slots, n_new = 4, 3, 32
    rng = jax.random.PRNGKey(2)
    prompts = [jax.random.randint(jax.random.fold_in(rng, i), (n,), 0,
                                  cfg.vocab_size).tolist()
               for i, n in enumerate((12, 9, 17))]

    def run(spec_draft):
        kw = dict(slots=slots, capacity=64, paged=True, page_size=4,
                  obs=Obs())
        if spec_draft is not None:
            kw.update(spec_draft=spec_draft, spec_k=k)
        eng = ContinuousEngine(cfg, params, **kw)
        out = []
        for _ in range(2):  # wave 0 warms every jit, wave 1 is measured
            eng.metrics_log.clear()
            ids = [eng.submit(Request(prompt=p, max_new_tokens=n_new))
                   for p in prompts]
            done = eng.run_until_done()
            out = [done[i].tokens for i in ids]
        return eng, out

    engines = {"baseline": run(None), "self_draft": run((cfg, params)),
               "fresh_draft": run((cfg, fresh))}
    base_out = engines["baseline"][1]
    for name, (_, out) in engines.items():
        assert out == base_out, f"{name} diverged from greedy baseline"

    # (a)/(b) accept accounting from the engine's own per-tick spec metrics
    totals = {}
    for name in ("self_draft", "fresh_draft"):
        eng = engines[name][0]
        s = [m["spec"] for m in eng.metrics_log if m.get("spec")]
        t = {f: sum(m.get(f, 0) for m in s)
             for f in ("windows", "drafted", "accepted", "emitted", "resyncs")}
        totals[name] = t
        tpv = t["emitted"] / t["windows"]
        rate = t["accepted"] / max(t["drafted"], 1)
        c = eng.obs.metrics.snapshot()["counters"]
        emit(f"spec_tokens_per_verify_{name}", 0.0,
             f"{tpv:.2f}tok/verify(k={k},accept_rate={rate:.2f},"
             f"windows={t['windows']},committed_pages="
             f"{c['spec.committed_pages']},rolled_back_pages="
             f"{c['spec.rolled_back_pages']})")
    self_tpv = totals["self_draft"]["emitted"] / totals["self_draft"]["windows"]
    fresh_tpv = (totals["fresh_draft"]["emitted"]
                 / totals["fresh_draft"]["windows"])
    assert self_tpv > 1.0, (
        "same-family drafter must accept >1 token per verify pass", self_tpv)
    assert fresh_tpv < self_tpv, (fresh_tpv, self_tpv)

    # (c) target forward passes per emitted token: the baseline decodes one
    # token per (batched) tick; the speculative engine emits a whole window
    # per verify pass.  Per-slot passes = windows / emitted.
    base_ticks = [m for m in engines["baseline"][0].metrics_log
                  if m["tokens_this_tick"]]
    emit("spec_target_passes_per_token", 0.0,
         f"baseline=1.00,self_draft="
         f"{totals['self_draft']['windows'] / totals['self_draft']['emitted']:.2f},"
         f"fresh_draft="
         f"{totals['fresh_draft']['windows'] / totals['fresh_draft']['emitted']:.2f}")

    # (d) decode-tick wall-clock (spec ticks carry draft + verify + commit)
    stats = {}
    for name, (eng, _) in engines.items():
        ts = np.asarray([m["tick_s"] for m in eng.metrics_log
                         if m["tokens_this_tick"]]) * 1e6
        stats[name] = {"p50": float(np.percentile(ts, 50)),
                       "p99": float(np.percentile(ts, 99)),
                       "ticks": len(ts)}
        emit(f"spec_decode_tick_p50_{name}", stats[name]["p50"],
             f"p99={stats[name]['p99']:.0f}us,ticks={len(ts)}")
    assert len(base_ticks) > totals["self_draft"]["windows"] / slots, (
        "speculation must need fewer target passes than baseline ticks")

    print("# spec_metrics_json:", json.dumps({
        "config": {"k": k, "slots": slots, "page_size": 4,
                   "max_new_tokens": n_new,
                   "prompt_lens": [len(p) for p in prompts]},
        "totals": totals,
        "tokens_per_verify": {"self_draft": self_tpv,
                              "fresh_draft": fresh_tpv},
        "tick_us": stats,
    }))


SECTIONS = {
    "table3": table3,
    "fig10": fig10,
    "fig11": fig11,
    "fig12": fig12,
    "fig13": fig13,
    "fig14_15": fig14_15,
    "kernel6x": kernel6x,
    "moe_impl": moe_impl,
    "quant": quant,
    "kv_quant": kv_quant,
    "paged": paged,
    "prefix": prefix,
    "chunked_prefill": chunked_prefill,
    "obs": obs,
    "fused_tick": fused_tick,
    "ep_serving": ep_serving,
    "spec": spec,
}


def main() -> None:
    picks = sys.argv[1:] or list(SECTIONS)
    print("name,us_per_call,derived")
    for p in picks:
        SECTIONS[p]()


if __name__ == "__main__":
    main()
