#!/usr/bin/env sh
# Single CI entrypoint (`make test`): quant subsystem module first (fast,
# covers the newest code), then the tier-1 suite minus the seed's known-red
# set (all of tests/test_dist.py + 2 HLO-accounting tests), so a green exit
# means "no worse than seed".  Shrink the exclusion list as those get fixed;
# the raw tier-1 command stays `PYTHONPATH=src python -m pytest -x -q`.
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -q tests/test_quant.py
python -m pytest -x -q \
  --ignore=tests/test_dist.py \
  --deselect tests/test_system.py::TestHLOAccounting::test_trip_count_multiplication \
  --deselect tests/test_system.py::TestHLOAccounting::test_collectives_counted
