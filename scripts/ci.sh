#!/usr/bin/env sh
# Single CI entrypoint (`make test`): the newest subsystems first (fast
# signal), then the full tier-1 suite, then the multi-device dist suite as
# its own stage (subprocesses under an 8-device host platform).  All three
# stages are green as of PR 2 — the seed's red set (8 dist + 2 HLO
# accounting) was repaired there.  The raw tier-1 command stays
# `PYTHONPATH=src python -m pytest -x -q`.
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# static-analysis gate: host-sync/tracer lint over src/repro, compile-shape
# contract + closure + compile-count prediction, donation/aliasing audit of
# every jitted engine fn, and the jaxpr graph audit (collectives, dtype
# drift, capacity dead-compute) on the reduced glm4 + gemma3 engines.
# Trace-time only — no device execution — so it runs first as the cheapest
# whole-stack signal.
python -m repro.launch.analyze

python -m pytest -q tests/test_quant.py tests/test_kv_quant.py

# paged serving stage: block-pool allocator, page-gather kernel vs ref,
# paged-vs-contiguous greedy parity, preemption/fragmentation scheduling
python -m pytest -q tests/test_paged.py

# prefix-sharing stage: refcount/CoW pool property fuzz (hypothesis, or the
# tests/_hyp.py single-draw shim), prefix-index semantics, shared-page
# parity vs the non-prefix engine, and the randomized scheduler fuzz
python -m pytest -q tests/test_kv_pool_prop.py tests/test_prefix.py

# observability stage: histogram percentile math, tracer nesting + Chrome
# trace_event schema, SLO accounting (queue-wait/TTFT/TPOT) on a
# hand-scheduled run, routing-stats parity with load_balance_stats under
# jit, and the steady-state zero-retrace regression
python -m pytest -q tests/test_obs.py

# chunked-prefill stage: prefill-chunk kernel vs ref, chunked-vs-scatter
# greedy parity (fp/int8, ring mixes, prefix sharing), chunk-boundary sweep,
# and the resumable admission state machine (bounded decode stalls,
# mid-prefill preemption, fork wait, progressive prefix registration)
python -m pytest -q tests/test_chunked.py

# fused-tick stage: grouped dropless dispatch layout invariants, grouped
# Pallas kernel (fp/int8/int4) vs the gather-einsum oracle, token-exact
# parity vs the dropless einsum reference under capacity-overflowing skew,
# and batched-vs-chunked engine greedy parity across arch families
# (batched engine cases run inside test_chunked.py above)
python -m pytest -q tests/test_grouped.py

# speculation stage: draft-then-verify decoding over CoW page forks —
# token-exact greedy parity vs the non-speculative engine across arch
# families / int8 KV / prefix sharing / chunked+batched admission, window
# geometry (k=1, page-boundary spans, budget clamps, mid-window eos),
# preemption + fork admissions mid-speculation, the pool-level fork
# commit/rollback run-helper properties + window-trace fuzz, the spec
# metrics/span observability asserts, and the predicted==observed verify
# compile-count contract
python -m pytest -q tests/test_spec.py \
    tests/test_kv_pool_prop.py::TestSpecRunHelpers \
    tests/test_kv_pool_prop.py::test_spec_window_trace_invariants \
    tests/test_obs.py::TestSpeculationObs \
    tests/test_analysis.py::test_predicted_equals_observed_compiles_spec

python -m pytest -x -q --ignore=tests/test_dist.py \
    --ignore=tests/test_dist_serving.py

# dist tier (jax-compat shim in parallel/compat.py + the dense-dispatch
# partial-sum-gather fix keep it green; the marker lets it be selected /
# skipped explicitly).  The subprocess scripts set their own XLA_FLAGS;
# exporting here too covers any future in-process multi-device test.
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m pytest -q -m dist tests/test_dist.py

# dist-serving tier: expert-parallel serving parity (sharded engine greedy
# output token-identical to single-device across arch mixes, int8 KV,
# grouped experts, batched prefill, prefix sharing, mesh shapes), the
# routing/collective conservation fuzz, preemption pool-drain on a sharded
# engine, the moe_dense multi-device guard, and the EP analysis-gate run
# (contract closure + donation + missing-collective on the sharded registry)
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m pytest -q -m dist tests/test_dist_serving.py \
    tests/test_analysis.py::test_ep_engine_contract_closure
