.PHONY: test analyze test-quant test-paged test-prefix test-chunked test-obs test-grouped test-spec test-dist test-dist-serving bench-quant bench-kv bench-paged bench-prefix bench-chunked bench-obs bench-fused-tick bench-ep-serving bench-spec

test:
	sh scripts/ci.sh

analyze:
	PYTHONPATH=src python -m repro.launch.analyze

test-quant:
	PYTHONPATH=src python -m pytest -q tests/test_quant.py tests/test_kv_quant.py

test-paged:
	PYTHONPATH=src python -m pytest -q tests/test_paged.py

test-prefix:
	PYTHONPATH=src python -m pytest -q tests/test_kv_pool_prop.py tests/test_prefix.py

test-chunked:
	PYTHONPATH=src python -m pytest -q tests/test_chunked.py

test-obs:
	PYTHONPATH=src python -m pytest -q tests/test_obs.py

test-grouped:
	PYTHONPATH=src python -m pytest -q tests/test_grouped.py \
		tests/test_chunked.py::TestBatchedPrefillTick

test-spec:
	PYTHONPATH=src python -m pytest -q tests/test_spec.py \
		tests/test_kv_pool_prop.py::TestSpecRunHelpers \
		tests/test_kv_pool_prop.py::test_spec_window_trace_invariants \
		tests/test_obs.py::TestSpeculationObs \
		tests/test_analysis.py::test_predicted_equals_observed_compiles_spec

test-dist:
	PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		python -m pytest -q -m dist tests/test_dist.py

test-dist-serving:
	PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		python -m pytest -q -m dist tests/test_dist_serving.py \
		tests/test_analysis.py::test_ep_engine_contract_closure

bench-quant:
	PYTHONPATH=src python -m benchmarks.run quant

bench-kv:
	PYTHONPATH=src python -m benchmarks.run kv_quant

bench-paged:
	PYTHONPATH=src python -m benchmarks.run paged

bench-prefix:
	PYTHONPATH=src python -m benchmarks.run prefix

bench-chunked:
	PYTHONPATH=src python -m benchmarks.run chunked_prefill

bench-obs:
	PYTHONPATH=src python -m benchmarks.run obs

bench-fused-tick:
	PYTHONPATH=src python -m benchmarks.run fused_tick

bench-ep-serving:
	PYTHONPATH=src python -m benchmarks.run ep_serving

bench-spec:
	PYTHONPATH=src python -m benchmarks.run spec
