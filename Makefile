.PHONY: test test-quant bench-quant

test:
	sh scripts/ci.sh

test-quant:
	PYTHONPATH=src python -m pytest -q tests/test_quant.py

bench-quant:
	PYTHONPATH=src python -m benchmarks.run quant
