"""DS-MoE serving demo (§5): train a small MoE briefly, then serve batched
requests through the engine comparing the paper-baseline sparse-einsum
dispatch against the optimized dense mapping-table dispatch — the same model
weights, measurably different step latency.

  PYTHONPATH=src python examples/serve_moe.py
"""
import time

import numpy as np

import jax

from repro.core.prmoe import nlg_moe
from repro.data.pipeline import data_stream
from repro.serving.engine import Engine, EngineConfig, Request
from repro.training.trainer import TrainConfig, train_loop

VOCAB = 512


def main() -> None:
    cfg = nlg_moe("serve-demo-moe", 4, 192, 4, 16, vocab=VOCAB).replace(
        param_dtype="float32", compute_dtype="float32"
    )
    it = data_stream(VOCAB, 8, 64, seed=0)
    params, _, _ = train_loop(
        cfg, TrainConfig(lr=1.5e-3, warmup_steps=5, decay_steps=80), it, 80, log_every=40
    )

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, VOCAB, size=24).tolist(), max_new_tokens=16)
            for _ in range(8)]

    for impl in ("einsum", "dense"):
        eng = Engine(cfg.replace(moe_impl=impl), params,
                     EngineConfig(max_batch=8, max_prefill=32, max_decode=16))
        eng.generate(reqs[:1])  # compile
        t0 = time.time()
        out = eng.generate(reqs)
        dt = time.time() - t0
        n = sum(len(r.tokens) for r in out)
        print(f"moe_impl={impl:7s}: {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")
    print("sample generation:", out[0].tokens)
    print("(dense mapping-table dispatch is the paper's §5.4 optimization; "
          "einsum is the baseline it replaces)")


if __name__ == "__main__":
    main()
