"""Mixture-of-Students (paper §4.2) at CPU scale: distill a PR-MoE teacher
into a depth-reduced PR-MoE student with STAGED knowledge distillation, and
compare against (a) the student trained from scratch and (b) full-KD —
reproducing the Table 5 ordering: staged-KD > from-scratch ≥ full-KD on the
final loss, with the student at ~12.5% fewer layers.

  PYTHONPATH=src python examples/distill_mos.py [--steps 240]
"""
import argparse

import jax

from repro.configs.base import count_params
from repro.core.prmoe import nlg_moe
from repro.data.pipeline import data_stream
from repro.models.model import init_params
from repro.training.distill import KDConfig, make_distill_step, make_student_config
from repro.training.optimizer import init_adamw
from repro.training.trainer import TrainConfig, train_loop

VOCAB = 512


def distill(student_cfg, teacher_cfg, teacher_params, kdc, steps, seed=1):
    params = init_params(student_cfg, jax.random.PRNGKey(seed))
    opt = init_adamw(params)
    tc = TrainConfig(lr=1.5e-3, warmup_steps=steps // 20, decay_steps=steps)
    step = jax.jit(make_distill_step(student_cfg, teacher_cfg, tc, kdc))
    it = data_stream(VOCAB, 8, 64, seed=seed)
    last = None
    for i in range(steps):
        toks, labels = next(it)
        params, opt, m = step(params, opt, teacher_params, toks, labels)
        if i % (steps // 6) == 0 or i == steps - 1:
            print(f"  step {i:4d} ce {float(m['ce']):.4f} kl {float(m['kl']):.4f} "
                  f"alpha {float(m['kd_alpha']):.1f}")
            last = float(m["ce"])
    return params, last


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=240)
    args = ap.parse_args()
    steps = args.steps

    f32 = dict(param_dtype="float32", compute_dtype="float32")
    teacher_cfg = nlg_moe("teacher-prmoe", 8, 128, 4, (4, 8), residual=True, vocab=VOCAB).replace(**f32)
    student_cfg = make_student_config(teacher_cfg, depth_ratio=0.75)
    print(f"teacher: {teacher_cfg.num_layers} layers, {count_params(teacher_cfg)/1e6:.1f}M params")
    print(f"student: {student_cfg.num_layers} layers, {count_params(student_cfg)/1e6:.1f}M params "
          f"({count_params(teacher_cfg)/count_params(student_cfg):.2f}x smaller)")

    print("\n[1/4] pretraining the PR-MoE teacher...")
    it = data_stream(VOCAB, 8, 64, seed=0)
    teacher_params, _, th = train_loop(
        teacher_cfg, TrainConfig(lr=1.5e-3, warmup_steps=steps // 20, decay_steps=steps),
        it, steps, log_every=steps // 4,
    )
    teacher_ce = th[-1]["ce"]

    print("\n[2/4] student from scratch (no KD)...")
    _, ce_scratch = distill(student_cfg, teacher_cfg, teacher_params,
                            KDConfig(alpha=0.0), steps)
    print("\n[3/4] student with FULL KD (paper: hurts late in training)...")
    _, ce_full = distill(student_cfg, teacher_cfg, teacher_params,
                         KDConfig(alpha=1.0, kd_stop_step=-1), steps)
    print(f"\n[4/4] student with STAGED KD (stop at {steps//2}, §4.2.1)...")
    _, ce_staged = distill(student_cfg, teacher_cfg, teacher_params,
                           KDConfig(alpha=1.0, kd_stop_step=steps // 2), steps)

    print("\n--- Mixture-of-Students summary (final CE) ---")
    print(f"teacher ({teacher_cfg.num_layers}L)        : {teacher_ce:.4f}")
    print(f"student from scratch   : {ce_scratch:.4f}")
    print(f"student full KD        : {ce_full:.4f}")
    print(f"student STAGED KD (MoS): {ce_staged:.4f}   <- paper's method")


if __name__ == "__main__":
    main()
