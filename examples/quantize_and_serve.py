"""MoQ quickstart (paper §4): train a small MoE briefly, quantize its expert
weights to int8, round-trip the quantized params through a checkpoint, and
serve fp vs quantized side by side — then add the int8 KV cache on top so
int8 experts AND an int8 cache serve together from one engine (the two §5
memory-bound levers composed).

  PYTHONPATH=src python examples/quantize_and_serve.py

Expected: expert bytes shrink ~4x, KV-cache bytes ~3.7x, greedy generations
match (almost) exactly in both steps.
"""
import os
import tempfile

import numpy as np

import jax

from repro.checkpoint import ckpt
from repro.configs.base import QuantConfig
from repro.core.prmoe import nlg_moe
from repro.data.pipeline import data_stream
from repro.quant import quantize_params, quantized_leaf_paths, tree_bytes
from repro.serving.engine import Engine, EngineConfig, Request
from repro.training.trainer import TrainConfig, train_loop

VOCAB = 512


def main() -> None:
    cfg = nlg_moe("quantize-demo-moe", 4, 192, 4, 16, vocab=VOCAB).replace(
        param_dtype="float32", compute_dtype="float32"
    )
    it = data_stream(VOCAB, 8, 64, seed=0)
    params, _, _ = train_loop(
        cfg, TrainConfig(lr=1.5e-3, warmup_steps=5, decay_steps=80), it, 80, log_every=40
    )

    # --- post-training weight-only quantization of the experts ------------
    qcfg = QuantConfig(bits=8, policy="experts")
    qparams = quantize_params(params, qcfg)
    print(f"quantized leaves ({qcfg.policy}, int{qcfg.bits}):")
    for p in quantized_leaf_paths(qparams):
        print("   ", p)
    fp_b, q_b = tree_bytes(params), tree_bytes(qparams)
    ex_b = tree_bytes(qparams, only_quantized=True)
    print(f"param bytes: fp32={fp_b/1e6:.2f}MB -> quantized={q_b/1e6:.2f}MB "
          f"(expert share now {ex_b/1e6:.2f}MB; model {fp_b/q_b:.2f}x smaller)")

    # --- checkpoint round-trip (QuantizedArray leaves in the manifest) ----
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "q8")
        ckpt.save(path, qparams, step=80)
        qparams, step = ckpt.load(path, qparams)
        print(f"checkpoint round-trip ok (step={step})")

    # --- serve both and compare greedy outputs ----------------------------
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, VOCAB, size=24).tolist(), max_new_tokens=16)
            for _ in range(8)]
    ec = EngineConfig(max_batch=8, max_prefill=32, max_decode=16)
    fp_out = Engine(cfg, params, ec).generate(reqs)
    q_out = Engine(cfg, qparams, ec).generate(reqs)

    tot = match = 0
    for a, b in zip(fp_out, q_out):
        tot += len(a.tokens)
        match += sum(int(x == y) for x, y in zip(a.tokens, b.tokens))
    print(f"greedy token agreement fp vs int8 experts: {match}/{tot} "
          f"({100.0 * match / tot:.1f}%)")
    print("fp   sample:", fp_out[0].tokens)
    print("int8 sample:", q_out[0].tokens)

    # --- compose the int8 KV cache on top (quant/kv.py) -------------------
    from repro.quant import kv_cache_bytes

    ec_kv = EngineConfig(max_batch=8, max_prefill=32, max_decode=16, kv_cache_bits=8)
    eng_kv = Engine(cfg, qparams, ec_kv)
    kv_out = eng_kv.generate(reqs)
    fp_cache_b = kv_cache_bytes(Engine(cfg, qparams, ec)._make_caches(8))
    q_cache_b = kv_cache_bytes(eng_kv._make_caches(8))
    print(f"KV cache bytes: fp32={fp_cache_b/1e6:.2f}MB -> int8={q_cache_b/1e6:.2f}MB "
          f"({fp_cache_b/q_cache_b:.2f}x fewer decode cache bytes)")
    tot = match = 0
    for a, b in zip(fp_out, kv_out):
        tot += len(a.tokens)
        match += sum(int(x == y) for x, y in zip(a.tokens, b.tokens))
    print(f"greedy token agreement fp vs int8 experts + int8 KV: {match}/{tot} "
          f"({100.0 * match / tot:.1f}%)")
    print("int8+kv sample:", kv_out[0].tokens)

    # --- and the paged KV block pool on top (serving/kv_pool.py) ----------
    # int8 experts + int8 pages + fragmentation-free packing: the pool is
    # provisioned for the actual traffic (~40-token sequences), half the
    # contiguous worst-case reservation, yet serves the same requests with
    # identical greedy tokens.
    from repro.models.model import init_caches, init_paged_caches
    from repro.serving.continuous import ContinuousEngine

    slots, capacity, ps = 4, 64, 8
    n_pages = slots * 5  # ~40 tokens per live sequence, vs capacity 64
    paged_eng = ContinuousEngine(cfg, qparams, slots=slots, capacity=capacity,
                                 kv_cache_bits=8, paged=True, page_size=ps,
                                 n_pages=n_pages)
    ids = [paged_eng.submit(r) for r in reqs]
    paged_done = paged_eng.run_until_done()
    contig_b = kv_cache_bytes(jax.eval_shape(
        lambda: init_caches(cfg, slots, capacity, kv_bits=8)))
    pool_b = kv_cache_bytes(jax.eval_shape(
        lambda: init_paged_caches(cfg, slots, capacity, n_pages=n_pages,
                                  page_size=ps, kv_bits=8)))
    print(f"paged pool: {n_pages} pages x {ps} tokens = {pool_b/1e6:.2f}MB vs "
          f"contiguous {slots}x{capacity} = {contig_b/1e6:.2f}MB "
          f"({contig_b/pool_b:.2f}x fewer bytes for the same traffic)")
    tot = match = 0
    for a, rid in zip(kv_out, ids):
        b = paged_done[rid]
        tot += len(a.tokens)
        match += sum(int(x == y) for x, y in zip(a.tokens, b.tokens))
    print(f"greedy token agreement contiguous vs paged (int8 experts + int8 KV): "
          f"{match}/{tot} ({100.0 * match / tot:.1f}%)")
    print(f"paged sample: {paged_done[ids[0]].tokens} "
          f"(preemptions={paged_eng.preemptions}, "
          f"peak_occupancy={max(m['page_occupancy'] for m in paged_eng.metrics_log):.2f})")


if __name__ == "__main__":
    main()
