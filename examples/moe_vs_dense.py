"""Reproduces the paper's §3 result qualitatively at CPU scale: an MoE model
with the SAME per-token compute as its dense base reaches a *lower* loss in
the same number of steps (the "same cost, better quality" direction of the
5x claim), and a dense model with ~4-5x the compute is needed to match it.

This is the end-to-end training driver deliverable: ~100M-class models,
a few hundred steps, real optimizer/data/trainer stack.

  PYTHONPATH=src python examples/moe_vs_dense.py [--steps 300] [--scale full]
"""
import argparse
import json

from repro.configs.base import count_active_params, count_params
from repro.core.prmoe import nlg_dense, nlg_moe
from repro.data.pipeline import data_stream
from repro.training.trainer import TrainConfig, train_loop

VOCAB = 2048


def run(cfg, steps: int, seed: int = 0, lr: float = 1.5e-3):
    it = data_stream(VOCAB, global_batch=8, seq_len=128, seed=seed)
    _, _, hist = train_loop(
        cfg, TrainConfig(lr=lr, warmup_steps=max(steps // 20, 1), decay_steps=steps),
        it, steps, log_every=max(steps // 10, 1),
    )
    return hist


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--scale", choices=["tiny", "full"], default="tiny",
                    help="tiny: CPU-minutes scale; full: ~100M-param models")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.scale == "full":
        base_layers, d, heads, experts = 12, 512, 8, 16
        dense_equiv_layers, dense_equiv_d = 12, 1024
    else:
        base_layers, d, heads, experts = 4, 128, 4, 8
        dense_equiv_layers, dense_equiv_d = 6, 256

    f32 = dict(param_dtype="float32", compute_dtype="float32")
    models = {
        # same compute per token as the MoE below (its dense base):
        "dense_base": nlg_dense("dense-base", base_layers, d, heads, vocab=VOCAB).replace(**f32),
        # MoE at the base's compute cost (top-1, every other layer):
        "moe": nlg_moe("moe", base_layers, d, heads, experts, vocab=VOCAB).replace(**f32),
        # PR-MoE: pyramid + residual, fewer params, same quality target:
        "pr_moe": nlg_moe("pr-moe", base_layers, d, heads, (experts // 2, experts),
                          residual=True, vocab=VOCAB).replace(**f32),
        # a bigger dense model (the "quality equivalent" costing ~4x more):
        "dense_equiv": nlg_dense("dense-equiv", dense_equiv_layers, dense_equiv_d,
                                 heads * 2, vocab=VOCAB).replace(**f32),
    }

    results = {}
    for name, cfg in models.items():
        print(f"\n=== {name}: {count_params(cfg)/1e6:.1f}M params, "
              f"{count_active_params(cfg)/1e6:.1f}M active/token ===")
        hist = run(cfg, args.steps)
        results[name] = hist
        print(f"{name}: final loss {hist[-1]['loss']:.4f}")

    print("\n--- summary (final CE loss; lower is better) ---")
    for name, hist in results.items():
        cfg = models[name]
        print(f"{name:12s} loss={hist[-1]['loss']:.4f} "
              f"params={count_params(cfg)/1e6:7.1f}M active={count_active_params(cfg)/1e6:6.1f}M")
    moe_final = results["moe"][-1]["loss"]
    base_final = results["dense_base"][-1]["loss"]
    print(f"\nMoE vs same-compute dense: {base_final - moe_final:+.4f} "
          f"(positive = MoE better at equal training cost — paper §3.3)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
