"""Prefix-sharing / copy-on-write paged serving demo.

Serving traffic that repeats a system prompt (here: every request opens
with the same 32-token preamble) stores the preamble's KV pages ONCE: each
admission looks the preamble up in the radix prefix index, points its block
table at the existing physical pages (refcounted), and prefills only its
unique tail.  Parallel sampling goes further — n samples of one prompt
share ALL its pages and diverge lazily, each copy-on-writing the boundary
page right before its first divergent append.

Greedy outputs are token-identical to the unshared paged engine (the decode
read path never changes — tables just point at shared pages); the win is
physical pages, i.e. concurrent sequences per GiB of cache.

  PYTHONPATH=src python examples/prefix_sharing.py
"""
import numpy as np

import jax

from repro.core.prmoe import nlg_moe
from repro.models.model import init_params
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request

VOCAB = 512


def main() -> None:
    cfg = nlg_moe("prefix-demo-moe", 4, 192, 4, 16, vocab=VOCAB).replace(
        param_dtype="float32", compute_dtype="float32"
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(1, VOCAB, size=32).tolist()  # 2 pages of 16
    reqs = [Request(prompt=system_prompt + rng.integers(1, VOCAB, size=6).tolist(),
                    max_new_tokens=10)
            for _ in range(6)]

    outs = {}
    for sharing in (False, True):
        eng = ContinuousEngine(cfg, params, slots=6, capacity=96, paged=True,
                               page_size=16, n_pages=30, prefix_sharing=sharing)
        ids = [eng.submit(r) for r in reqs]
        done = eng.run_until_done()
        outs[sharing] = [done[i].tokens for i in ids]
        peak_used = eng.n_pages - min(m["free_pages"] for m in eng.metrics_log)
        tag = "prefix-shared" if sharing else "paged (no sharing)"
        extra = (f", hits={eng.prefix_hits}, shared_tokens={eng.prefix_hit_tokens}, "
                 f"cow_copies={eng.cow_copies}") if sharing else ""
        print(f"{tag:>20}: peak live pages {peak_used}/{eng.n_pages}{extra}")
    assert outs[False] == outs[True], "sharing must not change greedy outputs"
    print("greedy outputs token-identical with and without sharing")

    # parallel sampling: 4 greedy samples off one prompt = one set of pages
    eng = ContinuousEngine(cfg, params, slots=4, capacity=96, paged=True,
                           page_size=16, n_pages=24, prefix_sharing=True)
    rids = eng.submit_n(reqs[0], 4)
    print(f"n=4 samples admitted on {eng.pool.used_count} physical pages "
          f"(independent admissions would take {4 * eng.pool.pages_for(38)})")
    done = eng.run_until_done()
    assert all(done[r].tokens == done[rids[0]].tokens for r in rids)  # greedy
    print(f"samples decoded to completion, cow_copies={eng.cow_copies}, "
          f"pool drained={eng.pool.free_count == eng.n_pages}")


if __name__ == "__main__":
    main()
