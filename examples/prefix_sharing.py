"""Prefix-sharing / copy-on-write paged serving demo.

Serving traffic that repeats a system prompt (here: every request opens
with the same 32-token preamble) stores the preamble's KV pages ONCE: each
admission looks the preamble up in the radix prefix index, points its block
table at the existing physical pages (refcounted), and handles only its
unique tail.  Parallel sampling goes further — n samples of one prompt
share ALL its pages and diverge lazily, each copy-on-writing the boundary
page right before its first divergent append.

Two flavors of the win are shown:

  * **pages** (exact-parity mode): with ``prefill_mode="scatter"`` the
    shared prefix is recomputed (its page writes trash-routed), so greedy
    outputs are bit-for-bit identical to the unshared engine while the
    preamble's pages are stored once.
  * **pages + prefill FLOPs** (default chunked mode): the admission starts
    its first chunk AFTER the shared pages and reads them in place — the
    preamble is never recomputed.  The reused K/V is byte-identical, but
    attention now sums it in block-table order instead of in-flight order,
    so greedy outputs match the recompute path only up to floating-point
    reduction order (tests/test_chunked.py pins the strict oracle parity).

  PYTHONPATH=src python examples/prefix_sharing.py
"""
import numpy as np

import jax

from repro.core.prmoe import nlg_moe
from repro.models.model import init_params
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request

VOCAB = 512


def main() -> None:
    cfg = nlg_moe("prefix-demo-moe", 4, 192, 4, 16, vocab=VOCAB).replace(
        param_dtype="float32", compute_dtype="float32"
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(1, VOCAB, size=32).tolist()  # 2 pages of 16
    reqs = [Request(prompt=system_prompt + rng.integers(1, VOCAB, size=6).tolist(),
                    max_new_tokens=10)
            for _ in range(6)]

    # -- pages win, exact parity (scatter mode recomputes the prefix) ------
    outs = {}
    for sharing in (False, True):
        eng = ContinuousEngine(cfg, params, slots=6, capacity=96, paged=True,
                               page_size=16, n_pages=30, prefix_sharing=sharing,
                               prefill_mode="scatter")
        ids = [eng.submit(r) for r in reqs]
        done = eng.run_until_done()
        outs[sharing] = [done[i].tokens for i in ids]
        peak_used = eng.n_pages - min(m["free_pages"] for m in eng.metrics_log)
        tag = "prefix-shared" if sharing else "paged (no sharing)"
        extra = (f", hits={eng.prefix_hits}, shared_tokens={eng.prefix_hit_tokens}, "
                 f"cow_copies={eng.cow_copies}") if sharing else ""
        print(f"{tag:>20}: peak live pages {peak_used}/{eng.n_pages}{extra}")
    assert outs[False] == outs[True], "sharing must not change greedy outputs"
    print("greedy outputs token-identical with and without sharing (scatter oracle)")

    # -- FLOPs win on top (default chunked mode reads the prefix in place) -
    toks = {}
    for sharing in (False, True):
        eng = ContinuousEngine(cfg, params, slots=6, capacity=96, paged=True,
                               page_size=16, n_pages=30, prefix_sharing=sharing)
        first = eng.submit(reqs[0])
        while any(s.active and s.prefilling for s in eng.slots):
            eng.step()  # let the preamble's pages land (and be indexed)
        for r in reqs[1:]:
            eng.submit(r)
        eng.run_until_done()
        toks[sharing] = (eng.prefill_tokens_total, eng.prefill_tokens_skipped)
    (total_ns, _), (total_s, skipped) = toks[False], toks[True]
    print(f"chunked prefill: {total_ns} prompt tokens computed without sharing, "
          f"{total_s} with ({skipped} skipped = {skipped / total_ns:.0%} of "
          f"prefill FLOPs saved)")

    # parallel sampling: 4 greedy samples off one prompt = one set of pages
    eng = ContinuousEngine(cfg, params, slots=4, capacity=96, paged=True,
                           page_size=16, n_pages=24, prefix_sharing=True)
    rids = eng.submit_n(reqs[0], 4)
    print(f"n=4 samples admitted on {eng.pool.used_count} physical pages "
          f"(independent admissions would take {4 * eng.pool.pages_for(38)})")
    done = eng.run_until_done()
    assert all(done[r].tokens == done[rids[0]].tokens for r in rids)  # greedy
    print(f"samples decoded to completion, cow_copies={eng.cow_copies}, "
          f"pool drained={eng.pool.free_count == eng.n_pages}")


if __name__ == "__main__":
    main()
