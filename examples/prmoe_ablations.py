"""PR-MoE design ablations — reproduces the paper's §4.1.1 *observations*
(Figure 2) and §4.1.4 architecture ablation (Figure 4) at CPU scale:

  Phenomenon-I  (Fig 2 left):  First-Half-MoE vs Second-Half-MoE —
                deeper MoE layers help more.
  Phenomenon-II (Fig 2 right): Top2-MoE vs Residual-MoE — a fixed dense
                branch + top-1 expert matches top-2 at top-1 comms.
  Figure 4:     standard MoE-32 vs MoE-128 vs Pyramid vs Residual vs PR-MoE.

  PYTHONPATH=src python examples/prmoe_ablations.py [--steps 200]
"""
import argparse
import json

from repro.configs.base import AttnSpec, FFNSpec, LayerSpec, ModelConfig, Segment
from repro.configs.registry import all_configs  # noqa: F401 (registry warm)
from repro.data.pipeline import data_stream
from repro.training.trainer import TrainConfig, train_loop

VOCAB = 512
D, HEADS, LAYERS = 128, 4, 8


def _attn():
    return AttnSpec(kind="global")


def _dense():
    return LayerSpec(_attn(), FFNSpec(kind="dense", d_ff=4 * D, act="gelu"))


def _moe(experts, top_k=1, residual=False):
    return LayerSpec(
        _attn(),
        FFNSpec(kind="moe", d_ff=4 * D, act="gelu", num_experts=experts, top_k=top_k,
                capacity_factor=2.0, residual=residual),
    )


def model(name, layers) -> ModelConfig:
    segs = tuple(Segment((l,), 1) for l in layers)
    return ModelConfig(
        name=name, family="moe", source="[ablation]", d_model=D, num_heads=HEADS,
        num_kv_heads=HEADS, head_dim=D // HEADS, vocab_size=VOCAB, segments=segs,
        tie_embeddings=True, param_dtype="float32", compute_dtype="float32",
        max_seq_len=4096,
    )


def build_variants():
    half = LAYERS // 2
    interleave = lambda mk: [(_dense() if i % 2 == 0 else mk()) for i in range(LAYERS)]
    v = {
        # Phenomenon-I: where should the MoE layers live?
        "first_half_moe": model("first-half", [_moe(8) if i < half else _dense() for i in range(LAYERS)]),
        "second_half_moe": model("second-half", [_dense() if i < half else _moe(8) for i in range(LAYERS)]),
        # Phenomenon-II: capacity via top-2 vs a residual dense branch
        "top2_moe": model("top2", interleave(lambda: _moe(8, top_k=2))),
        "residual_moe": model("residual", interleave(lambda: _moe(8, top_k=1, residual=True))),
        # Figure 4 sweep
        "moe_4": model("moe4", interleave(lambda: _moe(4))),
        "moe_16": model("moe16", interleave(lambda: _moe(16))),
        "pyramid_4_8": model("pyr", interleave(lambda: _moe(4))[:-2] + [_dense(), _moe(8)]),
        "pr_moe_4_8": model("pr", [
            (_dense() if i % 2 == 0 else _moe(4 if i < LAYERS - 2 else 8, residual=True))
            for i in range(LAYERS)
        ]),
    }
    return v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = {}
    for name, cfg in build_variants().items():
        from repro.configs.base import count_params

        it = data_stream(VOCAB, 8, 64, seed=0)
        _, _, hist = train_loop(
            cfg, TrainConfig(lr=1.5e-3, warmup_steps=args.steps // 20, decay_steps=args.steps),
            it, args.steps, log_every=args.steps, log_fn=lambda *_: None,
        )
        results[name] = {"final_loss": hist[-1]["loss"], "params_m": count_params(cfg) / 1e6}
        print(f"{name:18s} loss={hist[-1]['loss']:.4f} params={count_params(cfg)/1e6:6.1f}M")

    print("\n--- paper-claim checks ---")
    print(f"Phenomenon-I  (expect second-half < first-half): "
          f"{results['second_half_moe']['final_loss']:.4f} vs {results['first_half_moe']['final_loss']:.4f}")
    print(f"Phenomenon-II (expect residual ~= top2):         "
          f"{results['residual_moe']['final_loss']:.4f} vs {results['top2_moe']['final_loss']:.4f}")
    print(f"Figure 4      (expect PR-MoE ~ MoE-16 quality with fewer params): "
          f"pr={results['pr_moe_4_8']['final_loss']:.4f} ({results['pr_moe_4_8']['params_m']:.0f}M) "
          f"moe16={results['moe_16']['final_loss']:.4f} ({results['moe_16']['params_m']:.0f}M)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
