"""Quickstart: build a small DeepSpeed-MoE-style NLG model (GPT base + top-1
MoE on every other FFN, Residual-MoE branch), train it for a few steps on
synthetic data, then serve a couple of batched requests.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.prmoe import nlg_moe
from repro.data.pipeline import data_stream
from repro.serving.engine import Engine, EngineConfig, Request
from repro.training.trainer import TrainConfig, train_loop

VOCAB = 512


def main() -> None:
    # a micro "350M+MoE" analogue: 4 layers, 8 experts, residual branch
    cfg = nlg_moe("quickstart-moe", 4, 128, 4, 8, residual=True, vocab=VOCAB).replace(
        param_dtype="float32", compute_dtype="float32"
    )
    print(f"model: {cfg.name}, layers={cfg.num_layers}, "
          f"experts per MoE layer={[ls.ffn.num_experts for ls in cfg.layer_specs() if ls.ffn.kind=='moe']}")

    it = data_stream(VOCAB, global_batch=8, seq_len=64, seed=0)
    params, _, history = train_loop(
        cfg, TrainConfig(lr=1e-3, warmup_steps=5, decay_steps=60), it, num_steps=60, log_every=15
    )
    assert history[-1]["loss"] < history[0]["loss"]

    eng = Engine(cfg, params, EngineConfig(max_batch=4, max_prefill=32, max_decode=12))
    out = eng.generate([Request(prompt=[1, 2, 3, 4, 5], max_new_tokens=12),
                        Request(prompt=[7, 8, 9], max_new_tokens=12)])
    for i, r in enumerate(out):
        print(f"request {i}: generated {r.tokens}")


if __name__ == "__main__":
    main()
