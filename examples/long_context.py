"""Long-context decode demo (the long_500k shape at CPU scale): decode far
past the training window with BOUNDED memory on the sub-quadratic archs —
recurrentgemma (RG-LRU state + window-ring attention) and mamba2 (pure SSM
state) — and verify the window/state caches stay exact by comparing against
a teacher-forced forward over the full sequence.

  PYTHONPATH=src python examples/long_context.py [--context 2048]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import all_configs, make_reduced
from repro.models.model import decode_step, forward, init_caches, init_params, prefill


def run_arch(name: str, context: int, n_decode: int = 16) -> None:
    cfg = make_reduced(all_configs()[name])
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, context + n_decode), 0, cfg.vocab_size)

    # window/state caches: capacity far below the context for attention archs
    window_caps = [
        min(ls.mixer.window, context)
        for ls in cfg.layer_specs()
        if getattr(ls.mixer, "kind", "") == "local" and getattr(ls.mixer, "window", 0)
    ]
    caches = init_caches(cfg, 1, capacity=context + n_decode)
    t0 = time.time()
    _, caches = jax.jit(lambda p, t, c: prefill(cfg, p, t, c))(params, toks[:, :context], caches)
    t_prefill = time.time() - t0

    # memory held by recurrent/window state (the long-context story):
    state_bytes = sum(
        np.prod(l.shape) * l.dtype.itemsize
        for l in jax.tree.leaves(caches)
    )

    dec = jax.jit(lambda p, t, i, c: decode_step(cfg, p, t, i, c))
    t0 = time.time()
    lg = None
    for i in range(n_decode):
        lg, caches = dec(params, toks[:, context + i : context + i + 1],
                         jnp.asarray(context + i, jnp.int32), caches)
    jax.block_until_ready(lg)
    t_decode = (time.time() - t0) / n_decode

    # exactness vs teacher-forced full forward at the final position
    full_logits, _ = forward(cfg, params, toks)
    err = float(jnp.max(jnp.abs(lg - full_logits[:, context + n_decode - 1])))
    print(
        f"{name:22s} context={context} decode@{context+n_decode}: "
        f"cache={state_bytes/1e6:.1f}MB windows={window_caps or '—'} "
        f"prefill {t_prefill:.2f}s decode {t_decode*1e3:.0f}ms/tok  max|Δlogit|={err:.2e}"
    )
    assert err < 5e-3, f"{name}: long-context decode diverged"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--context", type=int, default=2048)
    args = ap.parse_args()
    for name in ("recurrentgemma-2b", "mamba2-370m", "gemma3-27b"):
        run_arch(name, args.context)
    print("\nall sub-quadratic archs decode exactly at long context "
          "(the production long_500k shape runs these same paths on TPU).")


if __name__ == "__main__":
    main()
