"""Compile-shape contract checker.

A serving engine is only viable under XLA if the set of (shape, dtype)
signatures its jitted functions are called with is CLOSED and SMALL: the
decode tick must have exactly one signature (never retraces in steady
state), and admission prefill may compile once per distinct chunk length
drawn from a bounded, page-aligned family.  PR 6's retrace watchdog observes
violations at runtime — after the compile has already burned a tick.  This
pass proves the property ahead of time from the engine's *declared*
contract (``ContinuousEngine.shape_contract()`` / ``Engine.shape_contract()``,
derived from the same config values that size the real buffers):

  1. **trace check** — every declared signature abstract-traces
     (``jax.eval_shape``; no compile, no device work), and for donating
     functions every donated input leaf has a shape/dtype-matching output
     leaf (the necessary condition for XLA to honor the donation — the
     authoritative per-leaf alias audit is ``analysis.donation``).
  2. **closure check** — signatures reachable from scheduler states
     (chunk boundaries +-1 around every prompt length, preemption replays
     that grow the context by generated tokens, fork admissions) stay inside
     the declared family, and every non-final chunk length is page-aligned
     (unaligned chunks are exactly the compile-churn bug the chunked-prefill
     scheduler defers sub-page budgets to avoid).
  3. **compile-count prediction** — a host-side replay of the scheduler's
     admission arithmetic (same chunk splitting as
     ``ContinuousEngine._advance_prefill``) yields the exact per-function
     compile counts a workload will pay.  ``tests/test_analysis.py`` and
     ``benchmarks/run.py obs`` hold this prediction equal to the retrace
     watchdog's observed ``per_fn`` counts — the static and runtime halves
     of the same instrument agreeing on the number.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax

from repro.analysis.findings import Report


@dataclass
class ContractEntry:
    """One jitted function's declared signature family.

    ``points`` is the closed domain (tuples of family parameters — e.g.
    ``(chunk_len,)``); ``sample`` the representative points that get
    abstract-traced (boundaries, page-multiples +-1).  ``primary`` marks
    steady-state functions (the watchdog's non-aux class): their family must
    be a singleton — a primary function with more than one admissible
    signature is an open compile set by construction."""

    name: str
    fn: Callable
    make: Callable[..., tuple]  # (*point) -> positional args (SDS pytrees)
    points: Tuple[tuple, ...]
    sample: Tuple[tuple, ...]
    primary: bool = False
    donate_argnums: Tuple[int, ...] = ()


def _aval_multiset(tree) -> Dict[Tuple, int]:
    out: Dict[Tuple, int] = {}
    for leaf in jax.tree.leaves(tree):
        key = (tuple(leaf.shape), str(leaf.dtype))
        out[key] = out.get(key, 0) + 1
    return out


def check_contract(entries: Sequence[ContractEntry],
                   report: Optional[Report] = None) -> Report:
    """Pass 1: primary-singleton + abstract-trace + donation feasibility."""
    report = report if report is not None else Report()
    total_sigs = 0
    for e in entries:
        total_sigs += len(e.points)
        if e.primary and len(e.points) != 1:
            report.add(
                "contract-open", "error", e.name,
                f"steady-state function declares {len(e.points)} admissible "
                "signatures — the fixed-shape tick contract requires exactly "
                "one (every extra signature is a steady-state retrace)",
            )
        for pt in e.sample:
            args = e.make(*pt)
            try:
                out = jax.eval_shape(e.fn, *args)
            except Exception as exc:
                report.add(
                    "contract-trace-failed", "error", f"{e.name}{pt}",
                    f"declared signature does not trace: {exc!r}".replace("\n", " ")[:300],
                )
                continue
            for argnum in e.donate_argnums:
                donated = _aval_multiset(args[argnum])
                outputs = _aval_multiset(out)
                short = {k: n for k, n in donated.items()
                         if outputs.get(k, 0) < n}
                if short:
                    k, n = next(iter(short.items()))
                    report.add(
                        "contract-donation-infeasible", "error", f"{e.name}{pt}",
                        f"donated arg {argnum} has {n} leaf(s) of aval {k} but "
                        f"only {outputs.get(k, 0)} matching output leaf(s) — "
                        "XLA cannot alias this donation",
                    )
    report.metrics["contract.functions"] = len(entries)
    report.metrics["contract.declared_signatures"] = total_sigs
    return report


# ---------------------------------------------------------------------------
# Chunk arithmetic (mirrors ContinuousEngine._advance_prefill)
# ---------------------------------------------------------------------------


def chunk_lengths(ctx_len: int, start: int, budget: int, page_size: int) -> List[int]:
    """Chunk lengths one uninterrupted application of ``budget`` tokens emits
    for a context of ``ctx_len`` beginning at page-aligned ``start`` — the
    same alignment rules as the scheduler: a non-final chunk ends on a page
    boundary, and leftover budget smaller than a page defers."""
    out: List[int] = []
    pos = start
    left = budget
    while pos < ctx_len and left > 0:
        end = min(ctx_len, pos + left)
        if end < ctx_len:
            end -= end % page_size
            if end <= pos:
                break  # sub-page leftover defers to the next tick
        out.append(end - pos)
        left -= end - pos
        pos = end
    return out


@dataclass
class Workload:
    """The scenario the prediction replays: ``prompt_lens`` submitted
    upfront in order, each decoding ``max_new`` tokens, the engine stepped
    ``ticks`` times.  ``forks`` > 0 marks the first request as a
    ``submit_n(req, forks + 1)`` parallel-sample base."""

    prompt_lens: Sequence[int]
    max_new: int
    ticks: int
    forks: int = 0


def reachable_chunk_lengths(capacity: int, page_size: int, prefill_chunk: int,
                            workload: Workload, *, perturb: int = 1,
                            preempt_generated: Iterable[int] = (0, 1)) -> set:
    """Every chunk length any reachable scheduler state can emit: prompt
    lengths +-``perturb``, preemption replays (context grows by generated
    tokens), all page-aligned resume starts, all partial tick budgets."""
    keep = capacity - max(1, min(workload.max_new, capacity - 1))
    ctxs = set()
    for p in workload.prompt_lens:
        for d in range(-perturb, perturb + 1):
            for g in list(preempt_generated) + [workload.max_new]:
                ctxs.add(min(max(1, p + d) + g, max(keep, 1), capacity))
    out = set()
    for ctx in ctxs:
        for start in range(0, ctx, page_size):
            for budget in (page_size, prefill_chunk, max(1, prefill_chunk // 2)):
                out.update(chunk_lengths(ctx, start, budget, page_size))
    return out


def check_closure(entries: Sequence[ContractEntry], *, capacity: int,
                  page_size: int, prefill_chunk: int, workload: Workload,
                  report: Optional[Report] = None) -> Report:
    """Pass 2: reachable signatures stay inside the declared family."""
    report = report if report is not None else Report()
    reach = reachable_chunk_lengths(capacity, page_size, prefill_chunk, workload)
    declared = {e.name: {pt[0] for pt in e.points} for e in entries
                if e.name in ("prefill_chunk_first", "prefill_chunk_cont")}
    for name, domain in declared.items():
        escaped = sorted(reach - domain)
        if escaped:
            report.add(
                "contract-escape", "error", name,
                f"reachable chunk lengths {escaped[:8]} are outside the "
                f"declared family (|domain|={len(domain)}) — each escape is "
                "an unplanned compilation",
            )
    bad_align = sorted(l for l in reach
                       if l > page_size and l % page_size and l != max(reach))
    # non-final chunks must be page multiples; the only unaligned length a
    # context can emit is its own final remainder, which is <= prefill_chunk
    over = sorted(l for l in reach if l > prefill_chunk or l <= 0)
    if over:
        report.add("contract-escape", "error", "chunk-budget",
                   f"reachable chunk lengths {over[:8]} exceed the per-tick "
                   f"budget {prefill_chunk}")
    report.metrics["contract.reachable_chunk_lengths"] = len(reach)
    report.metrics["contract.unaligned_reachable"] = len(bad_align)
    return report


# ---------------------------------------------------------------------------
# Compile-count prediction (host-side scheduler replay)
# ---------------------------------------------------------------------------


def predict_compiles(*, slots: int, capacity: int, page_size: int,
                     prefill_chunk: int, workload: Workload,
                     prefill_mode: str = "chunked",
                     skip_shared_compute: bool = True,
                     spec: Optional[dict] = None) -> Dict[str, int]:
    """Per-function compile counts the workload will pay, by replaying the
    scheduler's admission/decode arithmetic host-side (no tracing, no
    device).  Keys match the engine's jit registry / the retrace watchdog's
    ``per_fn`` snapshot; ``tests/test_analysis.py`` and the obs benchmark
    assert exact agreement with the observed counts.

    Scope (documented, asserted by the callers): requests submitted upfront,
    pool provisioned so the replayed workload never preempts, no prefix
    overlap between distinct prompts.  Forks model ``submit_n``: the base
    admits normally, each fork shares its pages (one ``copy_slot``
    signature) and CoWs its boundary page at the first divergent append
    (one ``copy_page`` signature).

    ``prefill_mode`` selects the admission state machine being replayed and
    with it the OUTPUT KEY SET (keys mirror the engine's jit registry for
    that mode): "chunked" predicts per-chunk-length first/cont compiles;
    "batched" replaces them with a single ``prefill_chunk_batched`` key that
    is 1 iff any chunk ran — the batched entry's shapes are fixed at
    ``[slots, prefill_chunk]``, so it compiles at most once no matter the
    workload (admission itself launches no compute; every mid-prefill slot
    advances one chunk per tick); "scatter" predicts one ``prefill`` compile
    per distinct context length.

    ``spec`` (a dict, ``{"commit_pass": bool}``) switches the decode side to
    draft-then-verify speculation: every decode tick becomes one fixed-shape
    ``verify`` + ``draft_propose`` + ``spec_reset_tail`` call (plus one
    ``spec_commit`` when the target arch carries non-paged recurrent state —
    ``commit_pass``), so each key compiles at most once; the one-token
    ``decode`` entry stays registered but is never called.  The drafter's
    lazy per-slot prefill traces one signature per distinct context length.
    Compile counts are accept-rate-INDEPENDENT (every per-tick shape is
    fixed at ``[slots, k+1]``), but tick/completion TIMING is not — callers
    asserting predicted==observed live must use a drafter whose accept
    pattern they control (the self-draft oracle: full accepts, no rollback,
    which also keeps ``reset_pages`` = "1 iff completions" exact, since
    completions are the only page-freeing events left)."""
    budget_tokens = max(1, min(workload.max_new, capacity - 1))
    keep = capacity - budget_tokens

    first_lens: set = set()
    cont_lens: set = set()
    scatter_sigs: set = set()

    class Slot:
        def __init__(self, ctx):
            self.ctx = ctx
            self.done = 0
            self.generated = 0
            self.started = False
            self.prefilling = True

    queue: List[int] = [min(max(p, 1), max(keep, 1)) for p in workload.prompt_lens]
    forks_waiting = workload.forks
    active: List[Slot] = []
    completions = 0
    fork_admitted = 0
    cow_events = 0
    decode_ran = False

    def advance(s: Slot, budget: int) -> int:
        spent = 0
        for n in chunk_lengths(s.ctx, s.done, budget, page_size):
            (cont_lens if s.started else first_lens).add(n)
            s.started = True
            s.done += n
            spent += n
        if s.done >= s.ctx:
            s.prefilling = False
            s.generated = 1  # last-chunk logits seed the first token
        return spent

    def admit(budget: Optional[int]) -> int:
        """Admit from the queue head into free slots; returns budget spent."""
        nonlocal fork_admitted
        spent = 0
        while queue and len(active) < slots:
            ctx = queue.pop(0)
            s = Slot(ctx)
            active.append(s)
            if prefill_mode == "chunked":
                spent += advance(s, prefill_chunk if budget is None
                                 else max(budget - spent, 0))
            elif prefill_mode == "batched":
                pass  # first chunk joins the NEXT tick's single batched call
            else:
                scatter_sigs.add(ctx)
                s.prefilling = False
                s.generated = 1
        # forks of the first request share it once it finishes prefilling
        nonlocal forks_waiting
        while (forks_waiting and active and not active[0].prefilling
               and len(active) < slots):
            f = Slot(active[0].ctx)
            f.prefilling = False
            f.started = True
            f.done = f.ctx
            f.generated = active[0].generated
            active.append(f)
            forks_waiting -= 1
            fork_admitted += 1
        return spent

    batched_ran = False
    admit(None)  # submit() admissions: one full chunk budget each
    for _ in range(workload.ticks):
        budget = prefill_chunk
        if prefill_mode == "batched":
            # every mid-prefill slot advances ONE chunk in the tick's single
            # batched call (per-ROW budget, fixed [slots, chunk] shapes)
            for s in [s for s in active if s.prefilling]:
                ch = chunk_lengths(s.ctx, s.done, prefill_chunk, page_size)
                if not ch:
                    continue
                batched_ran = True
                s.started = True
                s.done += ch[0]
                if s.done >= s.ctx:
                    s.prefilling = False
                    s.generated = 1
        else:
            for s in [s for s in active if s.prefilling]:
                if budget <= 0:
                    break
                budget -= advance(s, budget)
        decoders = [s for s in active if not s.prefilling]
        if decoders:
            decode_ran = True
            if fork_admitted and cow_events == 0:
                cow_events = 1  # first divergent append CoWs the shared page
            for s in decoders:
                s.generated += 1
        finished = [s for s in active if not s.prefilling
                    and s.generated >= budget_tokens]
        for s in finished:
            active.remove(s)
            completions += 1
        if finished:
            budget -= admit(budget)

    out = {
        "decode": 1 if decode_ran else 0,
        "prefill": len(scatter_sigs),
        "reset_pages": 1 if completions else 0,
        "copy_slot": 1 if fork_admitted else 0,
        "copy_page": 1 if cow_events else 0,
    }
    # key set mirrors the engine's jit registry for the mode — the observed
    # side compares EVERY registered fn's cache size, unfiltered
    if prefill_mode == "batched":
        out["prefill_chunk_batched"] = 1 if batched_ran else 0
    else:
        out["prefill_chunk_first"] = len(first_lens)
        out["prefill_chunk_cont"] = len(cont_lens)
    if spec is not None:
        # speculation replaces the one-token decode step with the fixed-shape
        # verify/propose/reset-tail triple; `decode` stays in the registry
        # (shape-contracted, never dispatched).  The drafter lazily prefills
        # each slot's context once — one compile per distinct context length
        # (clamped the same way the queue above was).
        v = out["decode"]
        out["decode"] = 0
        out["verify"] = v
        out["draft_propose"] = v
        out["spec_reset_tail"] = v
        if spec.get("commit_pass"):
            out["spec_commit"] = v
        out["draft_prefill"] = (
            len({min(max(p, 1), max(keep, 1)) for p in workload.prompt_lens})
            if v else 0)
    return out
