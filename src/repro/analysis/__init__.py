"""Trace-time static analysis for the serving stack.

Four passes, one report format (``findings.Report``), one CLI
(``launch/analyze.py`` / ``make analyze``):

  * :mod:`repro.analysis.contracts` — compile-shape contract checker
    (declared signature families abstract-trace, close under reachable
    scheduler states, and predict the exact compile count the retrace
    watchdog will observe).
  * :mod:`repro.analysis.donation`  — donation/aliasing auditor (every
    ``donate_argnums`` leaf produced an input-output alias in the lowered
    module; donated references are rebound, never read, host-side).
  * :mod:`repro.analysis.lint`      — AST host-sync / tracer-leak lint over
    ``src/repro`` with ``# analysis: allow(...)`` pragmas.
  * :mod:`repro.analysis.graph`     — jaxpr graph auditor (stray
    collectives, int8/int4->f32 dtype drift, capacity-padding dead compute).

See docs/ANALYSIS.md for rules, severities, and the contract <-> watchdog
relationship.
"""
from repro.analysis.findings import Finding, Report, SEVERITIES
from repro.analysis.contracts import (
    ContractEntry,
    Workload,
    check_contract,
    check_closure,
    chunk_lengths,
    predict_compiles,
    reachable_chunk_lengths,
)
from repro.analysis.donation import (
    audit_donation,
    audit_donated_rebinds,
    leaf_positions,
)
from repro.analysis.lint import LintConfig, lint_source, lint_tree, RULES
from repro.analysis.graph import (
    audit_collectives,
    audit_dead_compute,
    audit_dtype_drift,
    audit_graph,
    capacity_dead_compute,
    iter_eqns,
)

__all__ = [
    "Finding", "Report", "SEVERITIES",
    "ContractEntry", "Workload", "check_contract", "check_closure",
    "chunk_lengths", "predict_compiles", "reachable_chunk_lengths",
    "audit_donation", "audit_donated_rebinds", "leaf_positions",
    "LintConfig", "lint_source", "lint_tree", "RULES",
    "audit_collectives", "audit_dead_compute", "audit_dtype_drift",
    "audit_graph", "capacity_dead_compute", "iter_eqns",
]
