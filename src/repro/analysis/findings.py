"""Shared finding/report types for the trace-time static analysis suite.

Every pass (contract checker, donation auditor, host-sync lint, graph
auditor) emits :class:`Finding` records into one :class:`Report`; the
``launch/analyze.py`` CLI renders the report and turns it into an exit code.

Severity semantics (docs/ANALYSIS.md):

  * ``error``   — a violated serving-discipline invariant (hot-path host
                  sync, dropped donation, open-ended compile-shape set,
                  stray collective).  ``make analyze`` exits nonzero.
  * ``warning`` — the same patterns in cold paths (launch CLIs, trainers),
                  where a host sync is legitimate but worth an eyeball.
                  Fails only under ``--strict``.
  * ``info``    — accounting the other passes produce (predicted compile
                  counts, capacity-padding dead-compute fractions).  Never
                  fails the gate; it is the measurement channel.

Suppression: a finding whose source line (or the line above it) carries an
``# analysis: allow(<rule>) — <why>`` pragma is recorded as suppressed and
does not count toward the gate; ``render`` still lists suppressed counts so
pragma rot is visible.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

SEVERITIES = ("error", "warning", "info")


@dataclass
class Finding:
    rule: str  # e.g. "host-item", "donation-dropped", "contract-open"
    severity: str  # "error" | "warning" | "info"
    location: str  # "path/to/file.py:123" or "ContinuousEngine.decode"
    message: str
    suppressed: bool = False  # pragma'd findings stay in the report, inert

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r} (want {SEVERITIES})")

    def render(self) -> str:
        tag = "suppressed " if self.suppressed else ""
        return f"[{tag}{self.severity}] {self.rule} @ {self.location}: {self.message}"


@dataclass
class Report:
    """One pass's (or the whole suite's) findings, plus free-form metrics —
    the accounting channel (predicted compile counts, padded-compute
    fractions) that the CLI prints but never gates on."""

    findings: List[Finding] = field(default_factory=list)
    metrics: Dict[str, object] = field(default_factory=dict)

    def add(self, rule: str, severity: str, location: str, message: str,
            *, suppressed: bool = False) -> Finding:
        f = Finding(rule, severity, location, message, suppressed=suppressed)
        self.findings.append(f)
        return f

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)
        self.metrics.update(other.metrics)

    def active(self, severity: Optional[str] = None) -> List[Finding]:
        """Unsuppressed findings, optionally filtered by severity."""
        return [f for f in self.findings if not f.suppressed
                and (severity is None or f.severity == severity)]

    @property
    def errors(self) -> List[Finding]:
        return self.active("error")

    @property
    def warnings(self) -> List[Finding]:
        return self.active("warning")

    def failed(self, strict: bool = False) -> bool:
        return bool(self.errors) or (strict and bool(self.warnings))

    def render(self, *, show_info: bool = True, show_suppressed: bool = False) -> str:
        lines: List[str] = []
        order = {s: i for i, s in enumerate(SEVERITIES)}
        for f in sorted(self.findings, key=lambda f: (f.suppressed, order[f.severity], f.location)):
            if f.suppressed and not show_suppressed:
                continue
            if f.severity == "info" and not show_info:
                continue
            lines.append(f.render())
        for k in sorted(self.metrics):
            lines.append(f"[metric] {k} = {self.metrics[k]}")
        n_sup = sum(f.suppressed for f in self.findings)
        lines.append(
            f"-- {len(self.errors)} error(s), {len(self.warnings)} warning(s), "
            f"{len(self.active('info'))} info, {n_sup} suppressed --"
        )
        return "\n".join(lines)
