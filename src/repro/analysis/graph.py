"""Graph auditor: per-equation jaxpr walks over traced serving graphs.

``launch/hlo_account.py`` totals what a compiled graph *costs* (flops,
HBM traffic, collective bytes).  This pass audits what a traced graph
*contains* — the three structural defects the fused-tick / dropless-MoE
work will be measured against:

  * ``stray-collective``   — a communication primitive (psum, all_gather,
                             all_to_all, ppermute, ...) inside a graph the
                             engine declared single-device.  On one chip a
                             collective lowers to a copy at best; at worst it
                             means an ``out_shardings``/``shard_map`` leak
                             into the serving tick.
  * ``dtype-drift``        — ``convert_element_type`` from a quantized
                             integer dtype (int8 / int4) straight to float32
                             on a large buffer: the dequantize materializes a
                             4x-8x f32 copy of the weight/KV block instead of
                             staying in bf16 or fusing the scale into the
                             consuming dot.  (int32 position/index math is
                             exempt — only sub-byte and 8-bit sources count.)
  * ``capacity-padding``   — dead compute from capacity-factor gating: every
                             expert MLP dot runs over the full
                             ``[num_experts, capacity, d]`` dispatch buffer,
                             including slots gating left empty or dropped.
                             Reported as **info** with the analytic padded
                             fraction (1 - routed / (E*C)) cross-checked
                             against the actual leading-``num_experts`` dot
                             equations found in the graph.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import jax
from jax import core as jcore

from repro.analysis.findings import Report

# primitive names of cross-device communication in jax's lax.parallel
COLLECTIVE_PRIMS = {
    "psum", "pmax", "pmin", "ppermute", "pshuffle", "all_gather",
    "all_to_all", "psum_scatter", "reduce_scatter", "pgather", "pbroadcast",
}

# dequantize sources: sub-byte + 8-bit integer storage dtypes
_QUANT_SRC = {"int8", "uint8", "int4", "uint4"}


def _subjaxprs(params: Dict[str, Any]) -> Iterator[Any]:
    """Every Jaxpr/ClosedJaxpr reachable through an eqn's params (pjit's
    ``jaxpr``, scan/while bodies, cond ``branches``, custom_jvp calls...)."""
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for x in vals:
            if isinstance(x, jcore.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jcore.Jaxpr):
                yield x


def iter_eqns(jaxpr) -> Iterator[Any]:
    """All equations in a (closed) jaxpr, recursing through call/control-flow
    sub-jaxprs."""
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _subjaxprs(eqn.params):
            yield from iter_eqns(sub)


def _aval_of(var) -> Optional[Any]:
    return getattr(var, "aval", None)


def audit_collectives(jaxpr, name: str, report: Optional[Report] = None, *,
                      allowed: Sequence[str] = ()) -> Report:
    """Flag communication primitives in a graph declared single-device."""
    report = report if report is not None else Report()
    seen: Dict[str, int] = {}
    for eqn in iter_eqns(jaxpr):
        p = eqn.primitive.name
        if p in COLLECTIVE_PRIMS and p not in allowed:
            seen[p] = seen.get(p, 0) + 1
    for p, n in sorted(seen.items()):
        report.add(
            "stray-collective", "error", name,
            f"{n}x `{p}` in a single-device serving graph — a sharding or "
            "axis-env leak into the hot path (or an engine that should "
            "declare itself multi-device)",
        )
    report.metrics[f"graph.{name}.collectives"] = sum(seen.values())
    return report


def audit_dtype_drift(jaxpr, name: str, report: Optional[Report] = None, *,
                      min_elements: int = 4096) -> Report:
    """Flag int8/int4 -> f32 converts on large buffers (materialized
    dequantize instead of bf16 / fused-scale)."""
    report = report if report is not None else Report()
    hits = 0
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        src = _aval_of(eqn.invars[0])
        dst = _aval_of(eqn.outvars[0])
        if src is None or dst is None:
            continue
        if str(src.dtype) in _QUANT_SRC and str(dst.dtype) == "float32" \
                and math.prod(src.shape or (1,)) >= min_elements:
            hits += 1
            if hits <= 4:  # one finding per site, capped; total in metrics
                report.add(
                    "dtype-drift", "error", name,
                    f"convert {src.dtype}{list(src.shape)} -> float32: the "
                    "dequantized copy is 4-8x the quantized buffer — keep "
                    "the wide type bf16 or fuse the scale into the consumer",
                )
    report.metrics[f"graph.{name}.quant_f32_upcasts"] = hits
    return report


def capacity_dead_compute(num_tokens: int, num_experts: int, top_k: int,
                          capacity_factor: float) -> Dict[str, float]:
    """Analytic padded-compute fraction of capacity-factor dispatch: the
    dense ``[E, C, d]`` expert buffer runs every slot through the MLP whether
    or not gating filled it."""
    cap = max(1, int(capacity_factor * num_tokens * top_k / num_experts))
    slots = num_experts * cap
    routed = min(num_tokens * top_k, slots)
    return {
        "capacity": cap,
        "slots": slots,
        "routed_upper_bound": routed,
        "padded_fraction": 1.0 - routed / slots,
    }


def audit_dead_compute(jaxpr, name: str, *, num_tokens: int, num_experts: int,
                       top_k: int, capacity_factor: float,
                       impl: str = "einsum",
                       report: Optional[Report] = None) -> Report:
    """Cross-check the analytic padding fraction against the expert dots
    actually present in the graph (operands with leading dim
    ``num_experts``), and report the dead-compute share as info.

    ``impl="grouped"`` (dropless expert-sorted dispatch): the graph carries
    no ``[E, C, d]`` capacity buffer at all — its expert dots run over
    tile-padded sorted rows — so the capacity cross-check would be a FALSE
    finding there.  The audit instead reports the dropless path's analytic
    worst-case tile padding (< one tile per expert) as the info line."""
    report = report if report is not None else Report()
    if num_experts <= 0:
        return report
    if impl == "grouped":
        from repro.core.dispatch_grouped import GROUPED_TILE, grouped_rows

        tk = num_tokens * top_k
        ct = grouped_rows(num_tokens, top_k, num_experts, GROUPED_TILE)
        frac = 1.0 - tk / ct
        report.add(
            "capacity-padding", "info", name,
            f"grouped (dropless) dispatch: no [E, C] capacity buffer in the "
            f"graph; worst-case tile padding is {ct - tk} of {ct} sorted rows "
            f"({frac:.1%}, tile={GROUPED_TILE}), and every routed token is "
            "kept regardless of skew",
        )
        report.metrics[f"graph.{name}.expert_dots"] = 0
        report.metrics[f"graph.{name}.padded_fraction"] = round(frac, 4)
        return report
    stats = capacity_dead_compute(num_tokens, num_experts, top_k, capacity_factor)
    if impl in ("ep", "ep_serve"):
        # expert-parallel dispatch: the expert dots run inside shard_map over
        # per-shard [E_local, C, d] buffers, so a leading-dim == num_experts
        # scan would only catch unrelated batch-leading dots (e.g. attention
        # over num_slots == E).  Report the analytic padding and skip the
        # graph cross-check.
        report.add(
            "capacity-padding", "info", name,
            f"expert-parallel capacity dispatch: per-shard [E_local, "
            f"C={stats['capacity']}] buffers inside shard_map, "
            f">= {stats['padded_fraction']:.1%} capacity padding (analytic); "
            "full-E graph cross-check skipped — E_local-leading dots are "
            "indistinguishable from batch dims",
        )
        report.metrics[f"graph.{name}.expert_dots"] = 0
        report.metrics[f"graph.{name}.padded_fraction"] = round(stats["padded_fraction"], 4)
        return report
    expert_dots = 0
    expert_flops = 0.0
    graph_caps: set = set()
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "dot_general":
            continue
        lhs = _aval_of(eqn.invars[0])
        out = _aval_of(eqn.outvars[0])
        if lhs is None or out is None or not lhs.shape:
            continue
        if lhs.shape[0] == num_experts and len(lhs.shape) >= 3:
            expert_dots += 1
            graph_caps.add(int(lhs.shape[1]))
            dims = eqn.params.get("dimension_numbers")
            contract = 1
            if dims:
                for d in dims[0][0]:
                    contract *= lhs.shape[d]
            expert_flops += 2.0 * math.prod(out.shape) * contract
    if expert_dots and graph_caps != {stats["capacity"]}:
        report.add(
            "capacity-mismatch", "error", name,
            f"expert dispatch buffers in the graph use capacity {sorted(graph_caps)} "
            f"but the config's gating arithmetic gives {stats['capacity']} — "
            "the contract and the traced graph disagree",
        )
    if expert_dots:
        report.add(
            "capacity-padding", "info", name,
            f"{expert_dots} expert dot(s) over [E={num_experts}, "
            f"C={stats['capacity']}] buffers: >= {stats['padded_fraction']:.1%} "
            f"of their {expert_flops / 1e6:.1f} MFLOP is capacity padding "
            "(slots gating left empty still run the MLP) — the dropless "
            "baseline number",
        )
    report.metrics[f"graph.{name}.expert_dots"] = expert_dots
    report.metrics[f"graph.{name}.padded_fraction"] = round(stats["padded_fraction"], 4)
    return report


def audit_graph(name: str, fn, args: Sequence, *, single_device: bool = True,
                allowed_collectives: Sequence[str] = (),
                expect_collectives: bool = False,
                moe: Optional[Dict[str, Any]] = None,
                report: Optional[Report] = None) -> Report:
    """Run all graph checks on ``fn`` traced at ``args`` (ShapeDtypeStructs
    are fine — tracing only, no compile).  ``moe`` carries the gating
    arithmetic for the dead-compute pass:
    ``{num_tokens, num_experts, top_k, capacity_factor}``.

    ``single_device=False`` flips the collective check around: instead of
    flagging strays, ``expect_collectives=True`` asserts the graph DOES
    carry communication primitives — an expert-parallel serving graph whose
    all-to-all/all-gather exchange silently traced away (mesh context lost,
    EP impl fell back to a replicated kernel) would otherwise pass every
    other audit while serving single-device math on every rank."""
    report = report if report is not None else Report()
    try:
        closed = jax.make_jaxpr(fn)(*args)
    except Exception as exc:
        report.add("graph-trace-failed", "error", name,
                   f"could not trace for graph audit: {exc!r}".replace("\n", " ")[:300])
        return report
    if single_device:
        audit_collectives(closed, name, report, allowed=allowed_collectives)
    else:
        n_coll = sum(1 for eqn in iter_eqns(closed)
                     if eqn.primitive.name in COLLECTIVE_PRIMS)
        report.metrics[f"graph.{name}.collectives"] = n_coll
        if expect_collectives and n_coll == 0:
            report.add(
                "missing-collective", "error", name,
                "multi-device EP serving graph contains no communication "
                "primitive — the shard_map exchange traced away (lost mesh "
                "context or a silent fallback to a replicated MoE kernel)",
            )
    audit_dtype_drift(closed, name, report)
    if moe:
        audit_dead_compute(closed, name, report=report, **moe)
    return report
