"""Donation / aliasing auditor.

``donate_argnums`` is a *request*, not a guarantee: XLA only honors it when
a donated input buffer can actually alias some output (same shape + dtype,
platform support).  When it silently falls through, every engine tick
allocates a SECOND copy of the donated buffer — for the paged KV cache that
is multiple GiB of double-allocation and a hidden copy per tick, with no
error anywhere.  (jax emits a one-line warning at lowering time; nothing
fails.)

This pass lowers each jitted function at a representative signature and
verifies, leaf by leaf, that every donated pytree leaf produced an
input-output alias in the lowered module (the ``tf.aliasing_output``
attribute StableHLO records per aliased parameter).  Abstract lowering is
enough — no compile, no execution — so auditing the full-size serving
graphs is cheap.

A second, source-level check (``audit_donated_rebinds``) guards the host
side of the contract: after a call to a donating function, the donated
argument's buffer is DEAD — reading the old Python reference returns
garbage (or raises).  The only safe shape is rebinding the same reference
from the call's results in the same statement
(``logits, self.caches = self._decode(..., self.caches, ...)``), which is
exactly what the auditor requires of every call site of a registered
donating function.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.analysis.findings import Report

_ALIAS_ATTR_RE = re.compile(r"tf\.aliasing_output\s*=\s*(\d+)")
_ARG_RE = re.compile(r"%arg(\d+):\s*tensor<[^>]*>\s*(\{[^}]*\})?")


def _entry_param_aliases(stablehlo_text: str) -> Dict[int, bool]:
    """param index -> donation honored, parsed from the lowered module's
    entry function signature.  Single-device lowerings resolve the alias
    eagerly (``tf.aliasing_output = N``); multi-device lowerings mark the
    parameter donatable (``jax.buffer_donor = true``) and leave the pairing
    to compile time once shardings are fixed — both mean the donated buffer
    will not double-allocate."""
    m = re.search(r"func\.func\s+public\s+@main\((.*?)\)\s*->", stablehlo_text,
                  re.DOTALL)
    if not m:
        return {}
    out: Dict[int, bool] = {}
    for am in _ARG_RE.finditer(m.group(1)):
        idx = int(am.group(1))
        attrs = am.group(2) or ""
        out[idx] = "tf.aliasing_output" in attrs or "jax.buffer_donor" in attrs
    return out


def leaf_positions(args: Sequence, argnum: int) -> Tuple[int, List[str]]:
    """(first flat-parameter index, leaf key-paths) of ``args[argnum]`` in
    the jit calling convention (args flattened left to right)."""
    before = sum(len(jax.tree.leaves(a)) for a in args[:argnum])
    paths = [jax.tree_util.keystr(kp)
             for kp, _ in jax.tree_util.tree_flatten_with_path(args[argnum])[0]]
    return before, paths


def _kept_index_map(lowered, n_flat: int) -> Dict[int, int]:
    """flat-arg index -> entry-parameter position in the lowered module.

    ``jax.jit`` defaults to ``keep_unused=False``: flat arguments the traced
    computation never reads are PRUNED from the lowered module, shifting the
    positions of every later parameter (e.g. an ``active``-rows mask that a
    particular arch's decode graph happens not to consult).  The lowering
    records which flat vars survived in ``kept_var_idx``; without this map a
    positional alias lookup silently audits the wrong parameters."""
    kept = None
    try:
        kept = lowered._lowering.compile_args.get("kept_var_idx")
    except Exception:
        kept = None
    if kept is None:
        return {i: i for i in range(n_flat)}
    return {flat: pos for pos, flat in enumerate(sorted(kept))}


def audit_donation(name: str, jitfn, args: Sequence, donate_argnums: Sequence[int],
                   report: Optional[Report] = None, *,
                   location: str = "") -> Report:
    """Verify every donated leaf of ``jitfn`` at signature ``args`` (concrete
    arrays or ShapeDtypeStructs) produced an alias in the lowered module.

    ``donate_argnums`` is the engine's *declared* donation contract — passed
    separately from the jit wrapper precisely so a donation dropped from the
    ``jax.jit(...)`` call (the mutation the tests rehearse) is caught as a
    contract violation rather than silently re-shrinking the check."""
    report = report if report is not None else Report()
    loc = location or name
    try:
        lowered = jitfn.lower(*args)
        text = lowered.as_text()
    except Exception as e:  # lowering itself failing is its own finding
        report.add("donation-lower-failed", "error", loc,
                   f"could not lower for donation audit: {e!r}")
        return report
    aliases = _entry_param_aliases(text)
    if not aliases:
        report.add("donation-unparsed", "error", loc,
                   "could not parse entry parameters from lowered module")
        return report
    n_flat = len(jax.tree.leaves(list(args)))
    kept = _kept_index_map(lowered, n_flat)
    if len(kept) != len(aliases):
        report.add("donation-unparsed", "error", loc,
                   f"lowered module has {len(aliases)} entry parameters but "
                   f"the lowering kept {len(kept)} of {n_flat} flat args — "
                   "cannot map donated leaves to parameters")
        return report
    n_aliased_total = sum(aliases.values())
    n_declared = 0
    for argnum in donate_argnums:
        start, paths = leaf_positions(args, argnum)
        n_declared += len(paths)
        # a donated leaf pruned as unused (not in `kept`) cannot alias: the
        # matching output is a fresh buffer — report it as dropped too
        missing = [paths[i] for i in range(len(paths))
                   if not aliases.get(kept.get(start + i, -1), False)]
        if missing:
            shown = ", ".join(missing[:4]) + ("..." if len(missing) > 4 else "")
            report.add(
                "donation-dropped", "error", loc,
                f"declared donation of arg {argnum} produced no input-output "
                f"alias for {len(missing)}/{len(paths)} leaves ({shown}) — "
                "each unaliased leaf double-allocates per call",
            )
    report.metrics[f"donation.{name}.aliased"] = f"{n_aliased_total}/{n_declared}"
    return report


# ---------------------------------------------------------------------------
# Host-side read-after-donation (AST over the engine source)
# ---------------------------------------------------------------------------


def _expr_text(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return "<expr>"


def audit_donated_rebinds(source: str, relpath: str,
                          donated: Dict[str, int],
                          report: Optional[Report] = None) -> Report:
    """``donated`` maps a method attribute name (e.g. ``_decode``) to the
    donated positional-arg index.  Every call ``self.<fn>(...)`` must appear
    as the RHS of an assignment whose targets rebind the donated argument
    expression (``self.caches = ... self._decode(..., self.caches, ...)``);
    anything else leaves a live Python reference to a dead buffer."""
    report = report if report is not None else Report()
    tree = ast.parse(source, filename=relpath)

    class V(ast.NodeVisitor):
        def _targets_of(self, node: ast.AST) -> List[str]:
            out: List[str] = []
            parent = getattr(node, "_parent_assign", None)
            if parent is None:
                return out

            def collect(t):
                if isinstance(t, (ast.Tuple, ast.List)):
                    for e in t.elts:
                        collect(e)
                else:
                    out.append(_expr_text(t))

            for t in parent.targets:
                collect(t)
            return out

        def visit_Assign(self, node: ast.Assign) -> None:
            for sub in ast.walk(node.value):
                sub._parent_assign = node
            self.generic_visit(node)

        def visit_Call(self, node: ast.Call) -> None:
            name = None
            if isinstance(node.func, ast.Attribute) and \
                    isinstance(node.func.value, ast.Name) and \
                    node.func.value.id == "self":
                name = node.func.attr
            if name in donated:
                argnum = donated[name]
                if argnum >= len(node.args):
                    report.add("donation-arity", "error",
                               f"{relpath}:{node.lineno}",
                               f"self.{name} called with fewer than "
                               f"{argnum + 1} positional args")
                else:
                    arg_txt = _expr_text(node.args[argnum])
                    targets = self._targets_of(node)
                    if arg_txt not in targets:
                        report.add(
                            "donation-host-read", "error",
                            f"{relpath}:{node.lineno}",
                            f"donated arg `{arg_txt}` of self.{name} is not "
                            "rebound by the call's assignment targets "
                            f"({targets or 'no assignment'}) — the old "
                            "reference is a dead buffer after the call",
                        )
            self.generic_visit(node)

    V().visit(tree)
    return report
