"""AST host-sync / tracer-leak lint over ``src/repro``.

The serving hot path lives or dies on never blocking the Python thread on
device values mid-tick (and never leaking tracers into Python control flow
inside jitted code).  This pass finds the syntactic shapes those bugs take:

  * ``host-item``     — ``x.item()``: always a device->host sync.
  * ``host-cast``     — ``int()/float()/bool()`` over an expression that
                        involves a device value (a ``jnp.*``/``jax.*`` call,
                        a call to a function imported from the model/kernel
                        layers, a jitted ``self._*`` engine function, or a
                        local previously bound to one).  Blocks until the
                        value is ready.
  * ``host-asarray``  — ``np.asarray()/np.array()`` over a device value:
                        the transfer that ends XLA's async dispatch pipeline.
  * ``tracer-branch`` — Python ``if``/``while``/``assert`` on a device value
                        inside *traced* modules (models/core/kernels/quant):
                        under ``jit`` this is a ConcretizationTypeError at
                        best, a silently-specialized graph at worst.
  * ``debug-call``    — ``jax.debug.print/callback/breakpoint`` left in the
                        serving/training code (each is a host callback that
                        serializes the step).
  * ``block-sync``    — ``jax.block_until_ready`` / ``.block_until_ready()``
                        in hot modules; legitimate only as a deliberate
                        timing fence (pragma it with the justification).

Device-ness is inferred per function with a single in-order pass: calls
rooted at ``jnp.``/``jax.`` are device-producing, as are names imported from
modules matching ``device_import_re`` (the traced layers) and calls to
``self._*`` attributes in engine modules (the jitted fns); assignment
propagates it to the bound names.  Attribute reads of static metadata
(``.shape``/``.ndim``/``.dtype``/``.size``) are NOT device values — casting
a shape is free and idiomatic.

Severity comes from the module map: findings in hot modules (serving /
models / kernels / core / quant) are **errors**, in cold modules (launch
CLIs, training drivers, data, obs, ...) **warnings** — a host sync in a
results printer is fine, but the map keeps it visible so hot code cannot be
pasted there and drift back.

Suppression: ``# analysis: allow(<rule>[, <rule>...]) — <one-line why>`` on
the offending line, or alone on the line above it.  Suppressed findings are
still reported (inert) so pragma rot is visible; the justification text is
mandatory by convention, enforced by review rather than the parser.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Report

PRAGMA_RE = re.compile(r"#\s*analysis:\s*allow\(\s*([\w\-*,\s]+?)\s*\)")

RULES = ("host-item", "host-cast", "host-asarray", "tracer-branch",
         "debug-call", "block-sync")

# attribute reads that are static metadata, not device values
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval"}

# jax/jnp-rooted calls that return HOST values (platform probes, static
# metadata, abstract evaluation) — not device arrays
_HOST_CALLS = {
    "jax.default_backend", "jax.devices", "jax.local_devices",
    "jax.device_count", "jax.local_device_count", "jax.process_index",
    "jax.process_count", "jax.eval_shape", "jax.make_jaxpr",
    "jax.tree.structure", "jax.tree_util.tree_structure",
    "jnp.ndim", "jnp.shape", "jnp.size", "jnp.dtype", "jnp.result_type",
    "jnp.issubdtype", "jnp.iinfo", "jnp.finfo",
}


@dataclass
class LintConfig:
    # module-path regexes (matched against the path relative to the scan
    # root, forward slashes) — hot findings are errors, cold are warnings
    hot_re: str = r"(serving|models|kernels|core|quant)/"
    # traced modules: code that runs under jit — tracer-branch applies here
    traced_re: str = r"(models|kernels|core|quant)/"
    # imports from these modules are device-producing callables
    device_import_re: str = (
        r"repro\.(models|kernels|core|quant|serving\.sampling)")
    # calls to self.<attr> matching this, in hot modules, produce device
    # values (the engines' jitted functions)
    jit_attr_re: str = r"^_(decode|prefill|reset|copy|make_caches)"
    # boolean predicates by naming convention (is_/has_/check_/spec_is_...)
    # return host bools even when imported from device modules
    host_fn_re: str = r"(^_?(is|has|check|can|supports)_)|(^spec_is_)|(_is_)"
    skip_re: str = r"analysis/"  # don't lint the linter's own fixtures

    def severity_for(self, relpath: str, rule: str) -> Optional[str]:
        hot = re.search(self.hot_re, relpath) is not None
        if rule == "tracer-branch":
            return "error" if re.search(self.traced_re, relpath) else None
        return "error" if hot else "warning"


def _pragmas(source: str) -> Dict[int, Set[str]]:
    """line number (1-based) -> set of allowed rules on that line.  A pragma
    on a comment-only line also covers the next line."""
    out: Dict[int, Set[str]] = {}
    lines = source.splitlines()
    for i, line in enumerate(lines, 1):
        m = PRAGMA_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        out.setdefault(i, set()).update(rules)
        if line.strip().startswith("#"):
            out.setdefault(i + 1, set()).update(rules)
    return out


def _dotted(node: ast.AST) -> Optional[str]:
    """'jnp.einsum' / 'self._decode' / 'np.asarray' for an attr chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _FileLinter(ast.NodeVisitor):
    def __init__(self, relpath: str, source: str, cfg: LintConfig, report: Report):
        self.relpath = relpath
        self.cfg = cfg
        self.report = report
        self.pragmas = _pragmas(source)
        self.device_fns: Set[str] = set()  # module-level device-producing names
        self.scopes: List[Set[str]] = []  # per-function device-bound names

    # -- imports: which names are device-producing callables ---------------
    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module and re.search(self.cfg.device_import_re, node.module):
            for a in node.names:
                self.device_fns.add(a.asname or a.name)
        self.generic_visit(node)

    # -- device-ness --------------------------------------------------------
    def _call_is_device(self, call: ast.Call) -> bool:
        name = _dotted(call.func)
        if name is None:
            return False
        if re.search(self.cfg.host_fn_re, name.split(".")[-1]):
            return False  # boolean predicate by naming convention
        root = name.split(".")[0]
        if root in ("jnp", "jax"):
            if name in _HOST_CALLS:
                return False  # platform probe / static metadata, host value
            # jax.debug / block_until_ready have dedicated rules
            return not name.startswith(("jax.debug", "jax.block_until_ready"))
        if name in self.device_fns:
            return True
        if name.startswith("self."):
            attr = name.split(".", 1)[1]
            if re.match(self.cfg.jit_attr_re, attr):
                return True
        return False

    def _is_device(self, node: ast.AST) -> bool:
        """Does this expression involve a device value?  Static-metadata
        attribute reads (.shape etc.) cut the search."""
        for sub in self._walk_non_static(node):
            if isinstance(sub, ast.Call) and self._call_is_device(sub):
                return True
            if isinstance(sub, ast.Name) and self.scopes and sub.id in self.scopes[-1]:
                return True
        return False

    def _walk_non_static(self, node: ast.AST) -> Iterable[ast.AST]:
        yield node
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            # a comprehension's value is its ELEMENT: [leaf.shape[2] for leaf
            # in jax.tree.leaves(c)] is a host list of ints even though the
            # iterable is a device tree
            yield from self._walk_non_static(node.elt)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.Attribute) and child.attr in _STATIC_ATTRS:
                continue  # x.shape[...] is host-side metadata
            yield from self._walk_non_static(child)

    # -- scope handling ------------------------------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.scopes.append(set())
        self.generic_visit(node)
        self.scopes.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _bind_targets(self, targets: Sequence[ast.AST]) -> None:
        if not self.scopes:
            return
        for t in targets:
            if isinstance(t, (ast.Tuple, ast.List)):
                self._bind_targets(t.elts)
            elif isinstance(t, ast.Name):
                self.scopes[-1].add(t.id)
            elif isinstance(t, ast.Starred):
                self._bind_targets([t.value])

    def visit_Assign(self, node: ast.Assign) -> None:
        # visit RHS first: `x = np.asarray(x_dev)` must flag the OLD x
        self.visit(node.value)
        for t in node.targets:  # subscript/attr targets can hold calls too
            if not isinstance(t, (ast.Name, ast.Tuple, ast.List, ast.Starred)):
                self.visit(t)
        root = _dotted(node.value.func) if isinstance(node.value, ast.Call) else None
        if root and root.split(".")[0] == "np":
            pass  # np.* results are host values — the sync already happened
        elif self._is_device(node.value):
            self._bind_targets(node.targets)
        elif self.scopes:
            for t in node.targets:  # rebinding to a host value clears it
                if isinstance(t, ast.Name):
                    self.scopes[-1].discard(t.id)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.visit(node.value)
        if self._is_device(node.value):
            self._bind_targets([node.target])

    # -- findings ------------------------------------------------------------
    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        sev = self.cfg.severity_for(self.relpath, rule)
        if sev is None:
            return
        line = getattr(node, "lineno", 0)
        allowed = self.pragmas.get(line, set())
        suppressed = rule in allowed or "*" in allowed
        self.report.add(rule, sev, f"{self.relpath}:{line}", message,
                        suppressed=suppressed)

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if name:
            if name.endswith(".item") and not node.args:
                self._emit("host-item", node,
                           "`.item()` forces a device->host sync")
            elif name in ("int", "float", "bool") and node.args \
                    and self._is_device(node.args[0]):
                self._emit("host-cast", node,
                           f"`{name}()` over a device value blocks on the result")
            elif name in ("np.asarray", "np.array", "numpy.asarray", "numpy.array") \
                    and node.args and self._is_device(node.args[0]):
                self._emit("host-asarray", node,
                           f"`{name}` of a device value is a blocking transfer")
            elif name.startswith("jax.debug."):
                self._emit("debug-call", node,
                           f"`{name}` is a host callback; remove before serving")
            elif name == "jax.block_until_ready" or name.endswith(".block_until_ready"):
                self._emit("block-sync", node,
                           "explicit device fence in a hot module")
        self.generic_visit(node)

    def _check_branch(self, test: ast.AST, kind: str) -> None:
        # `x is None` / `x is not None` on a device name is a host-side
        # identity test, not a sync — common and fine
        if isinstance(test, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
            return
        # `isinstance(x, QuantizedKV)` is pytree-node type dispatch — static
        # under tracing (tracers keep their pytree structure), not a sync
        if isinstance(test, ast.Call) and _dotted(test.func) == "isinstance":
            return
        if self._is_device(test):
            self._emit("tracer-branch", test,
                       f"Python `{kind}` on a device value — under jit this "
                       "is a tracer leak (concretization)")

    def visit_If(self, node: ast.If) -> None:
        self._check_branch(node.test, "if")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._check_branch(node.test, "while")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        self._check_branch(node.test, "assert")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        self._check_branch(node.test, "if-expression")
        self.generic_visit(node)


def lint_source(source: str, relpath: str, cfg: Optional[LintConfig] = None,
                report: Optional[Report] = None) -> Report:
    report = report if report is not None else Report()
    cfg = cfg if cfg is not None else LintConfig()
    tree = ast.parse(source, filename=relpath)
    _FileLinter(relpath, source, cfg, report).visit(tree)
    return report


def lint_tree(root: str, cfg: Optional[LintConfig] = None) -> Report:
    """Lint every ``.py`` under ``root`` (the ``src/repro`` package)."""
    cfg = cfg if cfg is not None else LintConfig()
    report = Report()
    rootp = Path(root)
    for path in sorted(rootp.rglob("*.py")):
        rel = path.relative_to(rootp).as_posix()
        if re.search(cfg.skip_re, rel):
            continue
        lint_source(path.read_text(), rel, cfg, report)
    counts: Dict[str, int] = {}
    for f in report.findings:
        if not f.suppressed:
            counts[f.rule] = counts.get(f.rule, 0) + 1
    report.metrics["lint.files"] = sum(1 for _ in rootp.rglob("*.py"))
    report.metrics["lint.findings_by_rule"] = counts
    return report
