"""PR-MoE / MoS model builders (DeepSpeed-MoE §4) and the paper's own NLG
model family (§3, Table 1).

* Standard MoE NLG: "<base>+MoE-E" = GPT base with E experts on every *other*
  FFN layer, top-1 gating (Table 1: 350M+MoE-128, 1.3B+MoE-128).
* PR-MoE: Pyramid (second half of MoE layers has 2× experts) + Residual
  (fixed dense MLP + top-1 expert).  350M+PR-MoE-32/64, 1.3B+PR-MoE-64/128.
* MoS: the PR-MoE student with depth reduced 24 -> 21 (12.5%), trained with
  staged knowledge distillation (training/distill.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.configs.base import (
    AttnSpec,
    FFNSpec,
    LayerSpec,
    ModelConfig,
    Segment,
)


def _gpt_attn() -> AttnSpec:
    # Paper's NLG models are GPT-style: learned-pos in the original; we use
    # RoPE (TPU-era idiom) — documented deviation, does not change any of the
    # size/FLOP/communication claims being reproduced.
    return AttnSpec(kind="global", rope_theta=10_000.0)


def _dense_layer(d_ff: int) -> LayerSpec:
    return LayerSpec(_gpt_attn(), FFNSpec(kind="dense", d_ff=d_ff, act="gelu"))


def _moe_layer(d_ff: int, experts: int, residual: bool, top_k: int = 1) -> LayerSpec:
    return LayerSpec(
        _gpt_attn(),
        FFNSpec(
            kind="moe",
            d_ff=d_ff,
            act="gelu",
            num_experts=experts,
            top_k=top_k,
            capacity_factor=1.25,
            residual=residual,
            aux_loss_coef=0.01,
        ),
    )


def nlg_dense(name: str, n_layers: int, d_model: int, n_heads: int, vocab: int = 51_200) -> ModelConfig:
    layer = _dense_layer(4 * d_model)
    return ModelConfig(
        name=name,
        family="dense",
        source="[GPT-3 recipe, paper Table 1]",
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_heads,
        head_dim=d_model // n_heads,
        vocab_size=vocab,
        segments=(Segment((layer,), n_layers),),
        max_seq_len=2048,
        tie_embeddings=True,
    )


def nlg_moe(
    name: str,
    n_layers: int,
    d_model: int,
    n_heads: int,
    experts: int | Tuple[int, int],
    *,
    residual: bool = False,
    vocab: int = 51_200,
    student_layers: Optional[int] = None,
) -> ModelConfig:
    """'Every other FFN layer is MoE' (§3.1).  ``experts`` int -> standard MoE;
    (lo, hi) -> Pyramid: first half of MoE layers get lo, second half hi.
    ``student_layers`` trims depth for MoS (layers removed from the top,
    preserving the dense/MoE interleave)."""
    d_ff = 4 * d_model
    total = student_layers or n_layers
    dense_l = _dense_layer(d_ff)

    if isinstance(experts, int):
        pattern = (dense_l, _moe_layer(d_ff, experts, residual))
        reps, rem = divmod(total, 2)
        segs = [Segment(pattern, reps)]
        if rem:
            segs.append(Segment((dense_l,), 1))
        family = "moe"
    else:
        lo, hi = experts
        # Pyramid (§4.1.2, Fig. 3 & the Pyramid-MoE-32/64 ablation): the *last
        # two* MoE layers use 2x experts (`hi`), all earlier MoE layers use
        # `lo`.  This reproduces the paper's parameter counts exactly
        # (4B / 31B / 3.5B / 27B).
        n_moe = total // 2
        n_hi = min(2, n_moe)
        n_lo = n_moe - n_hi
        segs = []
        if n_lo:
            segs.append(Segment((dense_l, _moe_layer(d_ff, lo, residual)), n_lo))
        segs.append(Segment((dense_l, _moe_layer(d_ff, hi, residual)), n_hi))
        rem = total - 2 * n_moe
        if rem:
            segs.append(Segment((dense_l,), 1))
        family = "moe"

    return ModelConfig(
        name=name,
        family=family,
        source="[DeepSpeed-MoE Table 1]",
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_heads,
        head_dim=d_model // n_heads,
        vocab_size=vocab,
        segments=tuple(segs),
        max_seq_len=2048,
        tie_embeddings=True,
    )


# --- The paper's Table 1 / Table 6 model zoo ------------------------------


def paper_models() -> dict:
    m = {}
    m["nlg-350m"] = nlg_dense("nlg-350m", 24, 1024, 16)
    m["nlg-1.3b"] = nlg_dense("nlg-1.3b", 24, 2048, 16)
    m["nlg-6.7b"] = nlg_dense("nlg-6.7b", 32, 4096, 32)
    m["nlg-350m-moe128"] = nlg_moe("nlg-350m-moe128", 24, 1024, 16, 128)
    m["nlg-1.3b-moe128"] = nlg_moe("nlg-1.3b-moe128", 24, 2048, 16, 128)
    m["nlg-350m-prmoe-32-64"] = nlg_moe("nlg-350m-prmoe-32-64", 24, 1024, 16, (32, 64), residual=True)
    m["nlg-1.3b-prmoe-64-128"] = nlg_moe("nlg-1.3b-prmoe-64-128", 24, 2048, 16, (64, 128), residual=True)
    # MoS students: depth 24 -> 21 (12.5% reduction, §4.2.2)
    m["nlg-350m-prmoe-mos"] = nlg_moe(
        "nlg-350m-prmoe-mos", 24, 1024, 16, (32, 64), residual=True, student_layers=21
    )
    m["nlg-1.3b-prmoe-mos"] = nlg_moe(
        "nlg-1.3b-prmoe-mos", 24, 2048, 16, (64, 128), residual=True, student_layers=21
    )
    # Table 6 inference-eval configs (standard MoE):
    m["nlg-2.4b-moe128"] = nlg_moe("nlg-2.4b-moe128", 16, 3584, 28, 128)
    # NOTE: Table 6 lists 8B@30L and 24B@40L, but the stated totals (349.0B /
    # 1064.9B) only reconcile with 8B@40Lx4096 and 24B@30Lx8192 — the layer
    # counts appear transposed in the paper; we follow the totals.
    m["nlg-8b-moe128"] = nlg_moe("nlg-8b-moe128", 40, 4096, 32, 128)
    m["nlg-24b-moe128"] = nlg_moe("nlg-24b-moe128", 30, 8192, 64, 128)
    m["nlg-47b-moe128"] = nlg_moe("nlg-47b-moe128", 58, 8192, 64, 128)
    return m
