"""Grouped "dropless" MoE dispatch (MegaBlocks-style).

Capacity-factor dispatch (``dispatch.py`` / ``dispatch_einsum.py``) pads
every expert's token buffer to a static ``expert_capacity`` — the dead
compute the graph auditor's ``capacity-padding`` finding prices, and the
token *drops* whenever routing skews past the factor.  The grouped layout
removes both at once:

  1. sort the ``T*K`` (token, k) assignment slots by expert (stable argsort,
     token-major priority preserved — the same order capacity gating ranks);
  2. pad each expert's *actual* group only up to the next multiple of
     ``tile`` (the kernel's token-block size), never to capacity;
  3. scatter tokens into one flat ``[Ct, D]`` buffer of concatenated padded
     groups, where ``Ct = round_down(T*K + E*(tile-1), tile)`` is the static
     worst case over all routings — per-expert *offsets* are data, the
     buffer shape is not;
  4. hand the kernel a ``tile_expert [Ct/tile]`` map (tile index -> expert
     id) so each token tile walks against exactly its expert's weights
     (scalar-prefetched on TPU — ``kernels/expert_mlp_grouped.py``).

Every assignment keeps its expert (``keep`` all-True by construction when
gated with ``capacity = T*K``), so routing skew costs at most ``E`` partial
tiles of padding instead of dropped tokens — the dispatch is *exact* for
any routing, which is what makes it the batched-prefill engine's MoE
implementation of choice (capacity gating couples tokens across slots
through the shared buffer; dropless keeps rows independent).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.gating import Gating

# Default token-tile granularity of the grouped buffer.  8 matches the
# sublane granularity ``expert_capacity`` already pads to (cheap on CPU
# tests); pass 128 on TPU to keep the MXU systolic array full.
GROUPED_TILE = 8


def grouped_rows(num_tokens: int, top_k: int, num_experts: int,
                 tile: int = GROUPED_TILE) -> int:
    """Static row count of the grouped buffer: the worst case of per-expert
    tile padding over ALL routings.  Each non-empty group wastes at most
    ``tile - 1`` rows, and the total is itself a tile multiple."""
    tk = num_tokens * top_k
    return (tk + num_experts * (tile - 1)) // tile * tile


class GroupedLayout(NamedTuple):
    """Device-side routing layout for one dispatch.

    dst:         [T*K] int32 — grouped-buffer row of each (token, k) slot
                 (token-major; rows within an expert's group preserve the
                 capacity-gating priority order)
    tile_expert: [Ct/tile] int32 — expert id owning each token tile
                 (trailing unused tiles clamp to E-1; their rows stay zero
                 and no ``dst`` points at them)
    counts:      [E] int32 — real (un-padded) assignments per expert
    """

    dst: jax.Array
    tile_expert: jax.Array
    counts: jax.Array


def grouped_layout(g: Gating, num_experts: int, *,
                   tile: int = GROUPED_TILE) -> GroupedLayout:
    """Sort-free-shape layout: per-expert ragged offsets as *data* inside a
    static ``[Ct]`` index space (step 1-4 of the module docstring)."""
    T, K = g.expert_idx.shape
    TK = T * K
    flat_e = g.expert_idx.reshape(-1)  # [T*K], token-major
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=num_experts)
    padded = (counts + tile - 1) // tile * tile  # per-group tile padding ONLY
    # rank of each sorted slot within its expert's run (same searchsorted
    # trick as gating._positions_sort)
    group_start = jnp.searchsorted(sorted_e, jnp.arange(num_experts, dtype=flat_e.dtype),
                                   side="left")
    rank_sorted = jnp.arange(TK, dtype=jnp.int32) - group_start[sorted_e].astype(jnp.int32)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    dst_sorted = starts[sorted_e] + rank_sorted
    dst = jnp.zeros((TK,), jnp.int32).at[order].set(dst_sorted)
    # tile t covers rows [t*tile, (t+1)*tile): its owner is the expert whose
    # padded-prefix-sum first exceeds the tile's start row
    nt = grouped_rows(T, K, num_experts, tile) // tile
    bounds = jnp.cumsum(padded)  # [E]
    tile_expert = jnp.searchsorted(
        bounds, jnp.arange(nt, dtype=bounds.dtype) * tile, side="right")
    tile_expert = jnp.clip(tile_expert, 0, num_experts - 1).astype(jnp.int32)
    return GroupedLayout(dst=dst, tile_expert=tile_expert,
                         counts=counts.astype(jnp.int32))


def moe_grouped(x: jax.Array, g: Gating, num_experts: int,
                expert_fn: Callable[[jax.Array, jax.Array], jax.Array], *,
                tile: int = GROUPED_TILE) -> jax.Array:
    """x: [T, D]; ``g`` must be dropless gating (``capacity = T*K``).
    ``expert_fn``: (xg [Ct, D], tile_expert [Ct/tile]) -> [Ct, D], applying
    tile ``t``'s rows against expert ``tile_expert[t]``'s MLP.

    gather-by-token -> scatter into padded groups -> grouped experts ->
    gather-by-row -> weighted scatter-add combine (f32 accumulation, same
    precision discipline as the einsum path).
    """
    T, D = x.shape
    K = g.expert_idx.shape[1]
    TK = T * K
    layout = grouped_layout(g, num_experts, tile=tile)
    token = jnp.arange(TK, dtype=jnp.int32) // K  # flat slot -> source token
    Ct = layout.tile_expert.shape[0] * tile
    xg = jnp.zeros((Ct, D), x.dtype).at[layout.dst].set(x[token])
    yg = expert_fn(xg, layout.tile_expert)  # [Ct, D]
    w = g.combine_w.reshape(-1).astype(jnp.float32)  # keep is all-True (dropless)
    y = jnp.zeros((T, D), jnp.float32).at[token].add(
        w[:, None] * yg[layout.dst].astype(jnp.float32))
    return y.astype(x.dtype)
