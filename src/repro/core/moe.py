"""The MoE FFN layer (DeepSpeed-MoE §3 + §4 + §5).

Four interchangeable dispatch implementations (``cfg.moe_impl``):

  * ``einsum``  — sparse one-hot einsum (paper's baseline, §5.4)
  * ``dense``   — dense mapping-table scatter/gather (paper's optimization)
  * ``grouped`` — dropless expert-sorted dispatch (MegaBlocks-style): no
                  ``expert_capacity``, no drops; tokens tile-pad only to the
                  kernel tile (core/dispatch_grouped.py +
                  kernels/expert_mlp_grouped.py)
  * ``ep``      — dense dispatch + explicit expert-parallel all-to-all under
                  shard_map with parallelism-coordinated communication
                  (paper §5.2-5.3); requires an active mesh.

``residual=True`` adds the fixed dense-MLP branch of Residual-MoE (§4.1.1);
combined with pyramid segments this gives PR-MoE.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FFNSpec, ModelConfig
from repro.core import dispatch, dispatch_einsum, dispatch_grouped
from repro.core.gating import (
    expert_capacity,
    load_balance_loss,
    routing_stats,
    top_k_gating,
)
from repro.models.modules import dense_init, init_mlp, mlp
from repro.parallel.sharding import get_mesh, shard_hint
from repro.quant.qarrays import QuantizedArray


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig, spec: FFNSpec, dtype) -> dict:
    d, f, e = cfg.d_model, spec.d_ff, spec.num_experts
    ks = jax.random.split(key, 5)

    def stack_init(k, in_dim, out_dim):
        return jax.vmap(lambda kk: dense_init(kk, in_dim, out_dim, dtype))(jax.random.split(k, e))

    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "wi": stack_init(ks[1], d, f),  # [E, D, F]
        "wo": stack_init(ks[2], f, d),  # [E, F, D]
    }
    if spec.act == "swiglu":
        p["wg"] = stack_init(ks[3], d, f)
    if spec.residual:
        p["residual"] = init_mlp(ks[4], d, spec.residual_d_ff or spec.d_ff, spec.act, dtype)
    return p


# ---------------------------------------------------------------------------
# Expert FFN over stacked buffers
# ---------------------------------------------------------------------------


def experts_ffn(params: dict, xe: jax.Array, act: str, *, backend: str | None = None) -> jax.Array:
    """xe: [E, C, D] -> [E, C, D] — per-expert (Swi)GLU MLP as grouped GEMMs.

    Quantized expert weights (MoQ, repro/quant) are handled transparently:
    the int8-per-channel SwiGLU layout takes the Pallas dequant-in-kernel
    path on TPU (weights stream HBM→VMEM at 1 byte/param); other layouts
    (int4, group-wise, non-swiglu acts) dequantize into the einsum path.
    ``backend`` ("kernel" | "ref") pins the quantized path per call —
    prefer it over the process-wide toggle below when jit caching matters.
    """
    if isinstance(params["wi"], QuantizedArray):
        return _experts_ffn_quant(params, xe, act, backend)
    h = jnp.einsum("ecd,edf->ecf", xe, params["wi"])
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["wg"])) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return jnp.einsum("ecf,efd->ecd", h, params["wo"])


# Process-wide default for the quantized expert path: None = auto (Pallas
# kernel on TPU, dequant-einsum reference elsewhere — interpret-mode Pallas is
# a correctness tool, far too slow to serve from).  "kernel" / "ref" force.
QUANT_EXPERT_BACKEND = [None]


def set_quant_expert_backend(mode) -> None:
    """Test/benchmark knob.  The flag is read at trace time and is not part
    of any jit cache key, so changing it drops ALL cached compilations to
    keep already-jitted engines honest — expensive; per-call sites should
    pass ``experts_ffn(..., backend=...)`` instead."""
    assert mode in (None, "kernel", "ref"), mode
    if QUANT_EXPERT_BACKEND[0] == mode:
        return
    QUANT_EXPERT_BACKEND[0] = mode
    jax.clear_caches()


def _experts_ffn_quant(params: dict, xe: jax.Array, act: str, backend: str | None) -> jax.Array:
    from repro.kernels.expert_mlp_quant import _check_kernel_compat, expert_mlp_quant_ref

    wi, wo = params["wi"], params["wo"]
    wg = params.get("wg")
    mode = backend or QUANT_EXPERT_BACKEND[0]
    if mode is None:
        mode = "kernel" if jax.default_backend() == "tpu" else "ref"
    if mode == "kernel" and act == "swiglu" and _check_kernel_compat(xe, wi, wg, wo):
        from repro.kernels.ops import fused_expert_mlp_quant

        return fused_expert_mlp_quant(xe, wi, wg, wo)
    if act == "swiglu":
        return expert_mlp_quant_ref(xe, wi, wg, wo)
    h = jnp.einsum("ecd,edf->ecf", xe, wi.dequantize())
    h = jax.nn.gelu(h) if act == "gelu" else jax.nn.relu(h)
    return jnp.einsum("ecf,efd->ecd", h, wo.dequantize())


# Process-wide default for the grouped (dropless) expert path, same contract
# as QUANT_EXPERT_BACKEND: None = auto (Pallas kernel on TPU, gather-einsum
# reference elsewhere), "kernel" / "ref" force.
GROUPED_EXPERT_BACKEND = [None]


def set_grouped_expert_backend(mode) -> None:
    """Test/benchmark knob; read at trace time (not a jit cache key), so
    changing it drops ALL cached compilations — expensive; per-call sites
    should pass ``grouped_experts_ffn(..., backend=...)`` instead."""
    assert mode in (None, "kernel", "ref"), mode
    if GROUPED_EXPERT_BACKEND[0] == mode:
        return
    GROUPED_EXPERT_BACKEND[0] = mode
    jax.clear_caches()


def grouped_experts_ffn(
    params: dict, xg: jax.Array, te: jax.Array, act: str, *, backend: str | None = None
) -> jax.Array:
    """xg: [Ct, D] expert-sorted tile-padded tokens; te: [Ct/tile] tile ->
    expert map (core/dispatch_grouped.py layout) -> [Ct, D].

    fp and quantized weights both route to the grouped Pallas kernel on TPU
    (int8 AND int4 dequantize in VMEM — the grouped path is the first place
    int4 gets a true in-kernel execution); elsewhere the gather-einsum
    reference runs.
    """
    from repro.kernels import expert_mlp_grouped as gk

    wi, wo = params["wi"], params["wo"]
    wg = params.get("wg")
    quantized = isinstance(wi, QuantizedArray)
    mode = backend or GROUPED_EXPERT_BACKEND[0]
    if mode is None:
        mode = "kernel" if jax.default_backend() == "tpu" else "ref"
    if mode == "kernel" and act == "swiglu":
        if not quantized:
            from repro.kernels.ops import fused_expert_mlp_grouped

            return fused_expert_mlp_grouped(xg, te, wi, wg, wo)
        if gk._check_grouped_quant_compat(wi, wg, wo):
            from repro.kernels.ops import fused_expert_mlp_grouped_quant

            return fused_expert_mlp_grouped_quant(xg, te, wi, wg, wo)
    if quantized:
        return gk.grouped_mlp_quant_ref(xg, te, wi, wg, wo, act)
    return gk.grouped_mlp_ref(xg, te, wi, wg, wo, act)


# ---------------------------------------------------------------------------
# Layer apply
# ---------------------------------------------------------------------------


def moe_layer(
    cfg: ModelConfig,
    spec: FFNSpec,
    params: dict,
    x: jax.Array,  # [B, S, D]
    *,
    impl: str | None = None,
    with_stats: bool = False,
) -> Tuple[jax.Array, ...]:
    """Returns (y [B,S,D], aux_loss scalar); with ``with_stats=True`` a
    third element — a jit-returnable ``RoutingStats`` (token-count-
    independent shapes) for per-layer telemetry (docs/OBSERVABILITY.md)."""
    impl = impl or cfg.moe_impl
    B, S, D = x.shape
    E, K = spec.num_experts, spec.top_k
    stats = None

    if impl in ("ep_serve", "ep_grouped"):
        # Serving EP (core/moe_serve.py) needs an active mesh whose 'expert'
        # rule axes divide E; otherwise degrade to the equivalent
        # single-device kernel.  Under a live multi-device mesh the dense
        # mapping-table path is guarded (dispatch.moe_dense raises), so the
        # dense-family fallback there is the einsum dispatch.
        from repro.core.moe_serve import serve_ep_axes

        if serve_ep_axes(E) is None:
            if impl == "ep_grouped":
                impl = "grouped"
            else:
                impl = "einsum" if get_mesh() is not None else "dense"

    if impl in ("ep_serve", "ep_grouped"):
        from repro.core.moe_serve import moe_layer_ep_serve

        if isinstance(params.get("wi"), QuantizedArray):
            # shard_map in_specs address raw arrays (same rule as "ep"); the
            # engines dequantize expert leaves ONCE at load time — this
            # in-jit fallback only runs when a mesh appears after tracing.
            from repro.quant.ptq import dequantize_params

            params = {**params, **dequantize_params(
                {k: params[k] for k in ("wi", "wg", "wo") if k in params}
            )}
        kernel = "grouped" if impl == "ep_grouped" else "dense"
        y, aux = moe_layer_ep_serve(cfg, spec, params, x, kernel=kernel)
        if with_stats:
            # Router + gating re-run on the replicated token set outside
            # shard_map.  For the replicated-token schedules (decode,
            # grouped) this is EXACTLY the gating the sharded dispatch used
            # (global capacity / dropless); for the a2a prefill schedule the
            # drop accounting approximates the per-shard local capacity —
            # the same documented caveat as the training "ep" path.
            xs = x.reshape(B * S, D)
            capacity = (
                B * S * K if impl == "ep_grouped"
                else expert_capacity(B * S, E, K, spec.capacity_factor)
            )
            logits = xs.astype(jnp.float32) @ params["router"]
            stats = routing_stats(top_k_gating(logits, K, capacity), E)
    elif impl == "ep" and get_mesh() is not None:
        from repro.core.moe_parallel import moe_layer_ep

        if isinstance(params.get("wi"), QuantizedArray):
            # shard_map in_specs address raw arrays.  NB this fallback runs
            # inside the caller's jit, re-widening experts every step —
            # pure overhead, no bandwidth win.  The engines avoid it by
            # dequantizing ONCE at load time when cfg.moe_impl == "ep"
            # (kernel-level dequant stays the single-host serving path).
            from repro.quant.ptq import dequantize_params

            params = {**params, **dequantize_params(
                {k: params[k] for k in ("wi", "wg", "wo") if k in params}
            )}
        y, aux = moe_layer_ep(cfg, spec, params, x)
        if with_stats:
            # Telemetry for the EP path: re-run router + gating on the full
            # (replicated) token set OUTSIDE shard_map.  probs/top-k/f/P are
            # identical to the sharded dispatch; drop accounting uses the
            # global single-device capacity, so it approximates the
            # per-shard local-capacity drops (documented caveat — the
            # router matmul is T×E, negligible next to the experts).
            xs = x.reshape(B * S, D)
            capacity = expert_capacity(B * S, E, K, spec.capacity_factor)
            logits = xs.astype(jnp.float32) @ params["router"]
            stats = routing_stats(top_k_gating(logits, K, capacity), E)
    else:
        xs = x.reshape(B * S, D)
        T = B * S
        logits = xs.astype(jnp.float32) @ params["router"]
        if impl == "grouped":
            # Dropless: gate with capacity = T*K, so every assignment keeps
            # its expert by pigeonhole (keep all-True, f/P in RoutingStats
            # still report the balance the aux loss shapes).
            g = top_k_gating(logits, K, T * K)
            y = dispatch_grouped.moe_grouped(
                xs, g, E, lambda xg, te: grouped_experts_ffn(params, xg, te, spec.act)
            )
        else:
            capacity = expert_capacity(T, E, K, spec.capacity_factor)
            g = top_k_gating(logits, K, capacity)
            ef = lambda xe: experts_ffn(params, xe, spec.act)
            if impl == "einsum":
                y = dispatch_einsum.moe_einsum(xs, g, capacity, ef)
            else:  # dense mapping-table
                y = dispatch.moe_dense(xs, g, capacity, E, ef)
        aux = load_balance_loss(g.probs, g.expert_idx, E)
        if with_stats:
            stats = routing_stats(g, E)
        y = y.reshape(B, S, D)

    if spec.residual:
        # Residual-MoE (§4.1.1): fixed dense MLP branch + gated expert branch.
        y = y + mlp(params["residual"], x, spec.act)
    y = shard_hint(y, "batch", "seq", "embed")
    if with_stats:
        return y, aux, stats
    return y, aux
