"""Top-k expert gating (DeepSpeed-MoE §3.1, §5.4).

The gating pipeline is: router logits -> softmax -> top-k expert ids ->
capacity-constrained slot assignment (position-in-expert via prefix sum) ->
combine weights.  The pure-jnp implementation here is the *oracle* for the
fused Pallas gating kernel (kernels/moe_gating.py) and is itself used by the
einsum / dense dispatch paths.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Gating(NamedTuple):
    """T = tokens, K = top_k.

    expert_idx:  [T, K] int32 — chosen expert per (token, slot-k)
    combine_w:   [T, K] f32   — gate probability (0 where dropped)
    position:    [T, K] int32 — position within the expert's capacity buffer
    keep:        [T, K] bool  — False if dropped by capacity
    probs:       [T, E] f32   — full softmax (for aux loss)
    """

    expert_idx: jax.Array
    combine_w: jax.Array
    position: jax.Array
    keep: jax.Array
    probs: jax.Array


def expert_capacity(num_tokens: int, num_experts: int, top_k: int, capacity_factor: float) -> int:
    """Tokens each expert can accept (padded to a multiple of 8 ≥ 8)."""
    c = int(capacity_factor * num_tokens * top_k / num_experts)
    c = max(c, 8)
    return ((c + 7) // 8) * 8


def _positions_cumsum(flat_expert: jax.Array, E: int) -> jax.Array:
    """Prefix-sum over one-hot assignment matrix: O(T·K·E) work/memory.
    This is the textbook formulation (and the Pallas kernel's oracle)."""
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # [K*T, E]
    positions_flat = (jnp.cumsum(onehot, axis=0) - 1) * onehot
    return jnp.sum(positions_flat, axis=-1)  # [K*T]


def _positions_sort(flat_expert: jax.Array, E: int) -> jax.Array:
    """Rank-within-expert via stable argsort: O(T·K log T·K) work, O(T·K)
    memory — used for long sequences where the one-hot matrix would be
    prohibitive.  Stable sort preserves the k-major priority order."""
    TK = flat_expert.shape[0]
    order = jnp.argsort(flat_expert, stable=True)  # [TK]
    sorted_e = flat_expert[order]
    # start index of each expert's run in the sorted array
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=flat_expert.dtype), side="left")
    rank_sorted = jnp.arange(TK, dtype=jnp.int32) - group_start[sorted_e]
    pos = jnp.zeros((TK,), jnp.int32).at[order].set(rank_sorted)
    return pos


# Above this many one-hot elements, switch to the sort-based ranking.
_SORT_THRESHOLD = 1 << 22


def top_k_gating(
    logits: jax.Array,  # [T, E]
    top_k: int,
    capacity: int,
    *,
    normalize: bool = True,
    method: str = "auto",  # "auto" | "cumsum" | "sort"
) -> Gating:
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_w, expert_idx = jax.lax.top_k(probs, top_k)  # [T, K]
    if normalize and top_k > 1:
        gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # Position within each expert's buffer, computed in token-major order
    # (slot t*K+k): earlier tokens win capacity, and within a token the
    # primary expert wins first (Megatron/t5x convention; keeps the Pallas
    # kernel a single sequential sweep over token tiles).
    flat_expert = expert_idx.reshape(-1)  # [T*K], token-major
    if method == "auto":
        method = "sort" if T * top_k * E > _SORT_THRESHOLD else "cumsum"
    pos_flat = _positions_sort(flat_expert, E) if method == "sort" else _positions_cumsum(flat_expert, E)
    position = pos_flat.reshape(T, top_k)  # [T, K]

    keep = position < capacity
    combine_w = jnp.where(keep, gate_w, 0.0)
    position = jnp.where(keep, position, capacity - 1)  # clamped; masked out by combine_w/keep
    return Gating(expert_idx.astype(jnp.int32), combine_w, position.astype(jnp.int32), keep, probs)


def load_balance_stats(probs: jax.Array, expert_idx: jax.Array, num_experts: int):
    """Per-expert (f_e, P_e): fraction of primary (k=0) assignments and mean
    router probability.  Split out so expert-parallel shards can pmean these
    *linear* statistics across the EP axis before taking the product — the
    loss is nonlinear in (f, P), so averaging per-shard losses would NOT
    equal the global-batch loss."""
    T = probs.shape[0]
    primary = expert_idx[:, 0]
    f = jnp.bincount(primary, length=num_experts).astype(jnp.float32) / T
    p = jnp.mean(probs, axis=0)
    return f, p


class RoutingStats(NamedTuple):
    """Jit-returnable routing telemetry for one MoE layer (paper §3, §5:
    expert load balance is the MoE-specific serving/training signal).  All
    leaves have token-count-independent shapes, so the engines can return
    them from fixed-shape jitted steps and aggregate host-side.

    tokens_per_expert: [E] int32 — assignments KEPT per expert (all k slots)
    dropped_frac:      []  f32   — fraction of (token, k) assignments dropped
                                   by expert capacity
    entropy:           []  f32   — mean router-softmax entropy (nats);
                                   ln(E) = uniform, 0 = collapsed
    imbalance:         []  f32   — E · Σ_e f_e·P_e (the aux-loss statistic);
                                   1.0 = perfectly balanced, E = collapse
    f:                 [E] f32   — fraction of primary (k=0) assignments
    p:                 [E] f32   — mean router probability
    """

    tokens_per_expert: jax.Array
    dropped_frac: jax.Array
    entropy: jax.Array
    imbalance: jax.Array
    f: jax.Array
    p: jax.Array


def routing_stats(g: Gating, num_experts: int) -> RoutingStats:
    """RoutingStats from one layer's gating decision.  ``f``/``p`` (and the
    ``imbalance`` built from them) are exactly ``load_balance_stats`` — the
    parity tests/test_obs.py asserts — so telemetry can never drift from the
    loss the model trains against."""
    f, p = load_balance_stats(g.probs, g.expert_idx, num_experts)
    # kept assignments per expert over ALL k slots (dropped ones route to a
    # scratch bucket at index E and are cut off)
    kept_idx = jnp.where(g.keep, g.expert_idx, num_experts).reshape(-1)
    tokens_per_expert = jnp.bincount(kept_idx, length=num_experts + 1)[:num_experts]
    dropped = 1.0 - jnp.mean(g.keep.astype(jnp.float32))
    entropy = -jnp.mean(jnp.sum(g.probs * jnp.log(g.probs + 1e-9), axis=-1))
    imbalance = num_experts * jnp.sum(f * p)
    return RoutingStats(tokens_per_expert.astype(jnp.int32), dropped, entropy,
                        imbalance, f, p)


def summarize_routing(stats_tree) -> dict:
    """Host-side per-layer aggregation of a routing-stats pytree as returned
    by ``forward(..., return_routing=True)`` / the engines' decode steps:
    ``{seg: {pos: RoutingStats with [repeats, ...] leaves}}``.

    Returns plain floats/lists (JSON-ready): overall means across MoE layers
    plus a per-layer breakdown keyed ``"{seg}/{pos}[repeat]"``."""
    import numpy as np

    per_layer = {}
    for seg in sorted(stats_tree):
        for pos in sorted(stats_tree[seg]):
            st = stats_tree[seg][pos]
            reps = np.asarray(st.dropped_frac).shape[0]
            tpe = np.asarray(st.tokens_per_expert)
            for r in range(reps):
                per_layer[f"{seg}/{pos}[{r}]"] = {
                    "dropped_frac": float(np.asarray(st.dropped_frac)[r]),
                    "entropy": float(np.asarray(st.entropy)[r]),
                    "imbalance": float(np.asarray(st.imbalance)[r]),
                    "tokens_per_expert": tpe[r].tolist(),
                    "max_expert_load": (float(tpe[r].max() / max(tpe[r].sum(), 1))),
                }
    n = max(len(per_layer), 1)
    return {
        "moe_layers": len(per_layer),
        "dropped_frac": sum(v["dropped_frac"] for v in per_layer.values()) / n,
        "entropy": sum(v["entropy"] for v in per_layer.values()) / n,
        "imbalance": sum(v["imbalance"] for v in per_layer.values()) / n,
        "per_layer": per_layer,
    }


def load_balance_loss(probs: jax.Array, expert_idx: jax.Array, num_experts: int) -> jax.Array:
    """Switch-Transformer auxiliary loss: E * sum_e f_e * P_e (paper Table 1:
    'MoE loss coefficient' scales this in the total loss).  f_e counts primary
    (k=0) assignments; P_e is the mean router probability."""
    f, p = load_balance_stats(probs, expert_idx, num_experts)
    return num_experts * jnp.sum(f * p)


def router_z_loss(logits: jax.Array) -> jax.Array:
    """Router z-loss (ST-MoE): discourages large router logits. Optional."""
    z = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    return jnp.mean(z**2)
