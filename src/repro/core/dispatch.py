"""Dense mapping-table dispatch — the paper's §5.4 optimization.

Instead of one-hot einsums (S·E·M·c_e), tokens are routed with an explicit
token→(expert, slot) mapping table realised as scatter/gather, reducing the
data-movement complexity to S·M·c_e — the paper reports >6× MoE-kernel latency
reduction from this (together with gating fusion, which the Pallas kernel in
kernels/moe_gating.py provides on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gating import Gating


def flat_slot(g: Gating, capacity: int, num_experts: int) -> jax.Array:
    """[T, K] flattened destination slot in the [E*C (+1 trash)] buffer."""
    slot = g.expert_idx * capacity + g.position
    return jnp.where(g.keep, slot, num_experts * capacity)  # dropped -> trash row


def dispatch_dense(x: jax.Array, g: Gating, capacity: int, num_experts: int) -> jax.Array:
    """x: [T, D] -> expert buffers [E, C, D] via scatter (mapping table)."""
    T, D = x.shape
    K = g.expert_idx.shape[1]
    dest = flat_slot(g, capacity, num_experts).reshape(-1)  # [T*K]
    src = jnp.repeat(x, K, axis=0)  # [T*K, D] (cheap: K is 1..8)
    buf = jnp.zeros((num_experts * capacity + 1, D), x.dtype)
    buf = buf.at[dest].set(src, mode="drop", unique_indices=False)
    return buf[:-1].reshape(num_experts, capacity, D)


def combine_dense(ye: jax.Array, g: Gating, capacity: int, num_experts: int) -> jax.Array:
    """ye: [E, C, D] -> [T, D]: gather each token's expert outputs and mix
    with the gate weights."""
    T, K = g.expert_idx.shape
    D = ye.shape[-1]
    flat = jnp.concatenate([ye.reshape(num_experts * capacity, D), jnp.zeros((1, D), ye.dtype)])
    dest = flat_slot(g, capacity, num_experts)  # [T, K]
    gathered = flat[dest]  # [T, K, D]
    w = g.combine_w.astype(jnp.float32)[..., None]
    return jnp.sum(gathered.astype(jnp.float32) * w, axis=1).astype(ye.dtype)


def moe_dense(x: jax.Array, g: Gating, capacity: int, num_experts: int, expert_fn):
    """Dense-dispatch MoE: scatter -> expert_fn([E,C,D]) -> gather-combine.

    This is the GSPMD (non-shard_map) path; the EP implementation calls
    dispatch_dense/combine_dense directly inside its shard_map body instead.
    """
    from repro.parallel.sharding import get_mesh

    _mesh = get_mesh()
    if _mesh is not None and _mesh.devices.size > 1:
        # Documented XLA SPMD hazard: the partitioner mis-partitions the
        # combine gather over the expert outputs' pending partial sums (and
        # the grad program double-reduces regardless of the forward pin
        # below).  Fail loudly instead of silently returning wrong numbers.
        raise ValueError(
            "moe_impl='dense' is numerically unsafe under a multi-device "
            f"mesh ({_mesh.devices.size} devices): the XLA SPMD partitioner "
            "mis-partitions the combine gather / double-reduces under grad. "
            "Use moe_impl='ep' (training) or the serving EP schedules "
            "('ep_serve'/'ep_grouped' via cfg.ep_mesh), or 'einsum'/'grouped' "
            "for replicated execution."
        )
    xe = dispatch_dense(x, g, capacity, num_experts)
    ye = expert_fn(xe)
    # Pin the expert outputs to a concrete replicated sharding BEFORE the
    # combine gather.  With d_ff tensor-sliced over 'model', ye carries a
    # pending cross-shard partial sum, and older XLA SPMD partitioners
    # mis-partition a gather over such an operand (observed on the CPU
    # backend: combine returned exactly TP× the correct values; the grad
    # program stays wrong regardless, which is why multi-device training
    # uses the shard_map EP path, not this one).  No-op without a mesh.
    mesh = _mesh
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec

        ye = jax.lax.with_sharding_constraint(
            ye, NamedSharding(mesh, PartitionSpec(None, None, None))
        )
    return combine_dense(ye, g, capacity, num_experts)
