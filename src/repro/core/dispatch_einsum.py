"""Sparse one-hot einsum dispatch — the *baseline* the paper optimizes away.

DeepSpeed-MoE §5.4: conventional MoE implementations express token routing as
einsums against one-hot dispatch/combine tensors, costing S·E·M·c_e (E× more
work than necessary, "cubic" in the paper's terms).  We implement it faithfully
because every DS-MoE kernel claim (the 6× MoE-kernel latency reduction) is
measured *against this*.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gating import Gating


def dispatch_combine_tensors(g: Gating, capacity: int):
    """Build the classic [T, E, C] dispatch (bool) and combine (f32) tensors."""
    T, K = g.expert_idx.shape
    E = g.probs.shape[-1]
    eo = jax.nn.one_hot(g.expert_idx, E, dtype=jnp.float32)  # [T, K, E]
    po = jax.nn.one_hot(g.position, capacity, dtype=jnp.float32)  # [T, K, C]
    keep = g.keep.astype(jnp.float32)[..., None, None]
    dc = jnp.einsum("tke,tkc->tkec", eo, po) * keep  # [T, K, E, C]
    combine = jnp.sum(dc * g.combine_w[..., None, None], axis=1)  # [T, E, C]
    dispatch = jnp.sum(dc, axis=1) > 0  # [T, E, C] bool
    return dispatch, combine


def moe_einsum(x: jax.Array, g: Gating, capacity: int, expert_fn):
    """x: [T, D].  expert_fn: [E, C, D] -> [E, C, D] (per-expert FFN).

    Sparse-einsum dispatch (S·E·M·c) -> experts -> sparse-einsum combine.
    """
    dispatch, combine = dispatch_combine_tensors(g, capacity)
    xe = jnp.einsum("td,tec->ecd", x.astype(jnp.float32), dispatch.astype(jnp.float32))
    xe = xe.astype(x.dtype)
    ye = expert_fn(xe)  # [E, C, D]
    y = jnp.einsum("ecd,tec->td", ye.astype(jnp.float32), combine)
    return y.astype(x.dtype)


def moe_einsum_dropless(x: jax.Array, g: Gating, expert_fn):
    """Dropless oracle for the grouped path (core/dispatch_grouped.py): the
    same one-hot einsum dispatch, but with ``capacity = T*K`` — every
    assignment fits by pigeonhole, so no token is ever dropped regardless of
    routing skew.  ``g`` must have been gated with that capacity (keep
    all-True).  O(T·E·TK·D) — a correctness reference, never a serving path.
    """
    T, K = g.expert_idx.shape
    return moe_einsum(x, g, T * K, expert_fn)
