"""Expert-parallel MoE under shard_map — DeepSpeed-MoE §5.2-5.3 on a TPU mesh.

Parallelism layout (DESIGN.md §4), mesh (pod, data=16, model=16):

  tokens   x   : P(('pod','data'), None, None)   — batch over pod×data
  router       : replicated
  expert wi/wo : P('data', None, 'model')        — EP over 'data' (=16),
                                                   expert-*slicing* over 'model'
  y            : P(('pod','data'), None, None)

The dispatch all-to-all runs over **'data' only** — i.e. only among devices
sharing the same tensor-parallel ('model') rank.  This is precisely the
paper's *parallelism-coordinated communication* (§5.3, Fig. 9): activations
are replicated across tensor-parallel ranks, so the a2a group size is
p/L (=16) instead of p (=256), and the expert-slicing reduction is a single
psum over 'model' afterwards.  Across pods, experts are replicated (pure DP),
matching the paper's "data parallelism across nodes" for inference scaling;
the hierarchical variant (parallel/collectives.py) factors the a2a instead.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import FFNSpec, ModelConfig
from repro.core.dispatch import combine_dense, dispatch_dense
from repro.core.gating import expert_capacity, load_balance_loss, top_k_gating
from repro.parallel.compat import axis_size, shard_map
from repro.parallel.sharding import get_mesh

EP_AXIS = "data"
TP_AXIS = "model"


def _bwd_cast(x):
    """When the bf16-backward perf toggle is on, pin the cotangent dtype to
    the primal dtype at the communication boundaries of the MoE block —
    combine_dense does f32 math whose cotangents would otherwise flow
    through the expert-slicing psum and both all-to-alls at 4 bytes/el
    (EXPERIMENTS.md §Perf, kimi-train iteration)."""
    from repro.models.transformer import BF16_BWD

    if BF16_BWD[0]:
        from repro.models.modules import grad_cast

        return grad_cast(x)
    return x


def _axis_in_mesh(mesh, name: str) -> bool:
    return name in mesh.axis_names


# NOTE (EXPERIMENTS.md §Perf, refuted hypothesis): sharding the token dim
# over the TP axis inside the MoE block ("sequence-parallel dispatch") would
# shrink the capacity buffers 16x, but it is INCOMPATIBLE with expert-slicing:
# the F-partial outputs psum'd over 'model' must correspond to the SAME
# tokens on every TP rank.  Fixing it requires either unsliced experts
# (16x expert memory — infeasible at 1T params) or an extra all-gather that
# returns the traffic.  Kept as a negative result.


# Cross-pod expert parallelism (paper §5.3 hierarchical all-to-all, Fig. 8):
# EP spans ('pod','data') = 32 shards, expert memory per pod halves, and the
# dispatch exchange runs as intra-pod a2a (fast ICI) + layout transform +
# inter-pod a2a (slow DCI).  Enabled via launch/dryrun --train-opt ep_pod.
EP_POD = [False]


def set_ep_pod(on: bool) -> None:
    EP_POD[0] = bool(on)


def _moe_body(cfg: ModelConfig, spec: FFNSpec, mesh, hier: bool, x_loc, router, wi, wg, wo):
    """Per-device body.  x_loc: [B_loc, S, D] (replicated over 'model').
    wi: [E_loc, D, F_loc], wo: [E_loc, F_loc, D]."""
    from repro.parallel.collectives import (
        hierarchical_all_to_all,
        hierarchical_all_to_all_back,
    )

    B_loc, S, D = x_loc.shape
    E = spec.num_experts
    K = spec.top_k
    ep = axis_size(EP_AXIS) * (axis_size("pod") if hier else 1)
    E_loc = E // ep
    T_loc = B_loc * S
    cap = expert_capacity(T_loc, E, K, spec.capacity_factor)

    xs = _bwd_cast(x_loc.reshape(T_loc, D))
    logits = xs.astype(jnp.float32) @ router
    g = top_k_gating(logits, K, cap)

    # Local scatter into [E, cap, D] buffers (dense mapping table, §5.4).
    buf = dispatch_dense(xs, g, cap, E)

    if hier:
        # two-stage hierarchical exchange: intra-pod ('data') then inter-pod
        recv = hierarchical_all_to_all(buf, EP_AXIS, "pod")
    else:
        # Coordinated all-to-all over the EP axis only (groups of size p/L).
        recv = jax.lax.all_to_all(buf, EP_AXIS, split_axis=0, concat_axis=1, tiled=True)
    recv = _bwd_cast(recv)
    # recv: [E_loc, ep*cap, D]

    # Expert-sliced grouped GEMMs; psum over 'model' completes the slicing.
    h = jnp.einsum("ecd,edf->ecf", recv, wi)
    if spec.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, wg)) * h
    elif spec.act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    out = jnp.einsum("ecf,efd->ecd", h, wo)
    if _axis_in_mesh(mesh, TP_AXIS):
        out = jax.lax.psum(out, TP_AXIS)
    out = _bwd_cast(out)

    # Return all-to-all, then local combine.
    if hier:
        back = hierarchical_all_to_all_back(out, EP_AXIS, "pod")
    else:
        back = jax.lax.all_to_all(out, EP_AXIS, split_axis=1, concat_axis=0, tiled=True)
    back = _bwd_cast(back)
    y = combine_dense(back, g, cap, E).reshape(B_loc, S, D)

    # Global-batch load balance: pmean the per-expert stats (linear in the
    # tokens) across EP shards, THEN take the product — numerically identical
    # to the single-device dense path (per-shard losses averaged would not
    # be, the loss being nonlinear in f and P).
    from repro.core.gating import load_balance_stats

    f, p = load_balance_stats(g.probs, g.expert_idx, E)
    axes = [EP_AXIS] + (["pod"] if _axis_in_mesh(mesh, "pod") else [])
    f = jax.lax.pmean(f, tuple(axes))
    p = jax.lax.pmean(p, tuple(axes))
    aux = E * jnp.sum(f * p)
    return y, aux


def _moe_body_allgather(cfg: ModelConfig, spec: FFNSpec, mesh, x_loc, router, wi, wg, wo):
    """Small-batch (decode) schedule: all-gather the few tokens across the EP
    axis, compute local experts on the full token set, reduce-scatter the
    combined output back.  Communication is O(tokens·D) per layer instead of
    O(E·capacity·D) — the capacity-padded a2a buffers that dominate the a2a
    schedule when tokens-per-shard ≪ experts (EXPERIMENTS.md §Perf, kimi
    decode iteration 1)."""
    B_loc, S, D = x_loc.shape
    E, K = spec.num_experts, spec.top_k
    ep = axis_size(EP_AXIS)
    E_loc = E // ep
    my_ep = jax.lax.axis_index(EP_AXIS)

    # gather all tokens in the EP group: [T_all, D]
    xs = x_loc.reshape(B_loc * S, D)
    x_all = jax.lax.all_gather(xs, EP_AXIS, axis=0, tiled=True)
    T_all = x_all.shape[0]

    logits = x_all.astype(jnp.float32) @ router
    cap = expert_capacity(T_all, E, K, spec.capacity_factor)
    g = top_k_gating(logits, K, cap)

    # keep only assignments routed to OUR experts; everything else -> trash row
    lo, hi = my_ep * E_loc, (my_ep + 1) * E_loc
    mine = (g.expert_idx >= lo) & (g.expert_idx < hi)
    g_local = g._replace(
        expert_idx=jnp.where(mine, g.expert_idx - lo, 0),
        keep=g.keep & mine,
        combine_w=jnp.where(mine, g.combine_w, 0.0),
    )
    buf = dispatch_dense(x_all, g_local, cap, E_loc)  # [E_loc, cap, D]

    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    if spec.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * h
    elif spec.act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    out = jnp.einsum("ecf,efd->ecd", h, wo)
    if _axis_in_mesh(mesh, TP_AXIS):
        out = jax.lax.psum(out, TP_AXIS)  # expert-slicing reduction

    y_partial = combine_dense(out, g_local, cap, E_loc)  # [T_all, D], partial
    # sum expert contributions across EP shards and return each shard its slice
    y = jax.lax.psum_scatter(y_partial, EP_AXIS, scatter_dimension=0, tiled=True)

    aux = load_balance_loss(g.probs, g.expert_idx, E)
    # numerically identical on every EP shard (computed from the gathered
    # token set); the pmean just certifies replication for shard_map's vma.
    axes = [EP_AXIS] + (["pod"] if _axis_in_mesh(mesh, "pod") else [])
    aux = jax.lax.pmean(aux, tuple(axes))
    return y.reshape(B_loc, S, D), aux


def moe_layer_ep(cfg: ModelConfig, spec: FFNSpec, params: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    mesh = get_mesh()
    assert mesh is not None, "moe_impl='ep' requires an active mesh (parallel.sharding.use_mesh)"
    has_pod = _axis_in_mesh(mesh, "pod")
    has_tp = _axis_in_mesh(mesh, TP_AXIS)
    batch_axes = (("pod", EP_AXIS) if has_pod else EP_AXIS)

    sizes0 = dict(zip(mesh.axis_names, mesh.devices.shape))
    hier = (
        EP_POD[0]
        and has_pod
        and spec.num_experts % (sizes0[EP_AXIS] * sizes0.get("pod", 1)) == 0
    )
    ep_axes = ("pod", EP_AXIS) if hier else EP_AXIS

    x_spec = P(batch_axes, None, None)
    router_spec = P(None, None)
    wi_spec = P(ep_axes, None, TP_AXIS if has_tp else None)
    wo_spec = P(ep_axes, TP_AXIS if has_tp else None, None)

    wg = params.get("wg", params["wi"])  # placeholder when act != swiglu

    # Schedule selection: with few tokens per EP shard (decode), the
    # capacity-padded a2a buffers (E × cap × D) dwarf the actual token
    # traffic; switch to the all-gather/reduce-scatter schedule.
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = sizes[EP_AXIS]
    dp = ep * (sizes.get("pod", 1) if has_pod else 1)
    t_loc = (x.shape[0] // max(dp, 1)) * x.shape[1]
    if t_loc * spec.top_k <= spec.num_experts:
        body = partial(_moe_body_allgather, cfg, spec, mesh)
    else:
        body = partial(_moe_body, cfg, spec, mesh, hier)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, router_spec, wi_spec, wi_spec, wo_spec),
        out_specs=(x_spec, P()),
        check_vma=True,
    )
    # Pin every operand to its in_spec with an explicit constraint before the
    # shard_map boundary.  Without this, older XLA SPMD partitioners can feed
    # the manual computation a mis-resharded operand when the producer is
    # itself a partitioned gather/slice (observed on the CPU backend: a
    # sharded-embedding lookup flowing straight into this shard_map produced
    # O(1)-wrong expert outputs); the constraint forces a fully materialized
    # reshard first and is a no-op where the partitioner already agrees.
    constrain = lambda v, s: jax.lax.with_sharding_constraint(
        v, jax.sharding.NamedSharding(mesh, s)
    )
    return fn(
        constrain(x, x_spec),
        constrain(params["router"], router_spec),
        constrain(params["wi"], wi_spec),
        constrain(wg, wi_spec),
        constrain(params["wo"], wo_spec),
    )
