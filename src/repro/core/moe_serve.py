"""Expert-parallel MoE schedules for the *serving* engines (paper §5.2-5.3).

The training EP layer (core/moe_parallel.py) shards the token batch over the
mesh — fine for large train batches, impossible for serving where a decode
tick carries `slots` tokens (2-8) and a prefill chunk a few dozen rows,
neither divisible by the mesh.  The two schedules here keep the engines'
fixed shapes and are built for *token-exact parity* with the single-device
engine (the dist tier asserts bitwise-identical greedy output):

  * **replicated-token** (decode / grouped): every shard sees the full token
    set and runs the GLOBAL gating (identical on all shards — same capacity,
    same drops), computes only its local expert slice, and the full expert
    OUTPUT buffer is reassembled with all_gather/psum *before* a replicated
    combine.  Each output row has exactly one non-zero contributor shard, so
    the reduction is exact (0 + a == a in fp) and the combine is literally
    the single-device combine on the same values — bitwise parity even under
    capacity drops.  Communication is O(E·cap·D) (dense) or O(Ct·D)
    (grouped) per layer; at decode token counts this is the all-gather
    schedule of EXPERIMENTS.md run on the output side instead of the input
    side, trading a little bandwidth for exactness.

  * **a2a** (dense kernel, chunk prefill): tokens are zero-padded at the END
    to a mesh multiple, sharded over the EP axes, and exchanged with the
    flat or (two-axis mesh) hierarchical two-hop all-to-all
    (parallel/collectives.py, paper Fig. 8) — the paper's actual serving
    dataflow.  Capacity is per-shard, so parity with the single-device
    engine is exact only when nothing is dropped (trailing zero-pad rows
    cannot displace real tokens: capacity slots are claimed in token-major
    order); the dist tier runs it with a headroom capacity_factor.

Expert weights arrive pre-sharded [E_loc, D, F] per device (serving/ep.py
placement); the grouped/quantized expert kernels run per-device inside the
shard_map body.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import FFNSpec, ModelConfig
from repro.core.dispatch import combine_dense, dispatch_dense
from repro.core.dispatch_grouped import GROUPED_TILE, grouped_layout
from repro.core.gating import expert_capacity, load_balance_loss, load_balance_stats, top_k_gating
from repro.parallel.compat import axis_size, shard_map
from repro.parallel.sharding import get_mesh, get_rules


def serve_ep_axes(num_experts: int) -> Optional[Tuple[str, ...]]:
    """EP mesh axes for serving, or None when the ambient mesh can't shard
    this expert count.  Mirrors parallel/params._pick: the 'expert' rule's
    axes must ALL be present in the mesh (all-or-nothing) and their product
    must divide E — so the layer's dispatch agrees with the weight
    placement."""
    mesh = get_mesh()
    if mesh is None:
        return None
    axes = get_rules().get("expert")
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = 1
    for a in axes:
        if a not in sizes:
            return None
        ep *= sizes[a]
    if ep <= 1 or num_experts % ep != 0:
        return None
    return tuple(axes)


def _ep_rank(axes) -> jax.Array:
    """Linear rank within the EP group, major-first — the same order the
    all_gather/all_to_all collectives concatenate over a multi-axis group,
    so shard r owns experts [r*E_loc, (r+1)*E_loc)."""
    r = jnp.int32(0)
    for a in axes:
        r = r * axis_size(a) + jax.lax.axis_index(a)
    return r


def _ffn_params(wi, wg, wo, act):
    p = {"wi": wi, "wo": wo}
    if act == "swiglu":
        p["wg"] = wg
    return p


def _body_replicated_dense(cfg: ModelConfig, spec: FFNSpec, axes, x, router, wi, wg, wo):
    """Replicated-token schedule, capacity-dispatch kernel.  x: [B, S, D]
    replicated; wi/wo: local expert slice [E_loc, ...]."""
    from repro.core.moe import experts_ffn

    B, S, D = x.shape
    E, K = spec.num_experts, spec.top_k
    ep = 1
    for a in axes:
        ep *= axis_size(a)
    E_loc = E // ep
    T = B * S
    cap = expert_capacity(T, E, K, spec.capacity_factor)

    xs = x.reshape(T, D)
    logits = xs.astype(jnp.float32) @ router
    g = top_k_gating(logits, K, cap)  # GLOBAL gating — identical on every shard

    # Keep only assignments routed to OUR experts (moe_parallel all-gather
    # schedule's masking); position/keep come from the global gating, so the
    # local buffer rows are bit-identical to the corresponding rows of the
    # single-device [E, cap, D] buffer.
    lo = _ep_rank(axes) * E_loc
    mine = (g.expert_idx >= lo) & (g.expert_idx < lo + E_loc)
    g_loc = g._replace(
        expert_idx=jnp.where(mine, g.expert_idx - lo, 0),
        keep=g.keep & mine,
        combine_w=jnp.where(mine, g.combine_w, 0.0),
    )
    buf = dispatch_dense(xs, g_loc, cap, E_loc)  # [E_loc, cap, D]
    out_loc = experts_ffn(_ffn_params(wi, wg, wo, spec.act), buf, spec.act)

    # Reassemble the FULL [E, cap, D] expert-output buffer BEFORE combining:
    # major-first gather order matches lo = rank*E_loc, and each expert row
    # exists on exactly one shard, so this is exact reconstruction — the
    # combine below then runs replicated on the same values and global
    # gating as the single-device engine (bitwise parity, drops included).
    out = jax.lax.all_gather(out_loc, axes, axis=0, tiled=True)  # [E, cap, D]
    y = combine_dense(out, g, cap, E).reshape(B, S, D)

    aux = load_balance_loss(g.probs, g.expert_idx, E)
    aux = jax.lax.pmean(aux, axes)  # identical per shard; certifies replication
    return y, aux


def _body_replicated_grouped(cfg: ModelConfig, spec: FFNSpec, axes, x, router, wi, wg, wo):
    """Replicated-token schedule, dropless grouped kernel: the global grouped
    layout is computed on every shard, non-local tiles are masked, the local
    grouped kernel runs on its tile subset, and the [Ct, D] expert-output
    buffer is psum-reassembled before the replicated scatter-add combine
    (one non-zero contributor per row → exact)."""
    from repro.core.moe import grouped_experts_ffn

    B, S, D = x.shape
    E, K = spec.num_experts, spec.top_k
    ep = 1
    for a in axes:
        ep *= axis_size(a)
    E_loc = E // ep
    T = B * S
    TK = T * K

    xs = x.reshape(T, D)
    logits = xs.astype(jnp.float32) @ router
    g = top_k_gating(logits, K, TK)  # dropless global gating
    layout = grouped_layout(g, E, tile=GROUPED_TILE)
    token = jnp.arange(TK, dtype=jnp.int32) // K
    Ct = layout.tile_expert.shape[0] * GROUPED_TILE
    xg = jnp.zeros((Ct, D), xs.dtype).at[layout.dst].set(xs[token])

    # Mask tiles owned by other shards: zero their rows, clamp their expert
    # id into the local window so the per-device kernel never indexes out of
    # its [E_loc] weight slice.  (Trailing padding tiles clamp to E-1 in the
    # layout; no dst row points at them, so their owner is irrelevant.)
    lo = _ep_rank(axes) * E_loc
    tile_mine = (layout.tile_expert >= lo) & (layout.tile_expert < lo + E_loc)
    te_loc = jnp.where(tile_mine, layout.tile_expert - lo, 0).astype(jnp.int32)
    row_mine = jnp.repeat(tile_mine, GROUPED_TILE)  # [Ct]
    xg_loc = jnp.where(row_mine[:, None], xg, 0)
    yg_loc = grouped_experts_ffn(_ffn_params(wi, wg, wo, spec.act), xg_loc, te_loc, spec.act)
    yg_loc = jnp.where(row_mine[:, None], yg_loc.astype(jnp.float32), 0.0)
    yg = jax.lax.psum(yg_loc, axes)  # [Ct, D] f32, exact (single contributor/row)

    # Replicated combine — moe_grouped's scatter-add on the reassembled
    # buffer (already f32, matching its accumulation discipline).
    w = g.combine_w.reshape(-1).astype(jnp.float32)
    y = jnp.zeros((T, D), jnp.float32).at[token].add(w[:, None] * yg[layout.dst])
    y = y.astype(xs.dtype).reshape(B, S, D)

    aux = load_balance_loss(g.probs, g.expert_idx, E)
    aux = jax.lax.pmean(aux, axes)
    return y, aux


def _body_a2a(cfg: ModelConfig, spec: FFNSpec, axes, x_loc, router, wi, wg, wo):
    """Token-sharded a2a schedule (paper's serving dataflow).  x_loc:
    [T_loc, D] — this shard's slice of the end-padded token set."""
    from repro.core.moe import experts_ffn
    from repro.parallel.collectives import (
        flat_all_to_all,
        flat_all_to_all_back,
        hierarchical_all_to_all,
        hierarchical_all_to_all_back,
    )

    T_loc, D = x_loc.shape
    E, K = spec.num_experts, spec.top_k
    ep = 1
    for a in axes:
        ep *= axis_size(a)
    E_loc = E // ep
    cap = expert_capacity(T_loc, E, K, spec.capacity_factor)

    logits = x_loc.astype(jnp.float32) @ router
    g = top_k_gating(logits, K, cap)
    buf = dispatch_dense(x_loc, g, cap, E)  # [E, cap, D]

    if len(axes) == 2:
        # two-hop hierarchical exchange (Fig. 8): intra-host axis first,
        # layout transform, then the inter-host hop.  Expert ids are laid
        # out outer-major, matching _ep_rank's ordering.
        recv = hierarchical_all_to_all(buf, axes[1], axes[0])
    else:
        recv = flat_all_to_all(buf, axes)
    # recv: [E_loc, ep*cap, D]
    out = experts_ffn(_ffn_params(wi, wg, wo, spec.act), recv, spec.act)
    if len(axes) == 2:
        back = hierarchical_all_to_all_back(out, axes[1], axes[0])
    else:
        back = flat_all_to_all_back(out, axes)
    y = combine_dense(back, g, cap, E)  # [T_loc, D]

    # global-batch aux: pmean the linear per-expert stats, then the product
    f, p = load_balance_stats(g.probs, g.expert_idx, E)
    f = jax.lax.pmean(f, axes)
    p = jax.lax.pmean(p, axes)
    aux = E * jnp.sum(f * p)
    return y, aux


def moe_layer_ep_serve(
    cfg: ModelConfig,
    spec: FFNSpec,
    params: dict,
    x: jax.Array,  # [B, S, D]
    *,
    kernel: str = "dense",  # "dense" | "grouped"
) -> Tuple[jax.Array, jax.Array]:
    """Serving EP layer.  Caller (core/moe.py) guarantees an active mesh
    whose 'expert' rule axes divide ``spec.num_experts`` (serve_ep_axes)."""
    mesh = get_mesh()
    axes = serve_ep_axes(spec.num_experts)
    assert mesh is not None and axes is not None, "moe_layer_ep_serve requires a usable EP mesh"

    B, S, D = x.shape
    T = B * S
    ep = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in axes:
        ep *= sizes[a]

    wg = params.get("wg", params["wi"])  # placeholder when act != swiglu
    w_spec = P(axes if len(axes) > 1 else axes[0], None, None)
    rep = P()
    constrain = lambda v, s: jax.lax.with_sharding_constraint(
        v, jax.sharding.NamedSharding(mesh, s)
    )
    operands = (
        constrain(params["router"], P(None, None)),
        constrain(params["wi"], w_spec),
        constrain(wg, w_spec),
        constrain(params["wo"], w_spec),
    )

    # Schedule selection (moe_parallel's rule): with few tokens per shard the
    # capacity-padded a2a buffers dwarf the token traffic — and the grouped
    # kernel's layout is global by construction — so both take the
    # replicated-token schedule; batched/chunked prefill with the dense
    # kernel takes the paper's a2a exchange.
    if kernel == "grouped" or T * spec.top_k <= spec.num_experts:
        body = (
            _body_replicated_grouped if kernel == "grouped" else _body_replicated_dense
        )
        fn = shard_map(
            partial(body, cfg, spec, axes),
            mesh=mesh,
            in_specs=(rep, P(None, None), w_spec, w_spec, w_spec),
            out_specs=(rep, rep),
            check_vma=False,
        )
        return fn(constrain(x, rep), *operands)

    # a2a schedule: flatten, zero-pad at the END to a mesh multiple (trailing
    # pads can never displace a real token's capacity slot — slots are
    # claimed in token-major order), shard tokens over the EP axes.
    xs = x.reshape(T, D)
    Tp = -(-T // ep) * ep
    if Tp != T:
        xs = jnp.concatenate([xs, jnp.zeros((Tp - T, D), xs.dtype)])
    tok_spec = P(axes if len(axes) > 1 else axes[0], None)
    fn = shard_map(
        partial(_body_a2a, cfg, spec, axes),
        mesh=mesh,
        in_specs=(tok_spec, rep, w_spec, w_spec, w_spec),
        out_specs=(tok_spec, rep),
        check_vma=False,
    )
    y, aux = fn(constrain(xs, tok_spec), *operands)
    return y[:T].reshape(B, S, D), aux
