"""MoQ-style post-training quantization (DeepSpeed-MoE §4, "3.7x smaller";
Kim et al. 2022): weight-only int8 / int4 expert compression for serving.

Public surface:

  * :class:`~repro.quant.qarrays.QuantizedArray` — values+scales pytree node
    that flows through ``jax.jit`` / ``jax.lax.scan`` / the checkpoint
    manifest exactly like a plain array.
  * :func:`~repro.quant.ptq.quantize_params` — policy-driven PTQ over a
    params pytree (experts-only / experts+attention / all matmul weights).
  * ``kernels/expert_mlp_quant.py`` — Pallas grouped expert MLP that
    dequantizes int8 weight tiles in VMEM right before the MXU dot.
"""
from repro.quant.qarrays import QuantizedArray, materialize
from repro.quant.ptq import (
    dequantize_params,
    prepare_params_for_serving,
    quantize_params,
    quantized_leaf_paths,
    tree_bytes,
)

__all__ = [
    "QuantizedArray",
    "materialize",
    "quantize_params",
    "dequantize_params",
    "prepare_params_for_serving",
    "quantized_leaf_paths",
    "tree_bytes",
]
