"""MoQ-style post-training quantization (DeepSpeed-MoE §4, "3.7x smaller";
Kim et al. 2022) plus serving-time KV-cache quantization (§5 memory-bound
decode): weight-only int8 / int4 expert compression and an int8 KV cache.

Public surface:

  * :class:`~repro.quant.qarrays.QuantizedArray` — values+scales pytree node
    that flows through ``jax.jit`` / ``jax.lax.scan`` / the checkpoint
    manifest exactly like a plain array.
  * :func:`~repro.quant.ptq.quantize_params` — policy-driven PTQ over a
    params pytree (experts-only / experts+attention / all matmul weights).
  * :class:`~repro.quant.kv.QuantizedKV` — int8 KV-cache tensor with
    per-(timestep, head) scales, quantized on write during prefill/decode
    (``kv_cache_bits`` knob on QuantConfig / EngineConfig / serve.py).
  * ``kernels/expert_mlp_quant.py`` / ``kernels/attention_quant.py`` —
    Pallas kernels that dequantize int8 weight / K-V tiles in VMEM right
    before their MXU dots.
"""
from repro.quant.qarrays import QuantizedArray, materialize
from repro.quant.kv import QuantizedKV, kv_cache_bytes, kv_quantize_values, materialize_kv
from repro.quant.ptq import (
    dequantize_params,
    prepare_params_for_serving,
    quantize_params,
    quantized_leaf_paths,
    tree_bytes,
)

__all__ = [
    "QuantizedArray",
    "QuantizedKV",
    "materialize",
    "materialize_kv",
    "kv_quantize_values",
    "kv_cache_bytes",
    "quantize_params",
    "dequantize_params",
    "prepare_params_for_serving",
    "quantized_leaf_paths",
    "tree_bytes",
]
