"""``QuantizedArray``: a weight tensor stored as integer values + f32 scales.

Registered as a JAX pytree node (with attr keys, so the checkpoint manifest
sees ``.../wi/q`` and ``.../wi/scale`` leaves), which makes quantized params
flow through ``jax.jit``, ``jax.lax.scan`` over stacked layer params, and
``checkpoint/ckpt.py`` without any special-casing: every transformation that
slices / stacks the leading (scan) axis slices ``q`` and ``scale``
consistently because both carry the same leading dims.

Quantization is symmetric:

  * int8  — per-output-channel: one scale per output column, amax taken over
    the contraction axes (``reduce_axes``).
  * int4  — group-wise along the first contraction axis (``group_size``
    inputs share a scale), packed two nibbles per int8 byte along that axis.
    ``group_size=0`` degrades to per-output-channel int4.

``reduce_axes`` are stored relative to the *end* of the shape (negative), so
metadata stays valid when scan/vmap adds or strips leading stack axes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_QMAX = {8: 127.0, 4: 7.0}


def _norm_neg_axis(axis: int, ndim: int) -> int:
    """Normalize to a negative axis index (stable under added leading dims)."""
    ax = axis % ndim
    return ax - ndim


@jax.tree_util.register_pytree_with_keys_class
class QuantizedArray:
    """values (``q``, int8 storage) + scales (``scale``, f32) + metadata."""

    __slots__ = ("q", "scale", "bits", "group_size", "axis", "orig_dtype")

    def __init__(self, q, scale, bits: int, group_size: int, axis: int, orig_dtype: str):
        self.q = q
        self.scale = scale
        self.bits = bits  # 8 | 4
        self.group_size = group_size  # 0 = per-output-channel
        self.axis = axis  # negative: pack/group (first contraction) axis
        self.orig_dtype = orig_dtype

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten_with_keys(self):
        children = (
            (jax.tree_util.GetAttrKey("q"), self.q),
            (jax.tree_util.GetAttrKey("scale"), self.scale),
        )
        return children, (self.bits, self.group_size, self.axis, self.orig_dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q, scale, *aux)

    # -- array-ish surface --------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        s = list(jnp.shape(self.q))
        if self.bits == 4:
            s[self.axis] *= 2  # two nibbles per stored byte along the pack axis
        return tuple(s)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def dtype(self):
        return jnp.dtype(self.orig_dtype)

    @property
    def nbytes(self) -> int:
        return int(self.q.size * self.q.dtype.itemsize + self.scale.size * self.scale.dtype.itemsize)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"QuantizedArray(int{self.bits}, shape={self.shape}, "
            f"group_size={self.group_size}, axis={self.axis}, orig={self.orig_dtype})"
        )

    # -- numerics -----------------------------------------------------------
    @classmethod
    def quantize(
        cls,
        w: jax.Array,
        *,
        bits: int = 8,
        group_size: int = 0,
        reduce_axes: Tuple[int, ...] = (-2,),
    ) -> "QuantizedArray":
        """Symmetric weight quantization of ``w``.

        ``reduce_axes`` are the contraction axes of the matmul ``w`` feeds
        (amax is taken over them; the remaining axes are per-channel).
        Grouping/packing happens along ``reduce_axes[0]``.
        """
        if bits not in _QMAX:
            raise ValueError(f"bits must be 4 or 8, got {bits}")
        nd = w.ndim
        axes = tuple(_norm_neg_axis(a, nd) for a in reduce_axes)
        ax = axes[0] % nd
        qmax = _QMAX[bits]
        w32 = jnp.asarray(w, jnp.float32)

        if group_size > 0:
            din = w.shape[ax]
            if din % group_size:
                raise ValueError(f"group_size {group_size} must divide axis dim {din}")
            if bits == 4 and group_size % 2:
                raise ValueError("int4 group_size must be even (nibble packing)")
            n_groups = din // group_size
            gshape = w.shape[:ax] + (n_groups, group_size) + w.shape[ax + 1 :]
            wg = w32.reshape(gshape)
            red = (ax + 1,) + tuple((a % nd) + (1 if (a % nd) > ax else 0) for a in axes[1:])
            amax = jnp.max(jnp.abs(wg), axis=red, keepdims=True)
            scale = jnp.maximum(amax, 1e-8) / qmax
            q = jnp.clip(jnp.round(wg / scale), -qmax, qmax).astype(jnp.int8).reshape(w.shape)
            scale = jnp.squeeze(scale, axis=ax + 1)  # [..., n_groups, <1s for other axes>]
        else:
            amax = jnp.max(jnp.abs(w32), axis=axes, keepdims=True)
            scale = jnp.maximum(amax, 1e-8) / qmax
            q = jnp.clip(jnp.round(w32 / scale), -qmax, qmax).astype(jnp.int8)

        if bits == 4:
            if w.shape[ax] % 2:
                raise ValueError(f"int4 needs an even dim on axis {ax}, got {w.shape[ax]}")
            q = _pack_int4(q, ax)

        return cls(q, scale, bits, group_size, _norm_neg_axis(ax, nd), str(w.dtype))

    def dequantize(self) -> jax.Array:
        q = self.q
        if self.bits == 4:
            q = _unpack_int4(q, self.axis)
        w = q.astype(jnp.float32)
        if self.group_size > 0:
            ax = self.axis % w.ndim
            shape = w.shape
            n_groups = shape[ax] // self.group_size
            w = w.reshape(shape[:ax] + (n_groups, self.group_size) + shape[ax + 1 :])
            w = w * jnp.expand_dims(self.scale, axis=ax + 1)
            w = w.reshape(shape)
        else:
            w = w * self.scale
        return w.astype(self.dtype)


def _pack_int4(q: jax.Array, ax: int) -> jax.Array:
    """Pack adjacent int4 pairs along ``ax``: element i holds (2i | 2i+1<<4)."""
    qm = jnp.moveaxis(q, ax, -1).astype(jnp.int32)
    lo = qm[..., 0::2] & 0xF
    hi = qm[..., 1::2] & 0xF
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return jnp.moveaxis(jax.lax.bitcast_convert_type(packed, jnp.int8), -1, ax)


def _unpack_int4(q: jax.Array, ax: int) -> jax.Array:
    """Inverse of :func:`_pack_int4`; returns sign-extended int8 nibbles."""
    qm = jnp.moveaxis(q, ax, -1).astype(jnp.int32) & 0xFF
    lo = qm & 0xF
    hi = (qm >> 4) & 0xF
    lo = lo - 16 * (lo > 7)
    hi = hi - 16 * (hi > 7)
    inter = jnp.stack([lo, hi], axis=-1).reshape(qm.shape[:-1] + (qm.shape[-1] * 2,))
    return jnp.moveaxis(inter.astype(jnp.int8), -1, ax)


def materialize(w):
    """Dequantize if quantized, else pass through — the one-line hook that
    lets every matmul site accept fp or quantized weights transparently."""
    if isinstance(w, QuantizedArray):
        return w.dequantize()
    return w
