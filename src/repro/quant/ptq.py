"""Post-training weight-only quantization of a params pytree (MoQ, §4).

A :class:`QuantPolicy` decides which leaves to quantize by their key path:

  * ``experts``       — only the routed expert matrices (``moe/{wi,wg,wo}``).
    Expert weights are >90% of MoE params, so this alone is the paper's
    ~3.7x model-size win while leaving the dense "critical data path"
    (attention, shared FFN, router, norms, embeddings) at full precision.
  * ``experts_attn``  — experts + attention projections.
  * ``all``           — every matmul weight (experts, attention, dense FFNs,
    residual-MoE branch, unembed, frontend projector).  Router logits and
    norms always stay fp (they are tiny and accuracy-critical).

Each leaf is quantized with the contraction axes of the matmul it feeds, so
scales are per-*output*-channel (or per group of ``group_size`` inputs for
int4) and dequantization is a broadcast multiply.
"""
from __future__ import annotations

from typing import Any, List

import jax
import numpy as np

from repro.configs.base import QuantConfig
from repro.quant.qarrays import QuantizedArray
from repro.treepath import path_names as _path_names

# Key-path → contraction-axes table.  Axes are negative (end-relative) so the
# same rule applies to [D,F] dense mats and scan-stacked [R,E,D,F] experts.
_EXPERT_KEYS = ("wi", "wg", "wo")
_ATTN_QKV_AXES = (-3,)  # wq/wk/wv: [D, H, dh] contract D
_ATTN_WO_AXES = (-3, -2)  # wo: [H, dh, D] contract (H, dh)
_MATMUL_AXES = (-2,)  # [.., Din, Dout] contract Din


def _rule_for(path_names: List[str], policy: str):
    """Returns contraction axes for a quantizable leaf, or None to skip."""
    leaf = path_names[-1]
    inside = set(path_names[:-1])
    if "moe" in inside and leaf in _EXPERT_KEYS and "residual" not in inside:
        return _MATMUL_AXES  # stacked [.., E, Din, Dout] expert mats
    if policy == "experts":
        return None
    if ("attn" in inside or "cross" in inside) and leaf in ("wq", "wk", "wv", "wo"):
        return _ATTN_WO_AXES if leaf == "wo" else _ATTN_QKV_AXES
    if policy != "all":
        return None
    if ("ffn" in inside or "residual" in inside) and leaf in _EXPERT_KEYS:
        return _MATMUL_AXES
    if leaf in ("unembed", "frontend_proj"):
        return _MATMUL_AXES
    return None


def quantize_params(params: Any, qcfg: QuantConfig) -> Any:
    """Quantize matmul weights of ``params`` per ``qcfg``; everything else
    (router, norms, embeddings, caches-to-be) passes through untouched."""
    if qcfg.policy not in ("experts", "experts_attn", "all"):
        raise ValueError(f"unknown quant policy {qcfg.policy!r}")

    def visit(path, leaf):
        axes = _rule_for(_path_names(path), qcfg.policy)
        if axes is None:
            return leaf
        # group-wise scaling (int8 or int4) only applies along a single
        # contraction axis; the attention out-proj has two, so it falls back
        # to per-output-channel there.
        gs = qcfg.group_size if len(axes) == 1 else 0
        return QuantizedArray.quantize(leaf, bits=qcfg.bits, group_size=gs, reduce_axes=axes)

    return jax.tree_util.tree_map_with_path(visit, params)


def dequantize_params(params: Any) -> Any:
    """Materialize every QuantizedArray leaf back to fp (debug / ep path)."""
    return jax.tree_util.tree_map(
        lambda l: l.dequantize() if isinstance(l, QuantizedArray) else l,
        params,
        is_leaf=lambda l: isinstance(l, QuantizedArray),
    )


def prepare_params_for_serving(cfg, params: Any) -> Any:
    """Single home for the serving/quantization interaction rule: the
    explicit expert-parallel shard_map path addresses raw expert arrays, so
    when it will actually run (``moe_impl == "ep"`` under an active mesh)
    quantized *expert* leaves are materialized ONCE here — not per step
    inside the jitted decode.  Everything else (attention, unembed, dense
    FFNs) consumes QuantizedArray leaves natively at its matmul site and
    passes through untouched, keeping those policies' memory savings.  (If
    a mesh is entered only after engine construction, moe_layer's in-jit
    fallback still keeps results correct, just without the bytes win.)"""
    from repro.parallel.sharding import get_mesh

    if getattr(cfg, "moe_impl", None) not in ("ep", "ep_serve", "ep_grouped") or get_mesh() is None:
        return params

    def visit(path, leaf):
        if isinstance(leaf, QuantizedArray):
            names = _path_names(path)
            # only the routed expert mats directly under "moe" feed the
            # shard_map; the residual dense branch (moe/residual/*) keeps
            # its QuantizedArray leaves (mlp() materializes them in place)
            if len(names) >= 2 and names[-2] == "moe" and names[-1] in _EXPERT_KEYS:
                return leaf.dequantize()
        return leaf

    return jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda l: isinstance(l, QuantizedArray)
    )


def quantized_leaf_paths(params: Any) -> List[str]:
    """'/'-joined key paths of the quantized leaves (tests / reporting)."""
    out = []

    def visit(path, leaf):
        if isinstance(leaf, QuantizedArray):
            out.append("/".join(_path_names(path)))
        return leaf

    jax.tree_util.tree_map_with_path(visit, params, is_leaf=lambda l: isinstance(l, QuantizedArray))
    return out


def tree_bytes(params: Any, *, only_quantized: bool = False) -> int:
    """Total parameter bytes; QuantizedArray counts packed ints + scales."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda l: isinstance(l, QuantizedArray)
    ):
        if isinstance(leaf, QuantizedArray):
            total += leaf.nbytes
        elif not only_quantized:
            total += int(np.prod(np.shape(leaf))) * np.dtype(leaf.dtype).itemsize
    return total
