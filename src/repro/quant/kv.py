"""``QuantizedKV``: an int8 KV-cache layout with per-head, per-timestep scales.

DeepSpeed-MoE's inference analysis (§5) treats decode as memory-bandwidth
bound; PR 1 (MoQ, §4) shrank the expert weights, and at long context / large
batch the next dominant term in decode HBM traffic is the KV cache — every
decode step streams the full ``[B, T, H_kv, dh]`` K and V history.  Storing
them as int8 with one f32 scale per (batch, timestep, kv-head) cuts those
bytes ~4x (dh/(dh+4) of the ideal 4x for an f32 cache; 48-head-dim demo
models get 3.7x) while keeping the quantization *local*: each written token
is scaled independently, so cache writes never touch earlier entries and
ring-buffer slot reuse just overwrites (q, scale) pairs in place.

Like :class:`~repro.quant.qarrays.QuantizedArray`, the class is a pytree
node with attr keys, so pooled caches flow through ``jax.jit``,
``jax.lax.scan`` over stacked layers, ``dynamic_update_slice`` slot writes,
and the masked merges of continuous batching without special-casing: ``q``
and ``scale`` both carry the same leading (layers, batch, time) dims and are
sliced/stacked consistently.

Layout (one cache tensor, e.g. K):

  * ``q``      int8  [..., T, H_kv, dh]   — symmetric values, zero-point 0
  * ``scale``  f32   [..., T, H_kv, 1]    — amax/127 per (timestep, head)

The leading dims are layout-agnostic: contiguous slot caches carry
``[..., B, T, ...]`` and the paged serving path lays the same pair out *per
page* as ``[..., n_pages + 1, page_size, ...]`` (models/attention.py
``init_paged_kv_cache``) — per-token scales mean pages quantize, scatter,
recycle, and gather through block tables with no rescaling anywhere, and
the dequant-in-VMEM kernels stream 1-byte entries either way.

An all-zero slot quantizes to (q=0, scale≈0) and dequantizes to exact zeros,
so freshly-initialized / vacated ring slots behave like the fp cache's zero
fill (masked out by ``pos == -1`` anyway).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_KV_QMAX = 127.0


def kv_quantize_values(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [..., T, H, dh] -> (q int8 [..., T, H, dh], scale f32 [..., T, H, 1]).

    Symmetric per-(timestep, head) quantization over the head dim — the
    finest granularity that still amortizes (dh values share 4 scale bytes),
    and the one that matches decode writes: one new (q, scale) pair per head
    per step, no rescaling of history.
    """
    x32 = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / _KV_QMAX
    q = jnp.clip(jnp.round(x32 / scale), -_KV_QMAX, _KV_QMAX).astype(jnp.int8)
    return q, scale


@jax.tree_util.register_pytree_with_keys_class
class QuantizedKV:
    """int8 values + f32 per-(timestep, head) scales for one cache tensor."""

    __slots__ = ("q", "scale", "orig_dtype")

    def __init__(self, q, scale, orig_dtype: str):
        self.q = q
        self.scale = scale
        self.orig_dtype = orig_dtype

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten_with_keys(self):
        children = (
            (jax.tree_util.GetAttrKey("q"), self.q),
            (jax.tree_util.GetAttrKey("scale"), self.scale),
        )
        return children, (self.orig_dtype,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q, scale, *aux)

    # -- array-ish surface --------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(jnp.shape(self.q))

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def dtype(self):
        return jnp.dtype(self.orig_dtype)

    @property
    def nbytes(self) -> int:
        # via .shape/.dtype (not .size) so jax.eval_shape trees work too
        import numpy as np

        return int(
            np.prod(self.q.shape) * jnp.dtype(self.q.dtype).itemsize
            + np.prod(self.scale.shape) * jnp.dtype(self.scale.dtype).itemsize
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"QuantizedKV(int8, shape={self.shape}, orig={self.orig_dtype})"

    # -- numerics -----------------------------------------------------------
    @classmethod
    def zeros(cls, shape: Tuple[int, ...], dtype) -> "QuantizedKV":
        """Empty cache tensor: q=0 / scale=0 dequantizes to exact zeros."""
        return cls(
            jnp.zeros(shape, jnp.int8),
            jnp.zeros(shape[:-1] + (1,), jnp.float32),
            str(jnp.dtype(dtype)),
        )

    @classmethod
    def quantize(cls, x: jax.Array) -> "QuantizedKV":
        q, scale = kv_quantize_values(x)
        return cls(q, scale, str(x.dtype))

    def dequantize(self) -> jax.Array:
        return (self.q.astype(jnp.float32) * self.scale).astype(self.dtype)


def materialize_kv(x):
    """Dequantize if quantized, passthrough otherwise — the KV analogue of
    :func:`repro.quant.qarrays.materialize`."""
    if isinstance(x, QuantizedKV):
        return x.dequantize()
    return x


def kv_cache_bytes(caches) -> int:
    """Total KV/state cache bytes; QuantizedKV leaves count packed ints +
    scales (the serving-memory headroom number: batch slots ∝ 1/bytes).
    Accepts concrete arrays or a ``jax.eval_shape`` tree — sizing never
    needs to allocate a cache."""
    import numpy as np

    total = 0
    for leaf in jax.tree_util.tree_leaves(
        caches, is_leaf=lambda l: isinstance(l, QuantizedKV)
    ):
        if isinstance(leaf, QuantizedKV):
            total += leaf.nbytes
        else:
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total
