"""Checkpointing: pytree -> directory of .npy leaves + a JSON manifest.

Memory-efficient in the MoS sense the paper mentions (§Contributions,
"memory-efficient checkpointing"): leaves are streamed to disk one at a time
rather than materialising a single giant archive, and loading is lazy-ish
(np.load with mmap for large leaves).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.treepath import path_entry

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(path_entry(p) for p in path)
        out.append((key, leaf))
    return out, treedef


def save(path: str, tree: Any, *, step: int = 0, extra: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        fname = f"leaf{i:05d}_{_SAFE.sub('-', key)[:80]}.npy"
        np.save(os.path.join(path, fname), arr)
        manifest["leaves"].append({"key": key, "file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load(path: str, like: Any) -> tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype-checked).

    Fails with an informative ``ValueError`` when the manifest and ``like``
    disagree — the common cases being a checkpoint saved from a different
    architecture, or fp weights loaded into a quantized (``QuantizedArray``)
    tree / vice versa, where whole ``q``/``scale`` leaves go missing.
    """
    manifest_path = os.path.join(path, "manifest.json")
    if not os.path.exists(manifest_path):
        raise ValueError(f"no checkpoint at {path!r}: missing manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    flat, treedef = _flatten_with_paths(like)
    by_key = {e["key"]: e for e in manifest["leaves"]}

    missing = [key for key, _ in flat if key not in by_key]
    if missing:
        raise ValueError(
            f"checkpoint {path!r} is missing {len(missing)} leaves required by the "
            f"target structure (first few: {missing[:5]}); was it saved from a "
            "different architecture or quantization state?"
        )
    unused = set(by_key) - {key for key, _ in flat}
    if unused:
        raise ValueError(
            f"checkpoint {path!r} holds {len(unused)} leaves the target structure "
            f"does not expect (first few: {sorted(unused)[:5]}); refusing a "
            "partial restore."
        )

    leaves = []
    for key, leaf in flat:
        e = by_key[key]
        arr = np.load(os.path.join(path, e["file"]), mmap_mode="r")
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(
                f"checkpoint leaf {key!r}: stored shape {tuple(arr.shape)} != "
                f"expected {tuple(np.shape(leaf))}"
            )
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype if hasattr(leaf, "dtype") else None))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
