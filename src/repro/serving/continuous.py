"""Continuous batching on top of the DS-MoE serving stack.

Production MoE serving (paper §5.5: "hundreds of GPUs to meet traffic")
cannot wait for a whole batch to finish before admitting new requests.  This
scheduler maintains a fixed pool of decode *slots*; each slot has its own
sequence position, requests are admitted into free slots with a per-slot
prefill, and every engine tick decodes all active slots in one batched
ragged decode step (per-row positions, masked sampling).

Two KV-storage models share the scheduler:

  * **contiguous** (default) — each slot owns a ``capacity``-token cache
    row.  Admission = a free slot.  Simple, but memory is reserved for the
    worst case: a 12-token request strands ``capacity - 12`` tokens.
  * **paged** (``paged=True``) — cache memory is a shared pool of
    ``page_size``-token pages (serving/kv_pool.py); each slot holds a
    static-shape block table.  Admission goes by *free-block count*, a
    sequence's table grows lazily as it decodes, pages return to the pool
    the moment a request finishes, and when the pool is exhausted the
    youngest slot is preempted back to the queue (its pages freed, its
    progress resumed later via re-prefill over prompt + generated tokens).
    Effective concurrent sequences per byte now scale with actual sequence
    lengths, not the worst case — and multiply with ``kv_cache_bits=8``.

Paged mode optionally adds **prefix sharing with copy-on-write**
(``prefix_sharing=True``): a radix index over full-page token chunks
(serving/prefix_index.py) maps live prompt prefixes to physical pages, so a
request whose context repeats an admitted prefix — shared system prompts,
few-shot preambles — points its block table at the existing pages
(refcounted via ``KVBlockPool.share``) and only the tail is written at
prefill.  Parallel sampling (``submit_n``) rides the same mechanism: n
samples of one prompt share ALL its pages, including the partially-filled
boundary page, and diverge lazily — before a slot appends into a page with
refcount > 1, the scheduler forks it a private copy (``pool.fork`` +
``paged_copy_page``), so a page visible to another slot is never mutated.
The decode read path is untouched by construction (tables just point at
shared pages), which is what makes greedy parity against the non-shared
paged engine a strict end-to-end oracle.

Static shapes throughout: slot pool, page pool, and block tables are all
fixed, so the jitted decode step never recompiles as traffic arrives/leaves
— the property that makes continuous batching viable under XLA.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, PagedKVConfig
from repro.models.model import (
    init_caches,
    init_paged_caches,
    paged_copy_page,
    paged_copy_slot_leaves,
    paged_prefill_into_slot,
    paged_ragged_decode_step,
    paged_reset_pages,
    prefill_into_slot,
    ragged_decode_step,
)
from repro.serving.engine import Request, Response
from repro.serving.kv_pool import BlockTables, KVBlockPool
from repro.serving.prefix_index import PrefixIndex
from repro.serving.sampling import sample


@dataclass
class SlotState:
    request_id: int = -1
    pos: int = 0  # next absolute position
    generated: List[int] = field(default_factory=list)
    budget: int = 0
    active: bool = False
    admit_seq: int = -1  # admission order — youngest-first preemption key
    prompt_len: int = 0  # original (untruncated) prompt length
    # The request's base prompt, EXCLUDING generated tokens.  Preemption
    # re-queues (prompt, generated) separately; re-admission rebuilds the
    # context as (prompt + generated)[-keep:].  Storing the admitted context
    # here instead would duplicate the generated prefix on a second
    # preemption of the same request.
    prompt: List[int] = field(default_factory=list)
    # Last-context-token logits from this slot's admission prefill ([1, V]
    # numpy), kept under prefix sharing so parallel-sample forks admitted
    # before the base's first decode tick can draw their first token without
    # recomputing the prefill.
    prefill_logits: Optional[np.ndarray] = None


@dataclass
class _Pending:
    """Queue entry.  ``generated`` is non-empty for preempted requests: on
    re-admission the engine prefills over ``prompt + generated`` so greedy
    decoding resumes exactly where it left off.  ``fork_of`` >= 0 marks a
    parallel sample of the request with that id (submit_n): if its base is
    still at its admission state when this entry reaches the queue head, the
    fork shares ALL the base's pages instead of prefilling; otherwise it
    degrades to an ordinary request (prefix-index sharing still applies)."""

    rid: int
    prompt: List[int]
    budget: int  # total response budget (already clamped to capacity - 1)
    generated: List[int]
    prompt_len: int
    fork_of: int = -1


class ContinuousEngine:
    """Slot-pool continuous batching.  ``step()`` = one decode tick; requests
    are admitted on submit() whenever a slot (and, in paged mode, enough free
    pages) is available.

    Like ``Engine``, accepts MoQ-quantized params (``QuantizedArray`` leaves
    from ``repro.quant.quantize_params``) transparently."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4, capacity: int = 256,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 0.0,
                 eos_id: int = -1, seed: int = 0, kv_cache_bits: int = 0,
                 paged: bool = False, page_size: Optional[int] = None,
                 n_pages: Optional[int] = None, prefix_sharing: bool = False,
                 paged_cfg: Optional[PagedKVConfig] = None):
        if paged_cfg is not None:
            # bundled form of the same knobs (configs.base.PagedKVConfig);
            # mixing it with the loose kwargs would silently shadow them
            if paged or page_size is not None or n_pages is not None or prefix_sharing:
                raise ValueError(
                    "pass either paged_cfg or paged/page_size/n_pages/prefix_sharing, not both"
                )
            paged = True
            page_size = paged_cfg.page_size
            n_pages = paged_cfg.n_pages
            prefix_sharing = paged_cfg.prefix_sharing
        if prefix_sharing and not paged:
            raise ValueError("prefix_sharing requires paged=True (block tables)")
        self.cfg = cfg
        from repro.quant import prepare_params_for_serving

        self.params = prepare_params_for_serving(cfg, params)
        self.n_slots = slots
        self.capacity = capacity
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.eos_id = eos_id
        self.kv_cache_bits = kv_cache_bits
        self.paged = paged
        self.prefix_sharing = prefix_sharing
        self.prefix: Optional[PrefixIndex] = None
        if paged:
            self.page_size = page_size = int(page_size or 16)
            self.max_pages = -(-capacity // page_size)  # table entries per slot
            # n_pages None/0 = auto: slots * pages-per-capacity, i.e. the
            # contiguous worst case (same convention as EngineConfig/--pages)
            self.n_pages = int(n_pages) if n_pages else slots * self.max_pages
            if self.n_pages < self.max_pages:
                raise ValueError(
                    f"n_pages={self.n_pages} cannot hold even one full-capacity "
                    f"sequence ({self.max_pages} pages of {page_size})"
                )
            self.pool = KVBlockPool(self.n_pages, page_size)
            self.tables = BlockTables(slots, self.max_pages)
            if prefix_sharing:
                self.prefix = PrefixIndex(page_size)
            # kv_cache_bits=8 composes: int8 pages (~4x fewer bytes per cache
            # token) x fragmentation-free packing of those tokens
            self.caches = init_paged_caches(
                cfg, slots, capacity, n_pages=self.n_pages, page_size=page_size,
                kv_bits=kv_cache_bits,
            )
        else:
            # kv_cache_bits=8: pooled slot caches live as int8 QuantizedKV —
            # ~4x more slot-capacity per byte of cache memory; admission
            # prefill and ragged decode quantize on write
            self.caches = init_caches(cfg, slots, capacity, kv_bits=kv_cache_bits)
        self.slots = [SlotState() for _ in range(slots)]
        self.queue: List[_Pending] = []
        self.done: Dict[int, Response] = {}
        self.preemptions = 0
        self.cow_copies = 0  # pages privately duplicated before a divergent append
        self.prefix_hits = 0  # admissions that shared at least one indexed page
        self.prefix_hit_tokens = 0  # context tokens served from shared pages
        self.metrics_log: List[dict] = []
        self._metrics_cap = 65_536  # keep a bounded telemetry window
        self.last_metrics: dict = {}
        self._tick = 0
        self._next_id = 0
        self._admit_counter = 0
        self._key = jax.random.PRNGKey(seed)
        self._cur_token = np.zeros((slots,), np.int32)

        if paged:
            def _step(params, tokens, positions, active, caches, tables):
                return paged_ragged_decode_step(
                    cfg, params, tokens, positions, active, caches, tables
                )

            self._decode = jax.jit(_step, donate_argnums=(4,))

            def _prefill_one(params, tokens, positions, slot, caches, table_row, scatter_start):
                return paged_prefill_into_slot(
                    cfg, params, tokens, positions, slot, caches, table_row,
                    capacity=capacity, kv_bits=kv_cache_bits,
                    scatter_start=scatter_start,
                )

            self._prefill = jax.jit(_prefill_one, donate_argnums=(4,))
            self._reset_pages = jax.jit(
                lambda caches, mask: paged_reset_pages(cfg, caches, mask),
                donate_argnums=(0,),
            )
            # CoW device copy + parallel-sampling slot fork (src/dst traced)
            self._copy_page = jax.jit(
                lambda caches, src, dst: paged_copy_page(cfg, caches, src, dst),
                donate_argnums=(0,),
            )
            self._copy_slot = jax.jit(
                lambda caches, src, dst: paged_copy_slot_leaves(cfg, caches, src, dst),
                donate_argnums=(0,),
            )
        else:
            def _step(params, tokens, positions, active, caches):
                return ragged_decode_step(cfg, params, tokens, positions, active, caches)

            self._decode = jax.jit(_step, donate_argnums=(4,))

            def _prefill_one(params, tokens, positions, slot, caches):
                # single-request prefill written into the pooled caches at `slot`
                return prefill_into_slot(cfg, params, tokens, positions, slot, caches)

            self._prefill = jax.jit(_prefill_one, donate_argnums=(4,))

    # ------------------------------------------------------------------
    def _clamped_budget(self, req: Request) -> int:
        # Budget clamp: the response plus at least one context token must fit
        # the per-sequence capacity (a budget >= capacity used to flip the
        # prompt-truncation index positive and keep the WRONG end of the
        # prompt — or nothing at all).
        return max(1, min(req.max_new_tokens, self.capacity - 1))

    def submit(self, req: Request) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(_Pending(
            rid=rid, prompt=list(req.prompt), budget=self._clamped_budget(req),
            generated=[], prompt_len=len(req.prompt),
        ))
        self._admit()
        return rid

    def submit_n(self, req: Request, n: int) -> List[int]:
        """Submit ``n`` parallel samples of one prompt (one request id each).
        Under ``prefix_sharing`` the samples are page-aligned: the first is
        admitted normally and the rest fork it — block tables share ALL its
        prompt pages (including the partial boundary page) and per-slot
        ring/SSM/cross state is row-copied, so n samples cost one prompt's
        pages + one prefill until they diverge via copy-on-write.  Without
        sharing (or when slots/pages force staggered admission) each sample
        is served as an independent request — same tokens, no sharing."""
        if n < 1:
            raise ValueError(f"need n >= 1 samples, got {n}")
        budget = self._clamped_budget(req)
        rids: List[int] = []
        for j in range(n):
            rid = self._next_id
            self._next_id += 1
            self.queue.append(_Pending(
                rid=rid, prompt=list(req.prompt), budget=budget,
                generated=[], prompt_len=len(req.prompt),
                fork_of=rids[0] if j else -1,
            ))
            rids.append(rid)
        self._admit()
        return rids

    # ------------------------------------------------------------------
    def _fork_base_slot(self, item: _Pending) -> Optional[int]:
        """Slot index of ``item``'s fork base, iff the base is still exactly
        at its admission state: active, no decode tick since admission (its
        cache holds the prompt and nothing else — the single generated token
        is sampled but not yet written), prefill logits stashed.  Any other
        state means the boundary page already holds divergent tokens, so the
        fork must not share it and degrades to a normal admission."""
        if self.prefix is None or item.fork_of < 0:
            return None
        for b, s in enumerate(self.slots):
            if (s.active and s.request_id == item.fork_of
                    and len(s.generated) == 1 and s.prefill_logits is not None):
                return b
        return None

    def _admit_fork(self, i: int, b: int, item: _Pending) -> None:
        """Admit ``item`` into slot ``i`` as a page-aligned parallel sample of
        slot ``b``: share every page ``b`` holds (refcount + 1 each), point
        ``i``'s table at them, row-copy the per-slot leaves (window rings,
        SSM/LRU, cross), and draw the fork's first token from the base's
        stashed prefill logits.  Zero new pages, zero prefill compute; the
        first divergent append copy-on-writes the boundary page."""
        base = self.slots[b]
        pages = [int(p) for p in self.tables.row(b) if p >= 0]
        self.pool.share(pages, owner=i)
        self.tables.copy_row(i, b)
        self.caches = self._copy_slot(
            self.caches, jnp.asarray(b, jnp.int32), jnp.asarray(i, jnp.int32)
        )
        self._key, sub = jax.random.split(self._key)
        first = int(sample(jnp.asarray(base.prefill_logits), sub,
                           temperature=self.temperature,
                           top_k=self.top_k, top_p=self.top_p)[0])
        self.slots[i] = SlotState(
            request_id=item.rid, pos=base.pos, generated=[first],
            budget=item.budget, active=True, admit_seq=self._admit_counter,
            prompt_len=item.prompt_len, prompt=item.prompt,
            prefill_logits=base.prefill_logits,
        )
        self._admit_counter += 1
        self._cur_token[i] = first
        self.prefix_hits += 1
        self.prefix_hit_tokens += base.pos
        self._finish_if_done(i)

    def _admit(self) -> None:
        """FIFO admission: fill free slots from the queue head.  In paged
        mode a request is only admitted when the pool has enough free pages
        for its prompt (admission by free-block count); the queue head blocks
        rather than being skipped, so long requests cannot starve.  Under
        prefix sharing, pages covering an indexed full-page prefix of the
        context are shared rather than allocated, and only the tail is
        prefilled into fresh pages."""
        while self.queue:
            free = [i for i, s in enumerate(self.slots) if not s.active]
            if not free:
                return
            i = free[0]
            item = self.queue[0]
            fork_base = self._fork_base_slot(item)
            if fork_base is not None:
                self.queue.pop(0)
                self._admit_fork(i, fork_base, item)
                continue
            remaining = item.budget - len(item.generated)
            # keep the LAST (capacity - remaining) context tokens: the newest
            # prompt suffix, leaving exactly `remaining` cache tokens to decode
            keep = self.capacity - remaining
            ctx = (item.prompt + item.generated)[-keep:]
            shared: List[int] = []
            if self.paged:
                if self.prefix is not None:
                    # cap the match so at least one context token is left to
                    # prefill — last-token logits seed the first sample
                    shared = self.prefix.lookup(ctx, max_tokens=len(ctx) - 1)
                fresh = self.pool.alloc(
                    self.pool.pages_for(len(ctx)) - len(shared), owner=i)
                if fresh is None:
                    return  # wait for frees / completions
                if shared:
                    self.pool.share(shared, owner=i)
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += len(shared) * self.page_size
                self.tables.append(i, shared + fresh)
            self.queue.pop(0)
            toks = jnp.asarray(np.asarray(ctx, np.int32)[None])
            pos = jnp.arange(len(ctx), dtype=jnp.int32)[None]
            if self.paged:
                # shared-prefix positions are routed to the trash page inside
                # the scatter: a shared page is never written by an admission
                logits, self.caches = self._prefill(
                    self.params, toks, pos, jnp.asarray(i, jnp.int32), self.caches,
                    jnp.asarray(self.tables.row(i)),
                    jnp.asarray(len(shared) * self.page_size, jnp.int32),
                )
            else:
                logits, self.caches = self._prefill(
                    self.params, toks, pos, jnp.asarray(i, jnp.int32), self.caches
                )
            self._key, sub = jax.random.split(self._key)
            first = int(sample(logits, sub, temperature=self.temperature,
                               top_k=self.top_k, top_p=self.top_p)[0])
            stash = np.asarray(logits) if self.prefix is not None else None
            self.slots[i] = SlotState(
                request_id=item.rid, pos=len(ctx), generated=item.generated + [first],
                budget=item.budget, active=True, admit_seq=self._admit_counter,
                prompt_len=item.prompt_len, prompt=item.prompt,
                prefill_logits=stash,
            )
            self._admit_counter += 1
            self._cur_token[i] = first
            if self.prefix is not None:
                # register this context's full pages (shared entries are
                # already indexed and keep their mapping; fresh full pages
                # become shareable for future admissions)
                n_full = len(ctx) // self.page_size
                if n_full:
                    self.prefix.insert(ctx, [int(p) for p in self.tables.row(i)[:n_full]])
            self._finish_if_done(i)

    def _release_slot(self, i: int) -> None:
        if self.paged:
            # decref everything the slot holds; only pages whose refcount hit
            # zero are actually freed — pages another slot still references
            # stay live, mapped, and (if full) indexed for future sharing
            freed = self.pool.release(i)
            self.tables.reset(i)
            if freed:
                if self.prefix is not None:
                    self.prefix.evict_pages(freed)
                # invalidate the recycled pages' positions device-side, or a
                # later owner would see the previous occupant's stale K/V
                mask = np.zeros((self.n_pages + 1,), bool)
                mask[freed] = True
                self.caches = self._reset_pages(self.caches, jnp.asarray(mask))
        self.slots[i] = SlotState()

    def _finish_if_done(self, i: int) -> None:
        slot = self.slots[i]
        if not slot.active:
            return
        hit_eos = self.eos_id >= 0 and slot.generated and slot.generated[-1] == self.eos_id
        if len(slot.generated) >= slot.budget or hit_eos:
            gen = slot.generated
            if hit_eos:
                gen = gen[:-1]
            self.done[slot.request_id] = Response(tokens=gen, prompt_len=slot.prompt_len)
            self._release_slot(i)
            self._admit()

    def _preempt(self, i: int) -> None:
        """Push slot ``i`` back to the queue head and free its pages.  The
        request resumes later by re-prefilling prompt + generated-so-far, so
        greedy decoding continues token-exact."""
        slot = self.slots[i]
        self.queue.insert(0, _Pending(
            rid=slot.request_id, prompt=slot.prompt, budget=slot.budget,
            generated=slot.generated, prompt_len=slot.prompt_len,
        ))
        self._release_slot(i)
        self.preemptions += 1

    def _youngest_active(self) -> int:
        return max(
            (j for j, s in enumerate(self.slots) if s.active),
            key=lambda j: self.slots[j].admit_seq,
        )

    def _ensure_pages(self) -> None:
        """Pre-tick page discipline, per active slot in admission order:

        1. **Lazy table growth** — map a page for the slot's write position;
           when the pool is dry the *youngest* active slot is preempted
           (LIFO — the request with the least sunk prefill/decode work
           re-queues).
        2. **Copy-on-write** — if the write-position page has refcount > 1
           (a prefix/fork sharer), fork it: allocate a private page, copy the
           device contents, remap this slot's table entry, decref the
           original.  After this pass every active slot's write page has
           refcount 1, which is the invariant that makes shared pages
           read-only under decode."""
        order = sorted(
            (i for i, s in enumerate(self.slots) if s.active),
            key=lambda i: self.slots[i].admit_seq,
        )
        for i in order:
            slot = self.slots[i]
            while slot.active and self.tables.n_mapped(i) <= slot.pos // self.page_size:
                got = self.pool.alloc(1, owner=i)
                if got is not None:
                    self.tables.append(i, got)
                    continue
                victim = self._youngest_active()
                self._preempt(victim)
                if victim == i:
                    break  # this slot itself re-queued; stop growing it
            while self.slots[i].active:
                entry = slot.pos // self.page_size
                page = int(self.tables.row(i)[entry])
                if self.pool.refcount(page) <= 1:
                    break
                new = self.pool.fork(page, i)
                if new is None:
                    victim = self._youngest_active()
                    self._preempt(victim)
                    if victim == i:
                        break  # re-queued; a sharer keeps the page alive
                    continue  # a preemption may even have dropped the refcount
                self.caches = self._copy_page(
                    self.caches, jnp.asarray(page, jnp.int32), jnp.asarray(new, jnp.int32)
                )
                self.tables.set_entry(i, entry, new)
                self.cow_copies += 1

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One decode tick over all active slots.  Returns #active slots.
        Per-tick scheduler telemetry lands in ``last_metrics`` /
        ``metrics_log`` (active slots, free/shared pages, CoW copies, tok/s,
        preemptions)."""
        t0 = time.perf_counter()
        active = np.asarray([s.active for s in self.slots])
        if not active.any():
            self._admit()
            active = np.asarray([s.active for s in self.slots])
            if not active.any():
                return 0
        if self.paged:
            self._ensure_pages()
            active = np.asarray([s.active for s in self.slots])
            if not active.any():
                return 0
        positions = np.asarray([s.pos if s.active else 0 for s in self.slots], np.int32)
        tokens = jnp.asarray(self._cur_token[:, None])
        if self.paged:
            logits, self.caches = self._decode(
                self.params, tokens, jnp.asarray(positions), jnp.asarray(active),
                self.caches, jnp.asarray(self.tables.table),
            )
        else:
            logits, self.caches = self._decode(
                self.params, tokens, jnp.asarray(positions), jnp.asarray(active), self.caches
            )
        self._key, sub = jax.random.split(self._key)
        nxt = np.asarray(sample(logits, sub, temperature=self.temperature,
                                top_k=self.top_k, top_p=self.top_p))
        n_active = int(active.sum())
        for i, slot in enumerate(self.slots):
            # Gate on the PRE-decode snapshot, not slot.active: a completion
            # at row < i can trigger _admit into free row i mid-loop, and
            # that fresh slot must not consume nxt[i] — its logits row was
            # computed while the row was inactive.
            if not active[i]:
                continue
            slot.pos += 1
            slot.generated.append(int(nxt[i]))
            # the stashed admission logits are only consumable by a fork
            # BEFORE the base's first decode tick — drop the dead copy
            slot.prefill_logits = None
            self._cur_token[i] = int(nxt[i])
            self._finish_if_done(i)
        self._record_metrics(n_active, time.perf_counter() - t0)
        return n_active

    def _record_metrics(self, n_active: int, dt: float) -> None:
        self._tick += 1
        m = {
            "tick": self._tick,
            "active_slots": n_active,
            "queue_depth": len(self.queue),
            "tokens_this_tick": n_active,
            "tok_per_s": round(n_active / max(dt, 1e-9), 2),
            "preemptions": self.preemptions,
        }
        if self.paged:
            m["free_pages"] = self.pool.free_count
            m["page_occupancy"] = round(self.pool.occupancy, 4)
            m["shared_pages"] = self.pool.shared_count
            m["cow_copies"] = self.cow_copies
            if self.prefix is not None:
                m["prefix_hits"] = self.prefix_hits
                m["prefix_hit_tokens"] = self.prefix_hit_tokens
        self.last_metrics = m
        self.metrics_log.append(m)
        if len(self.metrics_log) > self._metrics_cap:
            del self.metrics_log[: -self._metrics_cap]

    def run_until_done(self, max_ticks: int = 10_000) -> Dict[int, Response]:
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return dict(self.done)
