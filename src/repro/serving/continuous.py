"""Continuous batching on top of the DS-MoE serving stack.

Production MoE serving (paper §5.5: "hundreds of GPUs to meet traffic")
cannot wait for a whole batch to finish before admitting new requests.  This
scheduler maintains a fixed pool of decode *slots*; each slot has its own
sequence position, requests are admitted into free slots with a per-slot
prefill, and every engine tick decodes all active slots in one batched
``ragged_decode_step`` (per-row positions/ring-slots, masked sampling).

Static shapes throughout: the slot pool is fixed, so the jitted decode step
never recompiles as traffic arrives/leaves — the property that makes
continuous batching viable under XLA.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import init_caches, ragged_decode_step
from repro.serving.engine import Request, Response
from repro.serving.sampling import sample


@dataclass
class SlotState:
    request_id: int = -1
    pos: int = 0  # next absolute position
    generated: List[int] = field(default_factory=list)
    budget: int = 0
    active: bool = False


class ContinuousEngine:
    """Slot-pool continuous batching.  ``step()`` = one decode tick; requests
    are admitted on submit() whenever a slot is free.

    Like ``Engine``, accepts MoQ-quantized params (``QuantizedArray`` leaves
    from ``repro.quant.quantize_params``) transparently."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4, capacity: int = 256,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 0.0,
                 eos_id: int = -1, seed: int = 0, kv_cache_bits: int = 0):
        self.cfg = cfg
        from repro.quant import prepare_params_for_serving

        self.params = prepare_params_for_serving(cfg, params)
        self.n_slots = slots
        self.capacity = capacity
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.eos_id = eos_id
        # kv_cache_bits=8: pooled slot caches live as int8 QuantizedKV —
        # ~4x more slot-capacity per byte of cache memory; admission prefill
        # and ragged decode quantize on write (models/attention.py)
        self.caches = init_caches(cfg, slots, capacity, kv_bits=kv_cache_bits)
        self.slots = [SlotState() for _ in range(slots)]
        self.queue: List[tuple] = []
        self.done: Dict[int, Response] = {}
        self._next_id = 0
        self._key = jax.random.PRNGKey(seed)
        self._cur_token = np.zeros((slots,), np.int32)

        def _step(params, tokens, positions, active, caches):
            return ragged_decode_step(cfg, params, tokens, positions, active, caches)

        self._decode = jax.jit(_step, donate_argnums=(4,))

        def _prefill_one(params, tokens, positions, slot, caches):
            # single-request prefill written into the pooled caches at `slot`
            from repro.models.model import prefill_into_slot

            return prefill_into_slot(cfg, params, tokens, positions, slot, caches)

        self._prefill = jax.jit(_prefill_one, donate_argnums=(4,))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, req))
        self._admit()
        return rid

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            rid, req = self.queue.pop(0)
            prompt = list(req.prompt)[-self.capacity + req.max_new_tokens :]
            toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
            pos = jnp.arange(len(prompt), dtype=jnp.int32)[None]
            logits, self.caches = self._prefill(
                self.params, toks, pos, jnp.asarray(i, jnp.int32), self.caches
            )
            self._key, sub = jax.random.split(self._key)
            first = int(sample(logits, sub, temperature=self.temperature,
                               top_k=self.top_k, top_p=self.top_p)[0])
            self.slots[i] = SlotState(
                request_id=rid, pos=len(prompt), generated=[first],
                budget=req.max_new_tokens, active=True,
            )
            self._cur_token[i] = first
            self._finish_if_done(i)

    def _finish_if_done(self, i: int) -> None:
        slot = self.slots[i]
        if not slot.active:
            return
        hit_eos = self.eos_id >= 0 and slot.generated and slot.generated[-1] == self.eos_id
        if len(slot.generated) >= slot.budget or hit_eos:
            gen = slot.generated
            if hit_eos:
                gen = gen[:-1]
            self.done[slot.request_id] = Response(tokens=gen, prompt_len=slot.pos)
            self.slots[i] = SlotState()
            self._admit()

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One decode tick over all active slots.  Returns #active slots."""
        active = np.asarray([s.active for s in self.slots])
        if not active.any():
            self._admit()
            active = np.asarray([s.active for s in self.slots])
            if not active.any():
                return 0
        positions = np.asarray([s.pos if s.active else 0 for s in self.slots], np.int32)
        tokens = jnp.asarray(self._cur_token[:, None])
        logits, self.caches = self._decode(
            self.params, tokens, jnp.asarray(positions), jnp.asarray(active), self.caches
        )
        self._key, sub = jax.random.split(self._key)
        nxt = np.asarray(sample(logits, sub, temperature=self.temperature,
                                top_k=self.top_k, top_p=self.top_p))
        for i, slot in enumerate(self.slots):
            if not slot.active:
                continue
            slot.pos += 1
            slot.generated.append(int(nxt[i]))
            self._cur_token[i] = int(nxt[i])
            self._finish_if_done(i)
        return int(active.sum())

    def run_until_done(self, max_ticks: int = 10_000) -> Dict[int, Response]:
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return dict(self.done)
