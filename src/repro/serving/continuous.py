"""Continuous batching on top of the DS-MoE serving stack.

Production MoE serving (paper §5.5: "hundreds of GPUs to meet traffic")
cannot wait for a whole batch to finish before admitting new requests.  This
scheduler maintains a fixed pool of decode *slots*; each slot has its own
sequence position, requests are admitted into free slots with a per-slot
prefill, and every engine tick decodes all active slots in one batched
ragged decode step (per-row positions, masked sampling).

Two KV-storage models share the scheduler:

  * **contiguous** (default) — each slot owns a ``capacity``-token cache
    row.  Admission = a free slot.  Simple, but memory is reserved for the
    worst case: a 12-token request strands ``capacity - 12`` tokens.
  * **paged** (``paged=True``) — cache memory is a shared pool of
    ``page_size``-token pages (serving/kv_pool.py); each slot holds a
    static-shape block table.  Admission goes by *free-block count*, a
    sequence's table grows lazily as it decodes, pages return to the pool
    the moment a request finishes, and when the pool is exhausted the
    youngest slot is preempted back to the queue (its pages freed, its
    progress resumed later via re-prefill over prompt + generated tokens).
    Effective concurrent sequences per byte now scale with actual sequence
    lengths, not the worst case — and multiply with ``kv_cache_bits=8``.

Paged mode optionally adds **prefix sharing with copy-on-write**
(``prefix_sharing=True``): a radix index over full-page token chunks
(serving/prefix_index.py) maps live prompt prefixes to physical pages, so a
request whose context repeats an admitted prefix — shared system prompts,
few-shot preambles — points its block table at the existing pages
(refcounted via ``KVBlockPool.share``) and only the tail is written at
prefill.  Parallel sampling (``submit_n``) rides the same mechanism: n
samples of one prompt share ALL its pages, including the partially-filled
boundary page, and diverge lazily — before a slot appends into a page with
refcount > 1, the scheduler forks it a private copy (``pool.fork`` +
``paged_copy_page``), so a page visible to another slot is never mutated.
The decode read path is untouched by construction (tables just point at
shared pages), which is what makes greedy parity against the non-shared
paged engine a strict end-to-end oracle.

Paged admission is a **resumable multi-tick state machine** (chunked
prefill, the default): admission reserves the slot and every prompt page up
front (all-or-nothing, so free-block admission semantics are unchanged),
then the prompt's *compute* is spread over engine ticks — each ``step()``
runs at most ``prefill_chunk`` tokens of prefill (page-aligned chunks,
written straight into pool pages by ``models.model.paged_prefill_chunk``)
before decoding the already-running slots, so a long-prompt admission can
never stall running decodes for more than one chunk of compute.  A
prefix-sharing admission starts its first chunk AFTER the shared pages and
reads them in place through the block table, so sharing saves the prefill
FLOPs as well as the pages.  There is no temp contiguous prefill cache
anywhere in this path; ``prefill_mode="scatter"`` retains the PR 3/4
temp-contiguous-then-scatter admission as a parity oracle
(tests/test_chunked.py asserts token-identical greedy outputs).

Static shapes throughout: slot pool, page pool, and block tables are all
fixed, so the jitted decode step never recompiles as traffic arrives/leaves
— the property that makes continuous batching viable under XLA.  Chunked
prefill compiles once per distinct chunk length (the page-aligned budget
plus each prompt's final remainder), same order as the per-prompt-length
compiles of the scatter path.

``prefill_mode="batched"`` fuses the tick further: ALL mid-prefill slots
advance one chunk in a SINGLE jitted call
(``models.model.paged_prefill_chunk_batched``) on a fixed ``[slots,
prefill_chunk]`` stacked shape — per-slot active masks, -1-padded position
rows, trash-routed tables for inactive rows — so an engine tick issues at
most two primary dispatches ({one batched prefill, one batched decode})
regardless of how many admissions are mid-prefill, and the batched entry
compiles exactly once (no per-chunk-length compiles at all).  Per-row
numerics are identical to the per-slot chunked path (tests/test_chunked.py
asserts token-exact greedy parity); "chunked" stays the default because the
batched call pads every row to the full chunk budget — it wins when several
admissions overlap (dispatch count), "chunked" when prefill traffic is
sparse (no padded compute).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, PagedKVConfig
from repro.core.gating import summarize_routing
from repro.models.model import (
    arch_fully_paged,
    init_caches,
    init_paged_caches,
    paged_copy_page,
    paged_copy_slot_leaves,
    paged_prefill_chunk,
    paged_prefill_chunk_batched,
    paged_prefill_into_slot,
    paged_ragged_decode_step,
    paged_reset_page_tails,
    paged_reset_pages,
    paged_verify_chunk_batched,
    prefill_into_slot,
    ragged_decode_step,
)
from repro.obs import Obs
from repro.serving.engine import Request, Response
from repro.serving.kv_pool import BlockTables, KVBlockPool
from repro.serving.prefix_index import PrefixIndex
from repro.serving.sampling import sample
from repro.serving.spec import Drafter, accept_length


@dataclass
class SlotState:
    """One decode slot's scheduler-side state.

    Invariants the scheduler maintains (fuzzed by tests/test_prefix.py and
    tests/test_chunked.py):

      * ``prefilling`` implies ``active`` — the slot holds its pages (all
        reserved at admission) but is excluded from decode ticks and from
        lazy growth / CoW in ``_ensure_pages``;
      * while ``prefilling``, ``pos == prefill_done`` = positions already
        written to pages (a prefix-sharing admission starts both at
        ``len(shared) * page_size``), and ``generated`` holds only tokens
        carried over from a preemption;
      * once prefill completes, ``generated[-1]`` is sampled but not yet
        written — the state a parallel-sample fork can share wholesale.
    """

    request_id: int = -1
    pos: int = 0  # next absolute position
    generated: List[int] = field(default_factory=list)
    budget: int = 0
    active: bool = False
    admit_seq: int = -1  # admission order — youngest-first preemption key
    prompt_len: int = 0  # original (untruncated) prompt length
    # The request's base prompt, EXCLUDING generated tokens.  Preemption
    # re-queues (prompt, generated) separately; re-admission rebuilds the
    # context as (prompt + generated)[-keep:].  Storing the admitted context
    # here instead would duplicate the generated prefix on a second
    # preemption of the same request.
    prompt: List[int] = field(default_factory=list)
    # Last-context-token logits from this slot's admission prefill ([1, V]
    # numpy), kept under prefix sharing so parallel-sample forks admitted
    # before the base's first decode tick can draw their first token without
    # recomputing the prefill.
    prefill_logits: Optional[np.ndarray] = None
    # Chunked-prefill progress (paged admission state machine): the admitted
    # context still being written, how many positions are done, and whether
    # the first chunk ran yet (it must RESET the per-slot leaves — the row
    # still holds the slot's previous occupant's ring/SSM state).
    prefilling: bool = False
    prefill_ctx: List[int] = field(default_factory=list)
    prefill_done: int = 0
    prefill_started: bool = False
    # Tokens by cache position: ``seq[j]`` is the token whose K/V lives (or,
    # for ``j == pos``, will live) at position ``j`` — context followed by
    # generated tokens.  Maintained from prefill completion on, with
    # ``len(seq) == pos + 1`` and ``seq[pos] == generated[-1]`` (the sampled
    # but not-yet-written current token).  Speculative decoding force-feeds
    # the drafter from it and rebuilds the drafter's cache on resync.
    seq: List[int] = field(default_factory=list)


@dataclass
class _Pending:
    """Queue entry.  ``generated`` is non-empty for preempted requests: on
    re-admission the engine prefills over ``prompt + generated`` so greedy
    decoding resumes exactly where it left off.  ``fork_of`` >= 0 marks a
    parallel sample of the request with that id (submit_n): if its base is
    still at its admission state when this entry reaches the queue head, the
    fork shares ALL the base's pages instead of prefilling; otherwise it
    degrades to an ordinary request (prefix-index sharing still applies)."""

    rid: int
    prompt: List[int]
    budget: int  # total response budget (already clamped to capacity - 1)
    generated: List[int]
    prompt_len: int
    fork_of: int = -1


class ContinuousEngine:
    """Slot-pool continuous batching.  ``step()`` = one engine tick (at most
    one chunk budget of admission prefill, then one decode step); requests
    are admitted on submit() whenever a slot (and, in paged mode, enough free
    pages for the WHOLE prompt — all-or-nothing) is available.

    Scheduler invariants, and the tests that hold them to account:

      * a refcount>1 page is never written — CoW before every divergent
        append, trash-routed prefill writes over shared entries
        (tests/test_prefix.py CoW-isolation, tests/test_kv_pool_prop.py);
      * mid-prefill slots never decode, and the decode step never touches
        their pages (table rows masked to -1) — tests/test_chunked.py;
      * per tick, admission prefill costs at most ``prefill_chunk`` tokens
        and every decode-eligible slot advances (bounded head-of-line
        blocking — tests/test_chunked.py interleaving fuzz);
      * preemption (youngest first) is token-exact from ANY state, including
        mid-prefill, because re-admission replays (prompt + generated)
        through the same greedy path — tests/test_paged.py round-trips;
      * the pool and prefix index drain to empty with traffic
        (tests/test_prefix.py scheduler fuzz).

    Like ``Engine``, accepts MoQ-quantized params (``QuantizedArray`` leaves
    from ``repro.quant.quantize_params``) transparently."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4, capacity: int = 256,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 0.0,
                 eos_id: int = -1, seed: int = 0, kv_cache_bits: int = 0,
                 paged: bool = False, page_size: Optional[int] = None,
                 n_pages: Optional[int] = None, prefix_sharing: bool = False,
                 prefill_chunk: int = 0, prefill_mode: str = "chunked",
                 paged_cfg: Optional[PagedKVConfig] = None,
                 obs: Optional[Obs] = None,
                 spec_draft: Optional[tuple] = None, spec_k: int = 4):
        if paged_cfg is not None:
            # bundled form of the same knobs (configs.base.PagedKVConfig);
            # mixing it with the loose kwargs would silently shadow them
            if (paged or page_size is not None or n_pages is not None
                    or prefix_sharing or prefill_chunk):
                raise ValueError(
                    "pass either paged_cfg or paged/page_size/n_pages/"
                    "prefix_sharing/prefill_chunk, not both"
                )
            paged = True
            page_size = paged_cfg.page_size
            n_pages = paged_cfg.n_pages
            prefix_sharing = paged_cfg.prefix_sharing
            prefill_chunk = paged_cfg.prefill_chunk
        if prefix_sharing and not paged:
            raise ValueError("prefix_sharing requires paged=True (block tables)")
        if prefill_mode not in ("chunked", "batched", "scatter"):
            raise ValueError(
                f"prefill_mode must be 'chunked', 'batched' or 'scatter', got {prefill_mode!r}"
            )
        if prefill_mode == "batched" and not paged:
            raise ValueError(
                "prefill_mode='batched' requires paged=True: the batched chunk "
                "prefill writes directly into pool pages through block tables"
            )
        if spec_draft is not None:
            # draft-then-verify speculative decoding (serving/spec.py):
            # spec_draft = (drafter ModelConfig, drafter params)
            if not paged:
                raise ValueError(
                    "speculative decoding requires paged=True: rollback is "
                    "implemented as dropping CoW page forks"
                )
            if temperature > 0.0:
                raise ValueError(
                    "speculative decoding is greedy-only: verification accepts "
                    "the longest argmax-agreeing draft prefix, which is exact "
                    "for temperature=0 and has no sampling analogue here"
                )
            if spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
            if spec_draft[0].vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"drafter vocab_size {spec_draft[0].vocab_size} != target "
                    f"vocab_size {cfg.vocab_size}: drafted token ids must BE "
                    "target token ids for verification to compare them"
                )
        from repro.quant import prepare_params_for_serving
        from repro.serving.ep import MeshCall, init_engine_mesh, place_params

        # EP serving mesh (cfg.ep_mesh): resolve BEFORE cfg is captured by
        # the jit closures below — the mesh rewrites moe_impl to the
        # shard_map serving schedule (serving/ep.py, core/moe_serve.py).
        self._mesh, self._mesh_rules, cfg = init_engine_mesh(cfg)
        self.cfg = cfg
        if spec_draft is not None and self._mesh is not None:
            raise NotImplementedError(
                "speculative decoding is not implemented over an "
                "expert-parallel serving mesh: the verify window's CoW fork "
                "plan is host-side per slot while the mesh replicates the page "
                "pool per rank — run without cfg.ep_mesh / --ep-devices, or "
                "drop --spec-draft"
            )

        if self._mesh is not None:
            from repro.parallel.sharding import use_mesh

            with use_mesh(self._mesh, self._mesh_rules):
                placed = prepare_params_for_serving(cfg, params)
            self.params = place_params(self._mesh, self._mesh_rules, placed)
        else:
            self.params = prepare_params_for_serving(cfg, params)
        self.n_slots = slots
        self.capacity = capacity
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.eos_id = eos_id
        self.kv_cache_bits = kv_cache_bits
        self.paged = paged
        self.prefix_sharing = prefix_sharing
        self.prefill_mode = prefill_mode
        self.prefix: Optional[PrefixIndex] = None
        if paged:
            self.page_size = page_size = int(page_size or 16)
            # tokens of prefill compute per admission tick (0 = auto); chunk
            # boundaries are page-aligned, so at least one page per tick
            self.prefill_chunk = int(prefill_chunk) if prefill_chunk else max(64, page_size)
            if self.prefill_chunk < page_size:
                raise ValueError(
                    f"prefill_chunk={self.prefill_chunk} must be >= page_size="
                    f"{page_size} (chunk boundaries are page-aligned)"
                )
            # prefix sharing skips the shared prefix's prefill COMPUTE only
            # when every mixer's state is paged; window-ring / SSM / LRU
            # per-slot state must be rebuilt by running the prefix (its page
            # writes are trash-routed — shared pages stay read-only)
            self._skip_shared_compute = arch_fully_paged(cfg)
            self.max_pages = -(-capacity // page_size)  # table entries per slot
            # n_pages None/0 = auto: slots * pages-per-capacity, i.e. the
            # contiguous worst case (same convention as EngineConfig/--pages)
            self.n_pages = int(n_pages) if n_pages else slots * self.max_pages
            if self.n_pages < self.max_pages:
                raise ValueError(
                    f"n_pages={self.n_pages} cannot hold even one full-capacity "
                    f"sequence ({self.max_pages} pages of {page_size})"
                )
            self.pool = KVBlockPool(self.n_pages, page_size)
            self.tables = BlockTables(slots, self.max_pages)
            if prefix_sharing:
                self.prefix = PrefixIndex(page_size)
            # kv_cache_bits=8 composes: int8 pages (~4x fewer bytes per cache
            # token) x fragmentation-free packing of those tokens
            self.caches = init_paged_caches(
                cfg, slots, capacity, n_pages=self.n_pages, page_size=page_size,
                kv_bits=kv_cache_bits,
            )
        else:
            # kv_cache_bits=8: pooled slot caches live as int8 QuantizedKV —
            # ~4x more slot-capacity per byte of cache memory; admission
            # prefill and ragged decode quantize on write
            self.caches = init_caches(cfg, slots, capacity, kv_bits=kv_cache_bits)
        if self._mesh is not None:
            # slot (batch) dim data-parallel over the EP axes when divisible;
            # pool pages + block-table state replicated (each rank reads only
            # its slots' pages — the host scheduler stays mesh-agnostic)
            from repro.serving.ep import place_caches

            self.caches = place_caches(
                self._mesh, self._mesh_rules, self.caches, slots=slots,
                n_pages=self.n_pages if paged else None,
            )
        self.slots = [SlotState() for _ in range(slots)]
        self.queue: List[_Pending] = []
        self.done: Dict[int, Response] = {}
        self.preemptions = 0
        self.cow_copies = 0  # pages privately duplicated before a divergent append
        self.prefix_hits = 0  # admissions that shared at least one indexed page
        self.prefix_hit_tokens = 0  # context tokens served from shared pages
        self.prefill_tokens_total = 0  # prompt tokens actually computed at prefill
        # context tokens whose prefill compute was SKIPPED because their K/V
        # was read from shared pages in place (chunked mode only — the
        # scatter oracle recomputes them; == prefix_hit_tokens there)
        self.prefill_tokens_skipped = 0
        self.metrics_log: List[dict] = []
        # Shared prefill budget for the CURRENT tick (None outside step()):
        # admissions triggered mid-tick (a completion freeing a slot for the
        # queue) draw their synchronous first chunk from THIS budget, so one
        # tick never runs more than prefill_chunk tokens of prefill no
        # matter how many admissions it cascades into.
        self._tick_budget: Optional[int] = None
        # prefill tokens computed during the current tick (both chunked and
        # batched modes add to it; _end_tick_prefill drains it)
        self._tick_prefill_done = 0
        # jitted-function invocations since the last recorded tick (every
        # _jit_registry fn call site increments it) and, in batched mode, the
        # fraction of slot rows carrying a real chunk in this tick's batched
        # prefill call — the two "fused tick" gauges
        self._jit_calls_tick = 0
        self._batched_occ_tick = 0.0
        self._metrics_cap = 65_536  # keep a bounded telemetry window
        self.last_metrics: dict = {}
        self._tick = 0
        self._next_id = 0
        self._admit_counter = 0
        self._key = jax.random.PRNGKey(seed)
        self._cur_token = np.zeros((slots,), np.int32)

        # -- observability ------------------------------------------------
        # Default Obs(): metrics on (they ARE the per-tick telemetry source,
        # ~µs/tick), tracer off (no-op fast path), routing collection off
        # (it changes the decode step's jitted return signature, so it is an
        # explicit opt-in at construction).  Obs.disabled() = all-off
        # benchmark baseline for the <1%-overhead guard.
        self.obs = obs if obs is not None else Obs()
        # hoisted enabled-check: the hot path tests one attribute, not three
        self._tr = self.obs.tracer if self.obs.tracer.enabled else None
        M = self.obs.metrics
        self._h_queue = M.histogram("serve.queue_wait_s")
        self._h_ttft = M.histogram("serve.ttft_s")
        self._h_tpot = M.histogram("serve.tpot_s", lo=1e-5, hi=10.0)
        self._h_tick = M.histogram("serve.tick_s")
        self._h_preempts = M.histogram("serve.preempts_per_req", unit="",
                                       lo=1.0, hi=1024.0, n_buckets=10)
        self._c_submitted = M.counter("serve.requests_submitted", unit="req")
        self._c_completed = M.counter("serve.requests_completed", unit="req")
        self._c_decode_toks = M.counter("serve.decode_tokens", unit="tok")
        self._c_prefill_toks = M.counter("serve.prefill_tokens_computed", unit="tok")
        self._c_prefill_skip = M.counter("serve.prefill_tokens_skipped", unit="tok")
        self._c_preempt = M.counter("serve.preemptions")
        self._c_cow = M.counter("serve.cow_copies", unit="page")
        self._c_prefix_hits = M.counter("serve.prefix_hits")
        self._c_prefix_toks = M.counter("serve.prefix_hit_tokens", unit="tok")
        self._c_retraces = M.counter("serve.retraces", unit="compile")
        self._g_active = M.gauge("serve.active_slots")
        self._g_queue = M.gauge("serve.queue_depth")
        self._g_free_pages = M.gauge("serve.free_pages")
        self._g_occupancy = M.gauge("serve.page_occupancy")
        self._g_peak_occ = M.gauge("serve.peak_page_occupancy")
        self._g_shared = M.gauge("serve.shared_pages")
        self._g_jit_calls = M.gauge("serve.jitted_calls_per_tick", unit="call")
        self._g_batch_occ = M.gauge("serve.batched_prefill_occupancy")
        self._g_r_drop = M.gauge("routing.dropped_frac")
        self._g_r_ent = M.gauge("routing.entropy", unit="nat")
        self._g_r_imb = M.gauge("routing.imbalance")
        # per-request SLO state: t_submit/t_admit/t_first/t_last/n_tokens/
        # preempts; popped into histograms at completion
        self._req_obs: Dict[int, dict] = {}
        routing = self.obs.routing

        # -- speculative decoding: drafter + verify plumbing ----------------
        self.spec_k = int(spec_k) if spec_draft is not None else 0
        self.drafter: Optional[Drafter] = None
        self._spec_commit = None
        self._spec_tick_m: dict = {}
        if spec_draft is not None:
            if routing:
                raise ValueError(
                    "routing collection is incompatible with speculative "
                    "decoding: the verify pass replaces the plain decode step "
                    "and does not return RoutingStats"
                )
            dcfg, dparams = spec_draft
            self.drafter = Drafter(
                dcfg, prepare_params_for_serving(dcfg, dparams),
                slots=slots, capacity=capacity, spec_k=self.spec_k,
            )
            self._h_accept = M.histogram(
                "spec.accept_rate", unit="", lo=1.0 / (4 * self.spec_k),
                hi=1.0 + 1e-9, n_buckets=16)
            self._h_tok_verify = M.histogram(
                "spec.tokens_per_verify", unit="tok", lo=1.0,
                hi=float(self.spec_k + 1) + 1e-9, n_buckets=16)
            self._c_spec_drafted = M.counter("spec.draft_tokens", unit="tok")
            self._c_spec_accepted = M.counter("spec.accepted_tokens", unit="tok")
            self._c_spec_verifies = M.counter("spec.verify_windows")
            self._c_spec_commit_pages = M.counter("spec.committed_pages", unit="page")
            self._c_spec_rollback_pages = M.counter("spec.rolled_back_pages", unit="page")
            self._c_spec_resyncs = M.counter("spec.draft_resyncs")

        if paged:
            def _step(params, tokens, positions, active, caches, tables):
                # normalized 3-tuple return (routing = () when collection is
                # off) so the call site rebinds the donated caches in one
                # unpacking assignment — the donation auditor's required shape
                out = paged_ragged_decode_step(
                    cfg, params, tokens, positions, active, caches, tables,
                    return_routing=routing,
                )
                if routing:
                    return out
                logits, caches = out
                return logits, caches, ()

            self._decode = jax.jit(_step, donate_argnums=(4,))

            def _prefill_one(params, tokens, positions, slot, caches, table_row, scatter_start):
                return paged_prefill_into_slot(
                    cfg, params, tokens, positions, slot, caches, table_row,
                    capacity=capacity, kv_bits=kv_cache_bits,
                    scatter_start=scatter_start,
                )

            self._prefill = jax.jit(_prefill_one, donate_argnums=(4,))

            if prefill_mode == "batched":
                # ONE fixed-shape entry covers every mid-prefill slot's chunk
                # per tick; reset/active are traced row masks, so the batched
                # call compiles exactly once — the per-slot first/cont jits
                # are deliberately NOT built in this mode (the jit registry,
                # watchdog, and predict_compiles key sets stay coherent)
                def _prefill_chunk_batched_fn(params, tokens, positions, reset,
                                              active, last_idx, caches, tables):
                    return paged_prefill_chunk_batched(
                        cfg, params, tokens, positions, reset, active, last_idx,
                        caches, tables, capacity=capacity,
                        kv_bits=kv_cache_bits, page_size=page_size,
                    )

                self._prefill_chunk_batched = jax.jit(
                    _prefill_chunk_batched_fn, donate_argnums=(6,))
            else:
                def _prefill_chunk_fn(params, tokens, positions, slot, caches, table_row, *, reset):
                    return paged_prefill_chunk(
                        cfg, params, tokens, positions, slot, caches, table_row,
                        capacity=capacity, kv_bits=kv_cache_bits, page_size=page_size,
                        reset=reset,
                    )

                # one compilation per distinct chunk length (budget + remainders)
                # x {first, continuation} — the first chunk of an admission resets
                # the slot's per-slot leaves (previous occupant's state), later
                # chunks resume them
                self._prefill_chunk_first = jax.jit(
                    functools.partial(_prefill_chunk_fn, reset=True), donate_argnums=(4,))
                self._prefill_chunk_cont = jax.jit(
                    functools.partial(_prefill_chunk_fn, reset=False), donate_argnums=(4,))
            self._reset_pages = jax.jit(
                lambda caches, mask: paged_reset_pages(cfg, caches, mask),
                donate_argnums=(0,),
            )
            # CoW device copy + parallel-sampling slot fork (src/dst traced)
            self._copy_page = jax.jit(
                lambda caches, src, dst: paged_copy_page(cfg, caches, src, dst),
                donate_argnums=(0,),
            )
            self._copy_slot = jax.jit(
                lambda caches, src, dst: paged_copy_slot_leaves(cfg, caches, src, dst),
                donate_argnums=(0,),
            )
            if self.drafter is not None:
                def _verify_fn(params, tokens, positions, active, caches, tables):
                    logits, caches = paged_verify_chunk_batched(
                        cfg, params, tokens, positions, active, caches, tables,
                        capacity=capacity, kv_bits=kv_cache_bits,
                        page_size=page_size,
                    )
                    # greedy-only engine: argmax inside the jit (identical to
                    # sample() at temperature 0) keeps the per-tick host sync
                    # to [slots, k + 1] int32 instead of full logits
                    return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

                self._verify = jax.jit(_verify_fn, donate_argnums=(4,))
                self._spec_reset_tail = jax.jit(
                    lambda caches, pages, offs: paged_reset_page_tails(
                        cfg, caches, pages, offs),
                    donate_argnums=(0,),
                )
                if not arch_fully_paged(cfg):
                    # recurrent per-slot state (window rings, SSM/LRU, conv
                    # prefixes) cannot be rolled back, so verify leaves it
                    # untouched; this separate pass advances it over the
                    # ACCEPTED tokens only, after the page handoff.  Its pool
                    # writes are inert: every accepted position is already
                    # stored in a committed page, so the `already` write guard
                    # trash-routes the rewrite.
                    def _spec_commit_fn(params, tokens, positions, reset,
                                        active, last_idx, caches, tables):
                        return paged_prefill_chunk_batched(
                            cfg, params, tokens, positions, reset, active,
                            last_idx, caches, tables, capacity=capacity,
                            kv_bits=kv_cache_bits, page_size=page_size,
                        )

                    self._spec_commit = jax.jit(
                        _spec_commit_fn, donate_argnums=(6,))
        else:
            def _step(params, tokens, positions, active, caches):
                out = ragged_decode_step(cfg, params, tokens, positions, active, caches,
                                         return_routing=routing)
                if routing:
                    return out
                logits, caches = out
                return logits, caches, ()

            self._decode = jax.jit(_step, donate_argnums=(4,))

            def _prefill_one(params, tokens, positions, slot, caches):
                # single-request prefill written into the pooled caches at `slot`
                return prefill_into_slot(cfg, params, tokens, positions, slot, caches)

            self._prefill = jax.jit(_prefill_one, donate_argnums=(4,))

        # Jit registry: name -> (fn, donate_argnums, primary).  The SINGLE
        # source of truth for which jitted functions exist, what they donate,
        # and which carry the steady-state never-retrace contract — the
        # retrace watchdog registers from it below (primary = non-aux) and
        # the static analysis suite reads it back via jitted_functions() /
        # shape_contract(), so runtime and trace-time checks cannot drift.
        # Non-primary fns legitimately compile late (novel prompt/chunk
        # lengths, first page-reset/CoW): counted, no steady-state warning.
        self._jit_registry = {"decode": (self._decode, (4,), True),
                              "prefill": (self._prefill, (4,), False)}
        if paged:
            if prefill_mode == "batched":
                # fixed-shape, compiles once — it carries the steady-state
                # never-retrace contract alongside decode (primary): the
                # "fused tick" is at most these two dispatches
                self._jit_registry["prefill_chunk_batched"] = (
                    self._prefill_chunk_batched, (6,), True)
            else:
                self._jit_registry.update({
                    "prefill_chunk_first": (self._prefill_chunk_first, (4,), False),
                    "prefill_chunk_cont": (self._prefill_chunk_cont, (4,), False),
                })
            self._jit_registry.update({
                "reset_pages": (self._reset_pages, (0,), False),
                "copy_page": (self._copy_page, (0,), False),
                "copy_slot": (self._copy_slot, (0,), False),
            })
            if self.drafter is not None:
                # fixed [slots, k + 1] / [k + 1, slots] shapes: the whole
                # speculative tick is primary never-retrace machinery except
                # the drafter's lazy per-context-length prefill
                self._jit_registry.update({
                    "verify": (self._verify, (4,), True),
                    "spec_reset_tail": (self._spec_reset_tail, (0,), True),
                    "draft_propose": (self.drafter._propose, (5,), True),
                    "draft_prefill": (self.drafter._prefill, (4,), False),
                })
                if self._spec_commit is not None:
                    self._jit_registry["spec_commit"] = (
                        self._spec_commit, (6,), True)
        if self._mesh is not None:
            # every entry point (execution, lower, eval_shape) runs under the
            # serving mesh; attribute forwarding keeps the watchdog's
            # _cache_size probe and the analysis gate working unchanged
            for _name in list(self._jit_registry):
                _fn, _don, _primary = self._jit_registry[_name]
                _w = MeshCall(_fn, self._mesh, self._mesh_rules)
                self._jit_registry[_name] = (_w, _don, _primary)
                setattr(self, "_" + _name, _w)
        wd = self.obs.watchdog
        for _name, (_fn, _don, _primary) in self._jit_registry.items():
            wd.register(_name, _fn, aux=not _primary)

    # -- declared contracts for the static analysis suite ----------------
    def jitted_functions(self) -> dict:
        """name -> (jitted fn, donate_argnums, primary) for every function a
        tick can invoke — what the donation auditor and contract checker
        audit, and the same classification the retrace watchdog enforces."""
        return dict(self._jit_registry)

    def shape_contract(self) -> list:
        """Declared compile-shape contract: the CLOSED set of signatures each
        jitted function may be called with, derived from the same config
        values that size the real buffers (slots / capacity / page geometry /
        chunk budget).  ``analysis.contracts.check_contract`` abstract-traces
        these; ``check_closure`` verifies scheduler-reachable states stay
        inside them."""
        from repro.analysis.contracts import ContractEntry

        aval = lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        params = jax.tree.map(aval, self.params)
        caches = jax.tree.map(aval, self.caches)
        i32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)
        boolv = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.bool_)
        S = self.n_slots

        def entry(name, make, points, sample):
            fn, don, primary = self._jit_registry[name]
            return ContractEntry(name=name, fn=fn, make=make,
                                 points=tuple(points), sample=tuple(sample),
                                 primary=primary, donate_argnums=don)

        # admission context lengths: at least `remaining` of the capacity is
        # reserved for decode, so a prefilled context never exceeds cap - 1
        ctx_lens = range(1, self.capacity)
        ctx_sample = sorted({1, 2, min(16, self.capacity - 1), self.capacity - 1})
        out = []
        if self.paged:
            MP = self.max_pages
            out.append(entry(
                "decode",
                lambda: (params, i32(S, 1), i32(S), boolv(S), caches, i32(S, MP)),
                [()], [()]))
            out.append(entry(
                "prefill",
                lambda n: (params, i32(1, n), i32(1, n), i32(), caches, i32(MP), i32()),
                [(n,) for n in ctx_lens], [(n,) for n in ctx_sample]))
            if self.prefill_mode == "batched":
                # ONE fixed signature: [slots, prefill_chunk] stacked chunks
                # (ragged rows ride as -1-padded positions), so the batched
                # entry has a singleton contract — the static-shape property
                # that makes it a primary never-retrace function
                C = self.prefill_chunk
                out.append(entry(
                    "prefill_chunk_batched",
                    lambda: (params, i32(S, C), i32(S, C), boolv(S), boolv(S),
                             i32(S), caches, i32(S, MP)),
                    [()], [()]))
            else:
                # chunk lengths: non-final chunks are page-aligned budget
                # slices, the final chunk is the context remainder — any
                # length from 1 to the per-tick budget is admissible, nothing
                # longer
                chunk_lens = range(1, self.prefill_chunk + 1)
                chunk_sample = sorted({1, max(1, self.page_size - 1), self.page_size,
                                       min(self.page_size + 1, self.prefill_chunk),
                                       self.prefill_chunk})
                for nm in ("prefill_chunk_first", "prefill_chunk_cont"):
                    out.append(entry(
                        nm,
                        lambda n: (params, i32(1, n), i32(1, n), i32(), caches, i32(MP)),
                        [(n,) for n in chunk_lens], [(n,) for n in chunk_sample]))
            out.append(entry(
                "reset_pages",
                lambda: (caches, jax.ShapeDtypeStruct((self.n_pages + 1,), jnp.bool_)),
                [()], [()]))
            for nm in ("copy_page", "copy_slot"):
                out.append(entry(nm, lambda: (caches, i32(), i32()), [()], [()]))
            if self.drafter is not None:
                K1 = self.spec_k + 1
                dparams = jax.tree.map(aval, self.drafter.params)
                dcaches = jax.tree.map(aval, self.drafter.caches)
                out.append(entry(
                    "verify",
                    lambda: (params, i32(S, K1), i32(S, K1), boolv(S), caches,
                             i32(S, MP)),
                    [()], [()]))
                out.append(entry(
                    "spec_reset_tail",
                    lambda: (caches, i32(S), i32(S)),
                    [()], [()]))
                if "spec_commit" in self._jit_registry:
                    out.append(entry(
                        "spec_commit",
                        lambda: (params, i32(S, K1), i32(S, K1), boolv(S),
                                 boolv(S), i32(S), caches, i32(S, MP)),
                        [()], [()]))
                out.append(entry(
                    "draft_propose",
                    lambda: (dparams, i32(K1, S), boolv(K1, S), i32(K1, S),
                             boolv(K1, S), dcaches),
                    [()], [()]))
                # lazy drafter (re)prefill: one [1, n] signature per distinct
                # committed-sequence length, same family as admission prefill
                out.append(entry(
                    "draft_prefill",
                    lambda n: (dparams, i32(1, n), i32(1, n), i32(), dcaches),
                    [(n,) for n in ctx_lens], [(n,) for n in ctx_sample]))
        else:
            out.append(entry(
                "decode",
                lambda: (params, i32(S, 1), i32(S), boolv(S), caches),
                [()], [()]))
            out.append(entry(
                "prefill",
                lambda n: (params, i32(1, n), i32(1, n), i32(), caches),
                [(n,) for n in ctx_lens], [(n,) for n in ctx_sample]))
        return out

    # -- request-lifecycle observability hooks -------------------------
    # Span taxonomy (docs/OBSERVABILITY.md): track ("request", rid) carries
    # queued -> prefill -> decode spans (preempted / prefix_hit / complete
    # instants); track ("slot", i) carries an occupancy span per admission
    # with nested prefill-chunk spans; track ("engine", 0) carries tick
    # spans plus cow_copy / preempt / retrace instants.

    def _obs_submitted(self, rid: int) -> None:
        self._c_submitted.inc()
        self._req_obs[rid] = {
            "t_submit": time.perf_counter(), "t_admit": None, "t_first": None,
            "t_last": None, "n_tokens": 0, "preempts": 0,
        }
        if self._tr:
            self._tr.begin(("request", rid), "queued")

    def _obs_admitted(self, rid: int, i: int) -> None:
        now = time.perf_counter()
        ro = self._req_obs.get(rid)
        if ro is not None and ro["t_admit"] is None:
            ro["t_admit"] = now
            self._h_queue.observe(now - ro["t_submit"])
        if self._tr:
            self._tr.end(("request", rid), ts=now)  # queued
            self._tr.begin(("request", rid), "prefill", ts=now)
            self._tr.begin(("slot", i), f"req{rid}", ts=now)

    def _obs_admitted_fork(self, rid: int, i: int, base_rid: int) -> None:
        now = time.perf_counter()
        ro = self._req_obs.get(rid)
        if ro is not None and ro["t_admit"] is None:
            ro["t_admit"] = now
            self._h_queue.observe(now - ro["t_submit"])
        if self._tr:
            self._tr.end(("request", rid), ts=now)  # queued
            self._tr.instant(("request", rid), "prefix_hit", ts=now,
                             args={"fork_of": base_rid})
            self._tr.begin(("request", rid), "decode", ts=now)
            self._tr.begin(("slot", i), f"req{rid}", ts=now)

    def _obs_token(self, rid: int, now: float) -> None:
        """One generated token: TTFT on the first, TPOT on the rest.  TPOT
        intervals broken by a preemption are dropped (t_last is reset) — the
        re-queue wait is preemption cost, not inter-token latency."""
        ro = self._req_obs.get(rid)
        if ro is None:
            return
        if ro["t_first"] is None:
            ro["t_first"] = now
            self._h_ttft.observe(now - ro["t_submit"])
        elif ro["t_last"] is not None:
            self._h_tpot.observe(now - ro["t_last"])
        ro["t_last"] = now
        ro["n_tokens"] += 1

    def _obs_first_token(self, rid: int) -> None:
        """Prefill finished and the first token was sampled: flip the
        request track from its prefill span to a decode span."""
        now = time.perf_counter()
        self._obs_token(rid, now)
        if self._tr:
            self._tr.end(("request", rid), ts=now)  # prefill
            self._tr.begin(("request", rid), "decode", ts=now)

    def _obs_completed(self, rid: int) -> None:
        now = time.perf_counter()
        ro = self._req_obs.pop(rid, None)
        if ro is not None:
            self._c_completed.inc()
            self._h_preempts.observe(ro["preempts"])
        if self._tr:
            self._tr.end(("request", rid), ts=now)  # decode
            self._tr.instant(("request", rid), "complete", ts=now)

    # ------------------------------------------------------------------
    def _clamped_budget(self, req: Request) -> int:
        # Budget clamp: the response plus at least one context token must fit
        # the per-sequence capacity (a budget >= capacity used to flip the
        # prompt-truncation index positive and keep the WRONG end of the
        # prompt — or nothing at all).
        return max(1, min(req.max_new_tokens, self.capacity - 1))

    def submit(self, req: Request) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(_Pending(
            rid=rid, prompt=list(req.prompt), budget=self._clamped_budget(req),
            generated=[], prompt_len=len(req.prompt),
        ))
        self._obs_submitted(rid)
        self._admit()
        return rid

    def submit_n(self, req: Request, n: int) -> List[int]:
        """Submit ``n`` parallel samples of one prompt (one request id each).
        Under ``prefix_sharing`` the samples are page-aligned: the first is
        admitted normally and the rest fork it — block tables share ALL its
        prompt pages (including the partial boundary page) and per-slot
        ring/SSM/cross state is row-copied, so n samples cost one prompt's
        pages + one prefill until they diverge via copy-on-write.  Without
        sharing (or when slots/pages force staggered admission) each sample
        is served as an independent request — same tokens, no sharing."""
        if n < 1:
            raise ValueError(f"need n >= 1 samples, got {n}")
        budget = self._clamped_budget(req)
        rids: List[int] = []
        for j in range(n):
            rid = self._next_id
            self._next_id += 1
            self.queue.append(_Pending(
                rid=rid, prompt=list(req.prompt), budget=budget,
                generated=[], prompt_len=len(req.prompt),
                fork_of=rids[0] if j else -1,
            ))
            rids.append(rid)
            self._obs_submitted(rid)
        self._admit()
        return rids

    # ------------------------------------------------------------------
    def _fork_base_slot(self, item: _Pending) -> Optional[int]:
        """Slot index of ``item``'s fork base, iff the base is still exactly
        at its admission state: active, no decode tick since admission (its
        cache holds the prompt and nothing else — the single generated token
        is sampled but not yet written), prefill logits stashed.  Any other
        state means the boundary page already holds divergent tokens, so the
        fork must not share it and degrades to a normal admission."""
        if self.prefix is None or item.fork_of < 0:
            return None
        for b, s in enumerate(self.slots):
            if (s.active and not s.prefilling and s.request_id == item.fork_of
                    and len(s.generated) == 1 and s.prefill_logits is not None):
                return b
        return None

    def _fork_base_prefilling(self, item: _Pending) -> bool:
        """True while ``item``'s fork base is still mid-chunked-prefill: the
        base's pages are incomplete, so the fork can neither share them nor
        sensibly degrade (the base WILL reach its shareable admission state
        in a bounded number of ticks).  The queue head blocks — consistent
        with FIFO admission never skipping the head."""
        if self.prefix is None or item.fork_of < 0:
            return False
        return any(
            s.active and s.prefilling and s.request_id == item.fork_of
            for s in self.slots
        )

    def _admit_fork(self, i: int, b: int, item: _Pending) -> None:
        """Admit ``item`` into slot ``i`` as a page-aligned parallel sample of
        slot ``b``: share every page ``b`` holds (refcount + 1 each), point
        ``i``'s table at them, row-copy the per-slot leaves (window rings,
        SSM/LRU, cross), and draw the fork's first token from the base's
        stashed prefill logits.  Zero new pages, zero prefill compute; the
        first divergent append copy-on-writes the boundary page."""
        base = self.slots[b]
        self._obs_admitted_fork(item.rid, i, base.request_id)
        pages = [int(p) for p in self.tables.row(b) if p >= 0]
        self.pool.share(pages, owner=i)
        self.tables.copy_row(i, b)
        self._jit_calls_tick += 1
        self.caches = self._copy_slot(
            self.caches, jnp.asarray(b, jnp.int32), jnp.asarray(i, jnp.int32)
        )
        self._key, sub = jax.random.split(self._key)
        # analysis: allow(host-cast) — the fork's first token must reach the Python scheduler (slot state, _cur_token) before the next tick
        first = int(sample(jnp.asarray(base.prefill_logits), sub,
                           temperature=self.temperature,
                           top_k=self.top_k, top_p=self.top_p)[0])
        self.slots[i] = SlotState(
            request_id=item.rid, pos=base.pos, generated=[first],
            budget=item.budget, active=True, admit_seq=self._admit_counter,
            prompt_len=item.prompt_len, prompt=item.prompt,
            prefill_logits=base.prefill_logits,
            seq=base.seq[:-1] + [first],
        )
        self._admit_counter += 1
        self._cur_token[i] = first
        self.prefix_hits += 1
        self.prefix_hit_tokens += base.pos
        self._c_prefix_hits.inc()
        self._c_prefix_toks.inc(base.pos)
        self._obs_token(item.rid, time.perf_counter())
        self._finish_if_done(i)

    def _admit(self) -> None:
        """FIFO admission: fill free slots from the queue head.  In paged
        mode a request is only admitted when the pool has enough free pages
        for its prompt (admission by free-block count); the queue head blocks
        rather than being skipped, so long requests cannot starve.  Under
        prefix sharing, pages covering an indexed full-page prefix of the
        context are shared rather than allocated, and only the tail is
        prefilled into fresh pages.

        With ``prefill_mode="chunked"`` (default, paged) admission reserves
        the slot and ALL the prompt's pages, runs the first chunk of prefill
        synchronously, and leaves the slot ``prefilling`` — subsequent chunks
        run one budget per ``step()`` interleaved with decode.  The scatter
        mode (and the contiguous engine) prefill the whole context here."""
        while self.queue:
            free = [i for i, s in enumerate(self.slots) if not s.active]
            if not free:
                return
            i = free[0]
            item = self.queue[0]
            fork_base = self._fork_base_slot(item)
            if fork_base is not None:
                self.queue.pop(0)
                self._admit_fork(i, fork_base, item)
                continue
            if self._fork_base_prefilling(item):
                return  # the base reaches its shareable state in O(ticks)
            remaining = item.budget - len(item.generated)
            # keep the LAST (capacity - remaining) context tokens: the newest
            # prompt suffix, leaving exactly `remaining` cache tokens to decode
            keep = self.capacity - remaining
            ctx = (item.prompt + item.generated)[-keep:]
            shared: List[int] = []
            if self.paged:
                if self.prefix is not None:
                    # cap the match so at least one context token is left to
                    # prefill — last-token logits seed the first sample
                    shared = self.prefix.lookup(ctx, max_tokens=len(ctx) - 1)
                fresh = self.pool.alloc(
                    self.pool.pages_for(len(ctx)) - len(shared), owner=i)
                if fresh is None:
                    return  # wait for frees / completions
                if shared:
                    self.pool.share(shared, owner=i)
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += len(shared) * self.page_size
                    self._c_prefix_hits.inc()
                    self._c_prefix_toks.inc(len(shared) * self.page_size)
                    if self._tr:
                        self._tr.instant(("request", item.rid), "prefix_hit",
                                         args={"tokens": len(shared) * self.page_size})
                self.tables.append(i, shared + fresh)
            self.queue.pop(0)
            self._obs_admitted(item.rid, i)
            if self.paged and self.prefill_mode in ("chunked", "batched"):
                # resumable admission: pages are reserved, compute is spread
                # over ticks.  On fully-paged archs shared-prefix positions
                # are never computed at all — their K/V is read from the
                # shared pages in place; ring/SSM archs recompute the prefix
                # (state rebuild) but still never write the shared pages.
                start = len(shared) * self.page_size if self._skip_shared_compute else 0
                self.prefill_tokens_skipped += start
                self._c_prefill_skip.inc(start)
                self.slots[i] = SlotState(
                    request_id=item.rid, pos=start, generated=list(item.generated),
                    budget=item.budget, active=True, admit_seq=self._admit_counter,
                    prompt_len=item.prompt_len, prompt=item.prompt,
                    prefilling=True, prefill_ctx=ctx, prefill_done=start,
                )
                self._admit_counter += 1
                if self.prefill_mode == "chunked":
                    self._advance_prefill(i)
                # batched mode: the slot joins the NEXT tick's single batched
                # prefill call — admission itself launches no compute
                continue
            toks = jnp.asarray(np.asarray(ctx, np.int32)[None])
            pos = jnp.arange(len(ctx), dtype=jnp.int32)[None]
            self._jit_calls_tick += 1
            if self.paged:
                # scatter oracle: full-context prefill into a temp contiguous
                # cache; shared-prefix positions are recomputed but their
                # writes are routed to the trash page — a shared page is
                # never written by an admission
                logits, self.caches = self._prefill(
                    self.params, toks, pos, jnp.asarray(i, jnp.int32), self.caches,
                    jnp.asarray(self.tables.row(i)),
                    jnp.asarray(len(shared) * self.page_size, jnp.int32),
                )
            else:
                logits, self.caches = self._prefill(
                    self.params, toks, pos, jnp.asarray(i, jnp.int32), self.caches
                )
            self.prefill_tokens_total += len(ctx)
            self._c_prefill_toks.inc(len(ctx))
            self._key, sub = jax.random.split(self._key)
            # analysis: allow(host-cast) — admission's first sampled token feeds Python slot state; the sync is the admission boundary, not the tick
            first = int(sample(logits, sub, temperature=self.temperature,
                               top_k=self.top_k, top_p=self.top_p)[0])
            # analysis: allow(host-asarray) — logits already host-synced by the cast above; the stash is what forks sample from without a recompute
            stash = np.asarray(logits) if self.prefix is not None else None
            self.slots[i] = SlotState(
                request_id=item.rid, pos=len(ctx), generated=item.generated + [first],
                budget=item.budget, active=True, admit_seq=self._admit_counter,
                prompt_len=item.prompt_len, prompt=item.prompt,
                prefill_logits=stash,
                seq=list(ctx) + [first],
            )
            self._admit_counter += 1
            self._cur_token[i] = first
            self._obs_first_token(item.rid)
            if self.prefix is not None:
                # register this context's full pages (shared entries are
                # already indexed and keep their mapping; fresh full pages
                # become shareable for future admissions)
                n_full = len(ctx) // self.page_size
                if n_full:
                    self.prefix.insert(ctx, [int(p) for p in self.tables.row(i)[:n_full]])
            self._finish_if_done(i)

    # ------------------------------------------------------------------
    def _advance_prefill(self, i: int) -> int:
        """Run slot ``i``'s chunked prefill up to the available budget — the
        current tick's shared ``_tick_budget`` when inside ``step()``, one
        full ``prefill_chunk`` when admission happens outside a tick
        (``submit()``) — and return the number of tokens computed.  Chunk
        boundaries are page-aligned (every non-final chunk fills whole pages
        and direct page writes never straddle a tick); the final chunk takes
        the remainder, and a leftover budget smaller than a page defers to
        the next tick rather than emitting an unaligned sub-page chunk
        (which would also cost a fresh XLA compilation per odd length).
        Full pages are registered in the prefix index PROGRESSIVELY, as soon
        as their chunk is written — an indexed page must already hold its
        K/V (another admission may share it the moment it appears), and
        indexing per chunk lets concurrent admissions share a long prompt's
        preamble while its tail is still being prefilled.  On the last chunk
        the returned logits seed the request's first sampled token."""
        slot = self.slots[i]
        done = 0
        # outside a tick (admission from submit()), one chunk budget total
        local_budget = self.prefill_chunk if self._tick_budget is None else None
        while slot.active and slot.prefilling:
            budget = self._tick_budget if local_budget is None else local_budget
            if budget <= 0:
                break
            ctx = slot.prefill_ctx
            start = slot.prefill_done
            end = min(len(ctx), start + budget)
            if end < len(ctx):
                aligned = end - (end % self.page_size)
                if aligned <= start:
                    break  # leftover budget < one page — resume next tick
                end = aligned
            toks = jnp.asarray(np.asarray(ctx[start:end], np.int32)[None])
            pos = jnp.arange(start, end, dtype=jnp.int32)[None]
            fn = self._prefill_chunk_cont if slot.prefill_started else self._prefill_chunk_first
            if self._tr:
                self._tr.begin(("slot", i), f"chunk[{start}:{end})",
                               args={"rid": slot.request_id})
            self._jit_calls_tick += 1
            logits, self.caches = fn(
                self.params, toks, pos, jnp.asarray(i, jnp.int32), self.caches,
                jnp.asarray(self.tables.row(i)),
            )
            if self._tr:
                self._tr.end(("slot", i))
            slot.prefill_started = True
            n = end - start
            done += n
            self.prefill_tokens_total += n
            self._c_prefill_toks.inc(n)
            if local_budget is None:
                self._tick_budget -= n
                self._tick_prefill_done += n
            else:
                local_budget -= n
            slot.prefill_done = slot.pos = end
            if self.prefix is not None:
                # progressive registration: every page this chunk completed
                # is shareable NOW (existing mappings — the shared prefix
                # itself — are kept, first writer wins)
                n_full = end // self.page_size
                if n_full:
                    self.prefix.insert(ctx, [int(p) for p in self.tables.row(i)[:n_full]])
            if end == len(ctx):
                self._key, sub = jax.random.split(self._key)
                # analysis: allow(host-cast) — last-chunk logits seed the request's first token; it must land in Python slot state this tick
                first = int(sample(logits, sub, temperature=self.temperature,
                                   top_k=self.top_k, top_p=self.top_p)[0])
                slot.prefilling = False
                slot.prefill_ctx = []
                slot.generated = slot.generated + [first]
                slot.seq = list(ctx) + [first]
                # analysis: allow(host-asarray) — already synced by the cast above; stashed for fork admission
                slot.prefill_logits = np.asarray(logits) if self.prefix is not None else None
                self._cur_token[i] = first
                self._obs_first_token(slot.request_id)
                self._finish_if_done(i)
                if self.queue:
                    # a fork blocked on THIS slot's prefill can now share it
                    self._admit()
        return done

    def _prefill_tick(self) -> None:
        """One tick's worth of admission prefill: advance prefilling slots in
        admission order against the tick's shared ``_tick_budget`` (set by
        ``step()``, spanning the WHOLE tick so completions that cascade into
        fresh admissions — during this pass or the decode phase — draw their
        first chunk from the same budget)."""
        order = sorted(
            (i for i, s in enumerate(self.slots) if s.active and s.prefilling),
            key=lambda i: self.slots[i].admit_seq,
        )
        for i in order:
            if self._tick_budget <= 0:
                break
            self._advance_prefill(i)

    def _prefill_tick_batched(self) -> None:
        """One tick's admission prefill as a SINGLE jitted call: every
        mid-prefill slot advances one chunk (up to ``prefill_chunk`` tokens
        each, page-aligned boundaries — the same per-slot chunk arithmetic as
        ``_advance_prefill``) through the fixed-shape batched entry.  Rows
        without a chunk this tick ride along inactive: all--1 table rows
        (pool writes trash-routed) and a masked per-slot-leaf merge inside
        the model entry keep their state untouched.  Finalization — first
        sampled token, progressive prefix-index registration, completion /
        cascaded admission — replays ``_advance_prefill``'s final-chunk
        semantics per finishing row, in admission order."""
        order = sorted(
            (i for i, s in enumerate(self.slots) if s.active and s.prefilling),
            key=lambda i: self.slots[i].admit_seq,
        )
        plan: Dict[int, tuple] = {}
        for i in order:
            slot = self.slots[i]
            ctx, start = slot.prefill_ctx, slot.prefill_done
            end = min(len(ctx), start + self.prefill_chunk)
            if end < len(ctx):
                end -= end % self.page_size
                if end <= start:
                    continue  # < one page of room — resume next tick
            plan[i] = (start, end)
        if not plan:
            return
        S, C = self.n_slots, self.prefill_chunk
        tokens = np.zeros((S, C), np.int32)
        positions = np.full((S, C), -1, np.int32)
        reset = np.zeros((S,), bool)
        active = np.zeros((S,), bool)
        last_idx = np.zeros((S,), np.int32)
        tbl = np.full((S, self.max_pages), -1, np.int32)
        for i, (start, end) in plan.items():
            slot = self.slots[i]
            n = end - start
            tokens[i, :n] = np.asarray(slot.prefill_ctx[start:end], np.int32)
            positions[i, :n] = np.arange(start, end, dtype=np.int32)
            reset[i] = not slot.prefill_started
            active[i] = True
            last_idx[i] = n - 1
            tbl[i] = self.tables.row(i)
            if self._tr:
                self._tr.begin(("slot", i), f"chunk[{start}:{end})",
                               args={"rid": slot.request_id})
        self._jit_calls_tick += 1
        self._batched_occ_tick = len(plan) / S
        logits, self.caches = self._prefill_chunk_batched(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(reset), jnp.asarray(active), jnp.asarray(last_idx),
            self.caches, jnp.asarray(tbl),
        )
        if self._tr:
            for i in plan:
                self._tr.end(("slot", i))
        logits_host: Optional[np.ndarray] = None
        for i, (start, end) in plan.items():  # dict preserves admission order
            slot = self.slots[i]
            n = end - start
            self._tick_prefill_done += n
            self.prefill_tokens_total += n
            self._c_prefill_toks.inc(n)
            slot.prefill_started = True
            slot.prefill_done = slot.pos = end
            ctx = slot.prefill_ctx
            if self.prefix is not None:
                # progressive registration, same as the per-slot path: pages
                # this chunk completed are shareable NOW
                n_full = end // self.page_size
                if n_full:
                    self.prefix.insert(ctx, [int(p) for p in self.tables.row(i)[:n_full]])
            if end == len(ctx):
                if logits_host is None:
                    # analysis: allow(host-asarray) — ONE sync serves every row finishing its prompt this tick; their first tokens must land in Python slot state
                    logits_host = np.asarray(logits)
                row = logits_host[i : i + 1]
                self._key, sub = jax.random.split(self._key)
                # analysis: allow(host-cast) — the finishing row's first sampled token feeds Python slot state (eos/budget/fork decisions)
                first = int(sample(jnp.asarray(row), sub, temperature=self.temperature,
                                   top_k=self.top_k, top_p=self.top_p)[0])
                slot.prefilling = False
                slot.prefill_ctx = []
                slot.generated = slot.generated + [first]
                slot.seq = list(ctx) + [first]
                slot.prefill_logits = row.copy() if self.prefix is not None else None
                self._cur_token[i] = first
                self._obs_first_token(slot.request_id)
                self._finish_if_done(i)
        if self.queue:
            # a fork blocked on a just-finished base's prefill can now share it
            self._admit()

    def _end_tick_prefill(self) -> int:
        """Close the tick's prefill budget; returns tokens spent this tick."""
        if self._tick_budget is None:
            return 0
        done = self._tick_prefill_done
        self._tick_budget = None
        self._tick_prefill_done = 0
        return done

    def _release_slot(self, i: int) -> None:
        if self._tr:
            self._tr.end(("slot", i))  # occupancy span opened at admission
        if self.paged:
            # decref everything the slot holds; only pages whose refcount hit
            # zero are actually freed — pages another slot still references
            # stay live, mapped, and (if full) indexed for future sharing
            freed = self.pool.release(i)
            self.tables.reset(i)
            if self.drafter is not None:
                # lazy re-prefill covers the next occupant (or this request's
                # re-admission after preemption) at its first speculative tick
                self.drafter.invalidate(i)
            if freed:
                if self.prefix is not None:
                    self.prefix.evict_pages(freed)
                # invalidate the recycled pages' positions device-side, or a
                # later owner would see the previous occupant's stale K/V
                mask = np.zeros((self.n_pages + 1,), bool)
                mask[freed] = True
                self._jit_calls_tick += 1
                self.caches = self._reset_pages(self.caches, jnp.asarray(mask))
        self.slots[i] = SlotState()

    def _finish_if_done(self, i: int) -> None:
        slot = self.slots[i]
        if not slot.active or slot.prefilling:
            return
        hit_eos = self.eos_id >= 0 and slot.generated and slot.generated[-1] == self.eos_id
        if len(slot.generated) >= slot.budget or hit_eos:
            gen = slot.generated
            if hit_eos:
                gen = gen[:-1]
            self.done[slot.request_id] = Response(tokens=gen, prompt_len=slot.prompt_len)
            self._obs_completed(slot.request_id)
            self._release_slot(i)
            self._admit()

    def _preempt(self, i: int) -> None:
        """Push slot ``i`` back to the queue head and free its pages.  The
        request resumes later by re-prefilling prompt + generated-so-far, so
        greedy decoding continues token-exact."""
        slot = self.slots[i]
        rid = slot.request_id
        self.queue.insert(0, _Pending(
            rid=rid, prompt=slot.prompt, budget=slot.budget,
            generated=slot.generated, prompt_len=slot.prompt_len,
        ))
        ro = self._req_obs.get(rid)
        if ro is not None:
            ro["preempts"] += 1
            ro["t_last"] = None  # don't count re-queue wait as TPOT
        if self._tr:
            now = time.perf_counter()
            self._tr.instant(("request", rid), "preempted", ts=now)
            self._tr.end(("request", rid), ts=now)  # decode (or prefill) span
            self._tr.begin(("request", rid), "queued", ts=now)
            self._tr.instant(("engine", 0), "preempt", ts=now,
                             args={"rid": rid, "slot": i})
        self._release_slot(i)
        self.preemptions += 1
        self._c_preempt.inc()

    def _youngest_active(self) -> int:
        return max(
            (j for j, s in enumerate(self.slots) if s.active),
            key=lambda j: self.slots[j].admit_seq,
        )

    def _ensure_pages(self) -> None:
        """Pre-tick page discipline, per active slot in admission order:

        1. **Lazy table growth** — map a page for the slot's write position;
           when the pool is dry the *youngest* active slot is preempted
           (LIFO — the request with the least sunk prefill/decode work
           re-queues).
        2. **Copy-on-write** — if the write-position page has refcount > 1
           (a prefix/fork sharer), fork it: allocate a private page, copy the
           device contents, remap this slot's table entry, decref the
           original.  After this pass every DECODING slot's write page has
           refcount 1, which is the invariant that makes shared pages
           read-only under decode.

        Mid-prefill slots are skipped: their pages were all reserved at
        admission (no growth needed) and chunks write only freshly-allocated
        refcount-1 pages (shared prefix pages are page-aligned and strictly
        before the first chunk, so no CoW either)."""
        order = sorted(
            (i for i, s in enumerate(self.slots) if s.active and not s.prefilling),
            key=lambda i: self.slots[i].admit_seq,
        )
        for i in order:
            slot = self.slots[i]
            while slot.active and self.tables.n_mapped(i) <= slot.pos // self.page_size:
                got = self.pool.alloc(1, owner=i)
                if got is not None:
                    self.tables.append(i, got)
                    continue
                victim = self._youngest_active()
                self._preempt(victim)
                if victim == i:
                    break  # this slot itself re-queued; stop growing it
            while self.slots[i].active:
                entry = slot.pos // self.page_size
                page = int(self.tables.row(i)[entry])
                if self.pool.refcount(page) <= 1:
                    break
                new = self.pool.fork(page, i)
                if new is None:
                    victim = self._youngest_active()
                    self._preempt(victim)
                    if victim == i:
                        break  # re-queued; a sharer keeps the page alive
                    continue  # a preemption may even have dropped the refcount
                self._jit_calls_tick += 1
                self.caches = self._copy_page(
                    self.caches, jnp.asarray(page, jnp.int32), jnp.asarray(new, jnp.int32)
                )
                self.tables.set_entry(i, entry, new)
                self.cow_copies += 1
                self._c_cow.inc()
                if self._tr:
                    self._tr.instant(("engine", 0), "cow_copy",
                                     args={"slot": i, "src": page, "dst": new})

    # -- speculative decoding (serving/spec.py holds the drafter) --------
    def _spec_plan_pages(self, decoding) -> Dict[int, dict]:
        """Per-slot speculation plan: window size ``k`` and the page run the
        verify pass writes.  The window covers positions ``p .. p + k`` (the
        unwritten current token plus ``k`` drafted ones), i.e. table entries
        ``e0 = p // ps`` through ``e1 = (p + k) // ps``:

          * the BOUNDARY entry ``e0`` (only when ``p`` is mid-page) is the
            partially-filled tail page.  If it is shared (refcount > 1) the
            plan forks it — fresh page + device copy — and the verify table
            points at the fork, so the shared base is never written (commit
            = refcount handoff, ``KVBlockPool.commit_fork_run``).  A private
            (refcount 1) boundary is written in place: the boundary entry
            ALWAYS commits (at least one token is emitted per window), so
            in-place writes are never rolled back — stale tail entries past
            the accepted point are handled by ``_spec_reset_tail``;
          * entries beyond ``e0`` are fresh pages, allocated all-or-nothing
            with the same preempt-youngest discipline as ``_ensure_pages``
            (which spec mode replaces: the plan subsumes lazy growth + CoW).
            On commit, entries up to the last accepted position's page join
            the block table; the rest roll back via ``drop_fork_run``.

        A dry pool preempts the youngest active slot — possibly one already
        planned (its plan is discarded below; ``_release_slot`` already freed
        its window pages) or the slot being planned (skipped)."""
        ps = self.page_size
        order = sorted((i for i in range(self.n_slots) if decoding[i]),
                       key=lambda i: self.slots[i].admit_seq)
        plans: Dict[int, dict] = {}
        for i in order:
            slot = self.slots[i]
            if not slot.active or slot.prefilling:
                continue  # preempted by an earlier plan's allocation
            p = slot.pos
            remaining = slot.budget - len(slot.generated)
            k = max(0, min(self.spec_k, remaining - 1, self.capacity - 1 - p))
            e0, e1 = p // ps, (p + k) // ps
            while True:
                slot = self.slots[i]
                if not slot.active or slot.prefilling:
                    break  # this slot itself was preempted; no plan
                boundary = int(self.tables.row(i)[e0]) if p % ps else -1
                fork_boundary = boundary >= 0 and self.pool.refcount(boundary) > 1
                need = (e1 - e0) + (0 if p % ps else 1) + (1 if fork_boundary else 0)
                got = self.pool.alloc(need, owner=i)
                if got is not None:
                    window: Dict[int, int] = {}
                    fork = -1
                    if fork_boundary:
                        fork = got.pop()
                        self._jit_calls_tick += 1
                        self.caches = self._copy_page(
                            self.caches, jnp.asarray(boundary, jnp.int32),
                            jnp.asarray(fork, jnp.int32))
                        self.cow_copies += 1
                        self._c_cow.inc()
                        window[e0] = fork
                    elif boundary >= 0:
                        window[e0] = boundary  # private: write in place
                    for e, pg in zip((e for e in range(e0, e1 + 1)
                                      if e not in window), got):
                        window[e] = pg
                    plans[i] = dict(
                        rid=slot.request_id, k=k, p=p, e0=e0, e1=e1,
                        window=window,
                        boundary_base=boundary if fork_boundary else -1,
                        boundary_fork=fork,
                    )
                    break
                victim = self._youngest_active()
                self._preempt(victim)
                if victim == i:
                    break
        # drop plans whose slot was preempted by a later allocation — its
        # window pages were freed (and device-reset) by _release_slot
        return {i: pl for i, pl in plans.items()
                if self.slots[i].active and not self.slots[i].prefilling
                and self.slots[i].request_id == pl["rid"]}

    def _spec_decode_tick(self, decoding) -> int:
        """One speculative decode tick: draft ``k`` tokens per decoding slot,
        verify every slot's ``k + 1`` window positions in ONE batched target
        pass over CoW-forked tail pages, commit the longest argmax-agreeing
        prefix (plus the target's own token at the first disagreement) by
        refcount handoff, and roll back the rejected suffix by dropping fork
        pages.  Token-exact vs the non-speculative greedy engine by
        construction (tests/test_spec.py).  Returns #tokens emitted.

        Ordering within the tick (each phase one jitted call at most):
        plan (may preempt) -> drafter sync/propose -> verify -> host
        accept/commit bookkeeping (pages, tables, slot state) -> committed
        recurrent-state pass (non-fully-paged archs) -> batched page-tail
        invalidation + rollback page resets -> completions (which may cascade
        admissions; they must run AFTER the commit pass or a fresh occupant's
        first chunk could be clobbered)."""
        plans = self._spec_plan_pages(decoding)
        if not plans:
            return 0
        ps = self.page_size
        S, C = self.n_slots, self.spec_k + 1

        # --- draft: lazily (re)sync the drafter, then ONE propose scan
        if self._tr:
            self._tr.begin(("engine", 0), "spec_draft")
        need_draft = []
        for i, pl in plans.items():
            slot = self.slots[i]
            if pl["k"] == 0:
                continue  # window is just the current token; nothing to draft
            if self.drafter.needs_sync(i, slot.pos):
                if self.drafter.next_pos[i] >= 0:
                    self._c_spec_resyncs.inc()
                    self._spec_tick_m["resyncs"] = \
                        self._spec_tick_m.get("resyncs", 0) + 1
                self._jit_calls_tick += 1
                self.drafter.sync(i, slot.seq, slot.pos)
            forced = slot.seq[int(self.drafter.next_pos[i]):slot.pos + 1]
            need_draft.append((i, forced, pl["k"]))
        if need_draft:
            self._jit_calls_tick += 1
            proposals = self.drafter.propose(need_draft)
        else:
            proposals = {}
        for i, pl in plans.items():
            pl["proposal"] = proposals.get(i, [])
        if self._tr:
            self._tr.end(("engine", 0))

        # --- verify: all windows, one batched pass over the fork tables
        if self._tr:
            self._tr.begin(("engine", 0), "spec_verify")
        tokens = np.zeros((S, C), np.int32)
        positions = np.full((S, C), -1, np.int32)
        active = np.zeros((S,), bool)
        tbl = np.full((S, self.max_pages), -1, np.int32)
        for i, pl in plans.items():
            k, p = pl["k"], pl["p"]
            tokens[i, 0] = self._cur_token[i]
            tokens[i, 1:1 + k] = pl["proposal"]
            positions[i, :k + 1] = np.arange(p, p + k + 1, dtype=np.int32)
            active[i] = True
            row = np.array(self.tables.row(i))
            for e, pg in pl["window"].items():
                row[e] = pg
            tbl[i] = row
        self._jit_calls_tick += 1
        greedy_dev, self.caches = self._verify(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(active), self.caches, jnp.asarray(tbl),
        )
        # analysis: allow(host-asarray) — THE per-tick sync: the target's greedy tokens drive accept/commit/rollback decisions on the host
        greedy = np.asarray(greedy_dev)
        if self._tr:
            self._tr.end(("engine", 0))

        # --- accept + commit/rollback bookkeeping (host side, in admission
        # order; greedy[i, j] is the target's token for position p + j + 1)
        if self._tr:
            self._tr.begin(("engine", 0), "spec_commit")
        tail_pages = np.full((S,), -1, np.int32)
        tail_offs = np.zeros((S,), np.int32)
        reset_mask = np.zeros((self.n_pages + 1,), bool)
        any_reset = False
        commit_rows = []
        emitted_total = accepted_total = drafted_total = 0
        t_tok = time.perf_counter()
        for i, pl in plans.items():
            slot = self.slots[i]
            k, p = pl["k"], pl["p"]
            a = accept_length(pl["proposal"], greedy[i])
            # accepted drafts, then the target's own token at the first
            # disagreement (or the bonus token after a full accept)
            appended = [int(t) for t in pl["proposal"][:a]] + [int(greedy[i, a])]
            if self.eos_id >= 0 and self.eos_id in appended:
                appended = appended[:appended.index(self.eos_id) + 1]
            n = len(appended)  # 1 <= n <= k + 1 <= remaining budget
            # pages: entries up to eb = page of the last ACCEPTED position
            # commit; later window entries roll back.  n >= 1 makes eb >= e0
            # always — the boundary entry commits on every outcome.
            eb = (p + n - 1) // ps
            if pl["boundary_fork"] >= 0:
                freed = self.pool.commit_fork_run([pl["boundary_base"]], i)
                self.tables.set_entry(i, pl["e0"], pl["boundary_fork"])
                if freed:  # a sharer departed mid-tick and left us the base
                    if self.prefix is not None:
                        self.prefix.evict_pages(freed)
                    reset_mask[freed] = True
                    any_reset = True
            grow = [pl["window"][e]
                    for e in range(self.tables.n_mapped(i), eb + 1)]
            if grow:
                self.tables.append(i, grow)
            rollback = [pl["window"][e] for e in range(eb + 1, pl["e1"] + 1)]
            if rollback:
                freed = self.pool.drop_fork_run(rollback, i)
                reset_mask[freed] = True
                any_reset = True
                self._c_spec_rollback_pages.inc(len(freed))
            self._c_spec_commit_pages.inc(len(grow))
            # position p + n - 1 is the last VALID write in page eb; verify
            # writes beyond it (rejected drafts) are invalidated in one
            # batched pass below, restoring the `already`-guard invariant
            tail_pages[i] = int(self.tables.row(i)[eb])
            tail_offs[i] = (p + n - 1) % ps + 1
            # slot state: appended[-1] is the new sampled-but-unwritten token
            slot.pos = p + n
            slot.generated.extend(appended)
            slot.seq.extend(appended)
            slot.prefill_logits = None
            self._cur_token[i] = appended[-1]
            if self.drafter.after_commit(i, p, k, a == k, slot.pos):
                self._c_spec_resyncs.inc()
                self._spec_tick_m["resyncs"] = \
                    self._spec_tick_m.get("resyncs", 0) + 1
            emitted_total += n
            accepted_total += a
            drafted_total += k
            self._c_spec_verifies.inc()
            self._c_spec_drafted.inc(k)
            self._c_spec_accepted.inc(a)
            if k:
                self._h_accept.observe(a / k)
            self._h_tok_verify.observe(float(n))
            if self._tr:
                self._tr.instant(("request", slot.request_id), "spec_commit",
                                 ts=t_tok, args={"drafted": k, "accepted": a,
                                                 "emitted": n})
            commit_rows.append((i, pl, appended))

        # --- committed recurrent-state pass (window rings / SSM / LRU /
        # conv): re-run the accepted tokens through the batched chunk entry
        # so per-slot leaves advance; pool writes are `already`-trash-routed
        if self._spec_commit is not None:
            tokens2 = np.zeros((S, C), np.int32)
            positions2 = np.full((S, C), -1, np.int32)
            active2 = np.zeros((S,), bool)
            last2 = np.zeros((S,), np.int32)
            tbl2 = np.full((S, self.max_pages), -1, np.int32)
            for i, pl, appended in commit_rows:
                slot = self.slots[i]
                n, p = len(appended), pl["p"]
                tokens2[i, :n] = np.asarray(slot.seq[p:p + n], np.int32)
                positions2[i, :n] = np.arange(p, p + n, dtype=np.int32)
                active2[i] = True
                last2[i] = n - 1
                tbl2[i] = self.tables.row(i)
            self._jit_calls_tick += 1
            _, self.caches = self._spec_commit(
                self.params, jnp.asarray(tokens2), jnp.asarray(positions2),
                jnp.asarray(np.zeros((S,), bool)), jnp.asarray(active2),
                jnp.asarray(last2), self.caches, jnp.asarray(tbl2),
            )

        # --- device-side invalidation: committed-page tails (always — one
        # fixed-shape call per commit tick) and rollback-freed pages
        self._jit_calls_tick += 1
        self.caches = self._spec_reset_tail(
            self.caches, jnp.asarray(tail_pages), jnp.asarray(tail_offs))
        if any_reset:
            self._jit_calls_tick += 1
            self.caches = self._reset_pages(self.caches, jnp.asarray(reset_mask))
        if self._tr:
            self._tr.end(("engine", 0))

        # --- completions last: _finish_if_done may release the slot and
        # cascade an admission into it (whose first chunk must not be
        # clobbered by the commit pass above).  Emitted tokens share one
        # timestamp — bursty TPOT is the truth of speculative decoding.
        for i, pl, appended in commit_rows:
            slot = self.slots[i]
            if slot.request_id != pl["rid"]:
                continue  # released + re-admitted earlier in this loop
            for _ in appended:
                self._obs_token(slot.request_id, t_tok)
            self._finish_if_done(i)
        self._spec_tick_m.update(
            windows=len(commit_rows), drafted=drafted_total,
            accepted=accepted_total, emitted=emitted_total)
        return emitted_total

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine tick: at most one chunk-budget of admission prefill
        (chunked mode), then one decode step over every active slot that is
        not mid-prefill.  Returns #active slots (decoding + prefilling), so
        callers keep ticking while long admissions are still being written.
        Per-tick scheduler telemetry lands in ``last_metrics`` /
        ``metrics_log`` (active slots, prefill/decode token counts,
        free/shared pages, CoW copies, tok/s, preemptions)."""
        t0 = time.perf_counter()
        if self._tr:
            self._tr.begin(("engine", 0), "tick", ts=t0,
                           args={"tick": self._tick + 1})
        if self.paged and self.prefill_mode in ("chunked", "batched"):
            # bounded head-of-line blocking: decode (below) runs every tick,
            # delayed by at most this one chunk of prefill compute — the
            # budget spans the whole tick, so admissions cascaded from
            # completions draw from it too.  (Batched mode budgets per ROW:
            # every mid-prefill slot advances one chunk in the single batched
            # call, so the tick still issues at most one prefill dispatch.)
            self._tick_budget = self.prefill_chunk
        if not any(s.active for s in self.slots):
            self._admit()
            if not any(s.active for s in self.slots):
                self._end_tick_prefill()
                if self._tr:
                    self._tr.end(("engine", 0))
                return 0
        if self._tick_budget is not None:
            if self.prefill_mode == "batched":
                self._prefill_tick_batched()
            else:
                self._prefill_tick()
        if self.paged and self.drafter is None:
            # spec mode skips this: _spec_plan_pages subsumes lazy growth and
            # CoW for the whole k+1 window, fork-first instead of copy-first
            self._ensure_pages()
        # rows eligible to decode this tick — mid-prefill slots are excluded,
        # and their table rows are masked out of the decode step so its pool
        # writes land in the trash page, never in a half-written prompt page
        decoding = np.asarray([s.active and not s.prefilling for s in self.slots])
        n_active = int(sum(s.active for s in self.slots))
        ran_prefill = self._tick_prefill_done > 0
        if ran_prefill:
            # fence the async chunk writes so the prefill/decode timer split
            # attributes device time to the phase that spent it
            # analysis: allow(block-sync) — deliberate timing fence for phase attribution
            jax.block_until_ready(self.caches)
        t_mid = time.perf_counter()
        if not decoding.any():
            prefill_toks = self._end_tick_prefill()
            if n_active or prefill_toks:
                self._record_metrics(0, t_mid - t0, prefill_toks, n_active,
                                     prefill_s=t_mid - t0)
            if self._tr:
                self._tr.end(("engine", 0))
            return n_active
        if self.drafter is not None:
            # speculative path: the whole draft/verify/commit tick replaces
            # the one-token decode step below (same tick telemetry shape)
            n_decoded = self._spec_decode_tick(decoding)
            prefill_toks = self._end_tick_prefill()
            # analysis: allow(block-sync) — tick boundary fence, same as the non-speculative tail below
            jax.block_until_ready(self.caches)
            t1 = time.perf_counter()
            self._record_metrics(n_decoded, t1 - t0, prefill_toks, n_active,
                                 prefill_s=t_mid - t0, decode_s=t1 - t_mid)
            if self._tr:
                self._tr.end(("engine", 0))
            return n_active
        positions = np.asarray([s.pos if s.active else 0 for s in self.slots], np.int32)
        tokens = jnp.asarray(self._cur_token[:, None])
        self._jit_calls_tick += 1
        if self.paged:
            tbl = np.where(decoding[:, None], self.tables.table, -1)
            logits, self.caches, routing_tree = self._decode(
                self.params, tokens, jnp.asarray(positions), jnp.asarray(decoding),
                self.caches, jnp.asarray(tbl),
            )
        else:
            logits, self.caches, routing_tree = self._decode(
                self.params, tokens, jnp.asarray(positions), jnp.asarray(decoding), self.caches
            )
        self._key, sub = jax.random.split(self._key)
        # analysis: allow(host-asarray) — THE per-tick sync: sampled tokens drive eos/budget/admission decisions on the host
        nxt = np.asarray(sample(logits, sub, temperature=self.temperature,
                                top_k=self.top_k, top_p=self.top_p))
        n_decoded = int(decoding.sum())
        t_tok = time.perf_counter()
        for i, slot in enumerate(self.slots):
            # Gate on the PRE-decode snapshot, not slot.active: a completion
            # at row < i can trigger _admit into free row i mid-loop, and
            # that fresh slot must not consume nxt[i] — its logits row was
            # computed while the row was inactive (or still prefilling).
            if not decoding[i]:
                continue
            slot.pos += 1
            slot.generated.append(int(nxt[i]))
            slot.seq.append(int(nxt[i]))
            # the stashed admission logits are only consumable by a fork
            # BEFORE the base's first decode tick — drop the dead copy
            slot.prefill_logits = None
            self._cur_token[i] = int(nxt[i])
            self._obs_token(slot.request_id, t_tok)
            self._finish_if_done(i)
        prefill_toks = self._end_tick_prefill()
        # fetching nxt blocked on the logits, but the donated cache updates
        # are still in flight — without this fence the recorded tick latency
        # under-reports the device time the tick actually consumed
        # analysis: allow(block-sync) — deliberate timing fence for tick latency accounting
        jax.block_until_ready(self.caches)
        t1 = time.perf_counter()
        routing_m = summarize_routing(routing_tree) if routing_tree else None
        self._record_metrics(n_decoded, t1 - t0, prefill_toks, n_active,
                             prefill_s=t_mid - t0, decode_s=t1 - t_mid,
                             routing=routing_m)
        if self._tr:
            self._tr.end(("engine", 0), ts=t1, args={"decoded": n_decoded})
        return n_active

    def _record_metrics(self, n_decoded: int, dt: float, prefill_toks: int = 0,
                        n_active: Optional[int] = None, *,
                        prefill_s: float = 0.0, decode_s: Optional[float] = None,
                        routing: Optional[dict] = None) -> None:
        retraces = self.obs.watchdog.tick()
        if retraces:
            self._c_retraces.inc(retraces)
            if self._tr and self.obs.watchdog.steady_retraces:
                self._tr.instant(("engine", 0), "retrace",
                                 args={"compiles": retraces})
        self._tick += 1
        self._h_tick.observe(dt)
        if n_decoded:
            self._c_decode_toks.inc(n_decoded)
        active = n_decoded if n_active is None else n_active
        self._g_active.set(active)
        self._g_queue.set(len(self.queue))
        m = {
            "tick": self._tick,
            # all slots holding pages, INCLUDING mid-prefill ones; the decode
            # participation count is tokens_this_tick
            "active_slots": active,
            "queue_depth": len(self.queue),
            "tokens_this_tick": n_decoded,
            "tok_per_s": round(n_decoded / max(dt, 1e-9), 2),
            "tick_s": round(dt, 6),
            # decode throughput over the decode phase only (the legacy
            # tok_per_s divides by the WHOLE tick, prefill included)
            "decode_tok_per_s": round(n_decoded / max(decode_s, 1e-9), 2)
            if decode_s is not None else 0.0,
            "prefill_tok_per_s": round(prefill_toks / max(prefill_s, 1e-9), 2)
            if prefill_toks else 0.0,
            "retraces": retraces,
            "preemptions": self.preemptions,
            # jitted-function invocations attributed to this tick (including
            # submit-time admissions since the last record) — in batched mode
            # the steady-state fused tick holds this at <= 2 primary calls
            "jitted_calls": self._jit_calls_tick,
        }
        self._g_jit_calls.set(self._jit_calls_tick)
        self._jit_calls_tick = 0
        if self.paged and self.prefill_mode == "batched":
            m["batched_prefill_occupancy"] = round(self._batched_occ_tick, 4)
            self._g_batch_occ.set(round(self._batched_occ_tick, 4))
            self._batched_occ_tick = 0.0
        if routing is not None:
            self._g_r_drop.set(routing["dropped_frac"])
            self._g_r_ent.set(routing["entropy"])
            self._g_r_imb.set(routing["imbalance"])
            m["routing"] = {k: routing[k] for k in
                            ("moe_layers", "dropped_frac", "entropy", "imbalance")}
        if self.paged:
            self._g_free_pages.set(self.pool.free_count)
            occ = self.pool.occupancy
            self._g_occupancy.set(round(occ, 4))
            self._g_peak_occ.set(round(max(occ, self._g_peak_occ.value or 0.0), 4))
            self._g_shared.set(self.pool.shared_count)
            m["prefill_tokens"] = prefill_toks
            m["free_pages"] = self.pool.free_count
            m["page_occupancy"] = round(self.pool.occupancy, 4)
            m["shared_pages"] = self.pool.shared_count
            m["cow_copies"] = self.cow_copies
            if self.prefix is not None:
                m["prefix_hits"] = self.prefix_hits
                m["prefix_hit_tokens"] = self.prefix_hit_tokens
        if self.drafter is not None and self._spec_tick_m:
            m["spec"] = self._spec_tick_m
            self._spec_tick_m = {}
        self.last_metrics = m
        self.metrics_log.append(m)
        if len(self.metrics_log) > self._metrics_cap:
            del self.metrics_log[: -self._metrics_cap]

    def run_until_done(self, max_ticks: int = 10_000) -> Dict[int, Response]:
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return dict(self.done)
