"""DS-MoE-style inference engine (paper §5): batched prefill + decode with
jitted steps, static shapes (padded request batches), KV/state caches, and
the multi-GPU parallelism layout applied through the active mesh.

The paper's design goals map as:
  * "group tokens with the same critical data path" -> dense-dispatch /
    expert-parallel MoE blocks inside ``decode_step`` (core/moe_parallel.py)
  * "aggregate memory bandwidth across devices"      -> params sharded per
    DESIGN.md §4; per-device bytes measured in benchmarks/fig10.
  * batching: requests are right-aligned into a fixed [B, S_max] prompt
    buffer; finished rows keep decoding into a scrap column (static shapes)
    and are masked out of the responses.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.gating import summarize_routing
from repro.models.model import decode_step, encode, init_caches, prefill
from repro.serving.sampling import sample


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_prefill: int = 256
    max_decode: int = 64
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 0.0  # nucleus sampling mass; 0 = off
    eos_id: int = -1  # -1: never stop early
    pad_id: int = 0
    kv_cache_bits: int = 0  # 0 = fp cache, 8 = int8 QuantizedKV (quant/kv.py)
    # Paged KV pool knobs, consumed by the continuous-serving path
    # (serve.py --paged -> ContinuousEngine; see configs.base.PagedKVConfig).
    # The static batched Engine always uses contiguous per-row caches.
    page_size: int = 0  # >0 = serve with a paged block pool
    n_pages: int = 0  # 0 = auto (slots * pages-per-capacity, no oversubscription)
    prefix_sharing: bool = False  # refcounted CoW page sharing (needs page_size > 0)
    prefill_chunk: int = 0  # admission-prefill tokens per tick (0 = auto: max(64, page_size))
    # Draft-then-verify speculative decoding (serving/spec.py): registry arch
    # name of the dense drafter, or "self" for the drafter==target oracle.
    # Greedy-only; needs page_size > 0. "" = off.
    spec_draft: str = ""
    spec_k: int = 4  # drafted tokens per verify window


@dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16


@dataclass
class Response:
    tokens: List[int]
    prompt_len: int


class Engine:
    """Synchronous batched engine; one jitted prefill + one jitted decode.

    ``params`` may be a full-precision pytree or the output of
    ``repro.quant.quantize_params`` — ``QuantizedArray`` leaves flow through
    the jitted steps unchanged and are dequantized at their matmul sites
    (MoQ serving, paper §4: expert bytes shrink ~4x/8x with int8/int4).
    """

    def __init__(self, cfg: ModelConfig, params, ec: EngineConfig, *, memory=None,
                 prefix_embeds=None, obs=None):
        from repro.quant import prepare_params_for_serving
        from repro.serving.ep import MeshCall, init_engine_mesh, place_params

        # EP serving mesh (cfg.ep_mesh): resolve BEFORE cfg is captured by
        # the jit closures below — the mesh rewrites moe_impl to the
        # shard_map serving schedule (serving/ep.py, core/moe_serve.py).
        self._mesh, self._mesh_rules, cfg = init_engine_mesh(cfg)
        self.cfg = cfg

        if self._mesh is not None:
            from repro.parallel.sharding import use_mesh

            with use_mesh(self._mesh, self._mesh_rules):
                params = prepare_params_for_serving(cfg, params)
            params = place_params(self._mesh, self._mesh_rules, params)
            self.params = params
        else:
            self.params = params = prepare_params_for_serving(cfg, params)
        self.ec = ec
        self.memory = memory
        self.prefix_embeds = prefix_embeds
        capacity = ec.max_prefill + ec.max_decode + (
            cfg.frontend.n_tokens if (cfg.frontend is not None and cfg.family == "vlm") else 0
        )
        self._capacity = capacity
        cross_len = memory.shape[1] if memory is not None else 0

        from repro.obs import Obs

        # same default contract as ContinuousEngine: metrics on, tracer off,
        # routing collection off (it changes the decode step's signature)
        self.obs = obs if obs is not None else Obs()
        self._tr = self.obs.tracer if self.obs.tracer.enabled else None
        routing = self.obs.routing
        M = self.obs.metrics
        self._h_prefill = M.histogram("serve.batch_prefill_s")
        self._h_step = M.histogram("serve.decode_step_s", lo=1e-5, hi=10.0)
        self._c_decode_toks = M.counter("serve.decode_tokens", unit="tok")
        self._c_completed = M.counter("serve.requests_completed", unit="req")
        self._c_retraces = M.counter("serve.retraces", unit="compile")
        self._g_r_drop = M.gauge("routing.dropped_frac")
        self._g_r_ent = M.gauge("routing.entropy", unit="nat")
        self._g_r_imb = M.gauge("routing.imbalance")
        # per-layer routing summary of the most recent decode step
        # (summarize_routing dict) when obs.routing is on
        self.last_routing = None

        def _prefill(params, tokens, caches, memory, prefix_embeds):
            return prefill(cfg, params, tokens, caches, memory=memory, prefix_embeds=prefix_embeds)

        def _decode(params, token, index, caches, memory):
            # normalized 3-tuple return (routing = () when collection is
            # off): the call site can always rebind the donated caches in
            # one unpacking assignment — the shape the donation auditor
            # requires of every donating call
            out = decode_step(cfg, params, token, index, caches, memory=memory,
                              return_routing=routing)
            if routing:
                return out
            logits, caches = out
            return logits, caches, ()

        # caches are donated: the static engine re-allocates per batch, but
        # without donation every decode step double-buffers the KV cache
        self._prefill = jax.jit(_prefill, donate_argnums=(2,))
        self._decode = jax.jit(_decode, donate_argnums=(3,))
        self._cross_len = cross_len
        # Jit registry (same shape as ContinuousEngine's): name ->
        # (fn, donate_argnums, primary).  Both non-primary: the static engine
        # legitimately compiles once per batch shape (B, prompt length), so
        # the never-retrace-after-warmup contract belongs to
        # ContinuousEngine's fixed-shape tick only; compiles are still
        # counted into serve.retraces
        self._jit_registry = {"decode": (self._decode, (3,), False),
                              "prefill": (self._prefill, (2,), False)}
        if self._mesh is not None:
            # every entry point (execution, lower, eval_shape) runs under the
            # serving mesh; attribute forwarding keeps the watchdog's
            # _cache_size probe and the analysis gate working unchanged
            for _name in list(self._jit_registry):
                _fn, _don, _primary = self._jit_registry[_name]
                _w = MeshCall(_fn, self._mesh, self._mesh_rules)
                self._jit_registry[_name] = (_w, _don, _primary)
                setattr(self, "_" + _name, _w)
        for _name, (_fn, _don, _primary) in self._jit_registry.items():
            self.obs.watchdog.register(_name, _fn, aux=not _primary)

    def _make_caches(self, batch: int):
        return init_caches(
            self.cfg, batch, self._capacity,
            cross_len=self._cross_len, kv_bits=self.ec.kv_cache_bits,
        )

    # -- declared contracts for the static analysis suite ----------------
    def jitted_functions(self) -> dict:
        """name -> (jitted fn, donate_argnums, primary); see
        ContinuousEngine.jitted_functions."""
        return dict(self._jit_registry)

    def shape_contract(self) -> list:
        """Declared compile-shape contract: one signature per admissible
        (batch, prompt-length) pair, bounded by EngineConfig.  Neither
        function is primary — the static engine's compile count scales with
        distinct batch shapes by design (that is why ContinuousEngine
        exists); the contract still bounds the family and feeds the
        donation/trace checks."""
        from repro.analysis.contracts import ContractEntry

        aval = lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        params = jax.tree.map(aval, self.params)
        mem = None if self.memory is None else aval(self.memory)
        pe = None if self.prefix_embeds is None else aval(self.prefix_embeds)
        i32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)
        ec = self.ec

        def caches_avals(b):
            return jax.eval_shape(lambda: self._make_caches(b))

        batches = sorted({1, 2, ec.max_batch} & set(range(1, ec.max_batch + 1))
                         | {ec.max_batch})
        lens = sorted({1, 16, ec.max_prefill} & set(range(1, ec.max_prefill + 1))
                      | {ec.max_prefill})
        _, don_p, prim_p = self._jit_registry["prefill"]
        _, don_d, prim_d = self._jit_registry["decode"]
        return [
            ContractEntry(
                name="prefill",
                fn=self._prefill,
                make=lambda b, s: (params, i32(b, s), caches_avals(b), mem, pe),
                points=tuple((b, s) for b in range(1, ec.max_batch + 1)
                             for s in range(1, ec.max_prefill + 1)),
                sample=tuple((b, s) for b in batches for s in lens),
                primary=prim_p, donate_argnums=don_p),
            ContractEntry(
                name="decode",
                fn=self._decode,
                make=lambda b: (params, i32(b, 1), i32(), caches_avals(b), mem),
                points=tuple((b,) for b in range(1, ec.max_batch + 1)),
                sample=tuple((b,) for b in batches),
                primary=prim_d, donate_argnums=don_d),
        ]

    def generate(self, requests: Sequence[Request], *, seed: int = 0) -> List[Response]:
        ec = self.ec
        out: List[Response] = []
        base = jax.random.PRNGKey(seed)
        for chunk, start in enumerate(range(0, len(requests), ec.max_batch)):
            # fold the chunk index into the key: chunk 2+ must not replay
            # chunk 1's sampling noise (chunk 0 keeps the unfolded key so
            # single-batch results are unchanged across versions)
            key = base if chunk == 0 else jax.random.fold_in(base, chunk)
            out.extend(self._generate_batch(requests[start : start + ec.max_batch], key))
        return out

    def _generate_batch(self, reqs: Sequence[Request], key: jax.Array) -> List[Response]:
        ec, cfg = self.ec, self.cfg
        B = len(reqs)
        # Right-align prompts into a fixed buffer so the last prefill position
        # is each row's final prompt token.
        S = min(max(len(r.prompt) for r in reqs), ec.max_prefill)
        toks = np.full((B, S), ec.pad_id, np.int32)
        for i, r in enumerate(reqs):
            p = list(r.prompt)[-S:]
            toks[i, S - len(p) :] = p

        caches = self._make_caches(B)
        tr = self._tr
        t0 = time.perf_counter()
        if tr:
            tr.begin(("engine", 0), "prefill", ts=t0,
                     args={"batch": B, "prompt_len": S})
        logits, caches = self._prefill(
            self.params, jnp.asarray(toks), caches, self.memory, self.prefix_embeds
        )
        if self.obs.metrics.enabled or tr:
            # analysis: allow(block-sync) — deliberate timing fence for the prefill histogram
            jax.block_until_ready(logits)
            t1 = time.perf_counter()
            self._h_prefill.observe(t1 - t0)
            if tr:
                tr.end(("engine", 0), ts=t1)
        offset = (
            self.cfg.frontend.n_tokens if (cfg.frontend is not None and cfg.family == "vlm") else 0
        )

        max_new = min(max(r.max_new_tokens for r in reqs), ec.max_decode)
        generated = np.zeros((B, max_new), np.int32)
        done = np.zeros((B,), bool)
        cur = sample(logits, key, temperature=ec.temperature, top_k=ec.top_k, top_p=ec.top_p)
        if tr:
            tr.begin(("engine", 0), "decode", args={"batch": B})
        t_prev = time.perf_counter()
        for t in range(max_new):
            # analysis: allow(host-asarray) — THE per-step sync: tokens drive host-side eos/stop logic while the next step is dispatched
            generated[:, t] = np.asarray(cur)  # blocks on the in-flight step
            now = time.perf_counter()
            if t:  # step t-1's device time ended at this sync point
                self._h_step.observe(now - t_prev)
            t_prev = now
            self._c_decode_toks.inc(int((~done).sum()))
            done |= generated[:, t] == ec.eos_id
            if done.all():
                generated = generated[:, : t + 1]
                break
            key, sub = jax.random.split(key)
            idx = jnp.asarray(S + offset + t, jnp.int32)
            # single unpacking assignment: the donated caches are rebound by
            # the same statement that calls the donating function
            logits, caches, routing_tree = self._decode(
                self.params, cur[:, None], idx, caches, self.memory
            )
            if self.obs.routing:
                self.last_routing = summarize_routing(routing_tree) if routing_tree else None
                if self.last_routing:
                    self._g_r_drop.set(self.last_routing["dropped_frac"])
                    self._g_r_ent.set(self.last_routing["entropy"])
                    self._g_r_imb.set(self.last_routing["imbalance"])
            fresh = self.obs.watchdog.tick()
            if fresh:
                self._c_retraces.inc(fresh)
            cur = sample(logits, sub, temperature=ec.temperature, top_k=ec.top_k, top_p=ec.top_p)
        if tr:
            tr.end(("engine", 0))
        self._c_completed.inc(B)

        res = []
        for i, r in enumerate(reqs):
            g = generated[i].tolist()
            if ec.eos_id >= 0 and ec.eos_id in g:
                g = g[: g.index(ec.eos_id)]
            res.append(Response(tokens=g[: r.max_new_tokens], prompt_len=len(r.prompt)))
        return res
