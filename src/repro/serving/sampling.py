"""Token sampling for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, key, *, temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits [B, V] -> tokens [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cut = vals[:, -1:]
        logits = jnp.where(logits < cut, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
