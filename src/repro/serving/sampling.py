"""Token sampling for the serving engine: greedy, temperature, top-k and
top-p (nucleus) filtering — top-k and top-p compose (k first, then p)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_MASKED = -1e30


def _top_k_mask(logits: jax.Array, top_k: int) -> jax.Array:
    vals, _ = jax.lax.top_k(logits, top_k)
    cut = vals[:, -1:]
    return jnp.where(logits < cut, _MASKED, logits)


def _top_p_mask(logits: jax.Array, top_p: float) -> jax.Array:
    """Keep the smallest set of tokens whose probability mass >= top_p."""
    sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # token i stays if the mass strictly before it is < top_p (so the first
    # token crossing the threshold is included)
    keep = cum - probs < top_p
    n_keep = jnp.maximum(jnp.sum(keep, axis=-1, keepdims=True), 1)
    cutoff = jnp.take_along_axis(sorted_logits, n_keep - 1, axis=-1)
    return jnp.where(logits < cutoff, _MASKED, logits)


def sample(
    logits: jax.Array,
    key,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 0.0,
) -> jax.Array:
    """logits [B, V] -> tokens [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        logits = _top_k_mask(logits, top_k)
    if 0.0 < top_p < 1.0:
        logits = _top_p_mask(logits, top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
