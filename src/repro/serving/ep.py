"""Multi-device expert-parallel serving plumbing (paper §5.2-5.3).

The engines stay single-host programs; this module gives them a device mesh:

  * ``build_serving_mesh`` — mesh + sharding rules from ``cfg.ep_mesh``:
    ``(8,)`` = flat EP over one axis; ``(4, 2)`` = ("pod", ep_axis) two-axis
    mesh whose MoE exchange runs the hierarchical two-hop all-to-all
    (paper Fig. 8).  Serving meshes carry no tensor-parallel axis — experts
    partition over ALL mesh axes, everything else replicates for aggregate
    memory bandwidth (§5.1).
  * ``init_engine_mesh`` — resolves the mesh and rewrites ``cfg.moe_impl``
    to the serving EP schedule (core/moe_serve.py): "grouped" →
    "ep_grouped", every capacity impl → "ep_serve".
  * ``place_params`` / ``place_caches`` — device_put with the rule-derived
    PartitionSpecs (parallel/params.py): expert wi/wg/wo sharded
    ``P(ep_axes, ...)``, non-expert params replicated; KV caches sharded
    over the slot dim when ``slots % ep == 0`` (attention data-parallel
    over slots) and replicated otherwise.  The paged block pool itself is
    replicated — each rank only *reads* the pages of its slot shard, and
    the host-side scheduler stays mesh-agnostic.
  * ``MeshCall`` — wraps each jitted engine entry point so calls, ``lower``
    and abstract traces all run under the engine's mesh (thread-local
    ambient mesh for shard_map / shard_hint), while forwarding attributes
    like ``_cache_size`` so the retrace watchdog and the analysis gate's
    compile-count prediction keep working unchanged.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding

from repro.parallel.compat import make_mesh
from repro.parallel.sharding import DEFAULT_RULES, use_mesh


def parse_ep_mesh(text: str) -> Tuple[int, ...]:
    """'8' -> (8,); '4x2' -> (4, 2) (hosts x devices-per-host)."""
    try:
        shape = tuple(int(p) for p in text.lower().split("x"))
    except ValueError:
        raise ValueError(f"bad --ep-devices {text!r}: expected '8' or '4x2'") from None
    if not shape or any(n < 1 for n in shape) or len(shape) > 2:
        raise ValueError(f"bad --ep-devices {text!r}: expected '8' or '4x2'")
    return shape


def build_serving_mesh(shape, *, ep_axis: str = "data"):
    """(mesh, rules) for an EP serving topology, or (None, None) when the
    shape is trivial.  1-d: flat EP over ``ep_axis``.  2-d: ("pod",
    ep_axis), outer (host) axis major — experts lay out outer-major, which
    is what the hierarchical all-to-all's stage split assumes."""
    shape = tuple(int(n) for n in (shape or ()))
    ndev = 1
    for n in shape:
        ndev *= n
    if not shape or ndev <= 1:
        return None, None
    if len(shape) == 1:
        names: Tuple[str, ...] = (ep_axis,)
        expert = ep_axis
    elif len(shape) == 2:
        names = ("pod", ep_axis)
        expert = names
    else:
        raise ValueError(f"ep_mesh supports 1 or 2 axes, got {shape}")
    avail = len(jax.devices())
    if ndev > avail:
        raise ValueError(
            f"ep_mesh={shape} needs {ndev} devices but only {avail} are "
            "visible (CPU testing: XLA_FLAGS="
            f"--xla_force_host_platform_device_count={ndev})"
        )
    mesh = make_mesh(shape, names)
    rules = {**DEFAULT_RULES, "expert": expert, "batch": expert}
    return mesh, rules


def serving_moe_impl(impl: str) -> str:
    """Map a single-device moe_impl to its EP serving schedule."""
    if impl in ("ep_serve", "ep_grouped"):
        return impl
    return "ep_grouped" if impl == "grouped" else "ep_serve"


def init_engine_mesh(cfg):
    """(mesh, rules, cfg') for an engine: None/None/cfg when cfg.ep_mesh is
    trivial, else the serving mesh plus cfg with moe_impl rewritten to the
    EP schedule.  Must run BEFORE the engine captures cfg in its jit
    closures."""
    mesh, rules = build_serving_mesh(
        getattr(cfg, "ep_mesh", ()), ep_axis=getattr(cfg, "ep_axis", "data")
    )
    if mesh is None:
        return None, None, cfg
    return mesh, rules, cfg.replace(moe_impl=serving_moe_impl(cfg.moe_impl))


def ep_degree(mesh) -> int:
    return 1 if mesh is None else int(mesh.devices.size)


def _place(mesh, tree, specs):
    """device_put each leaf of ``tree`` with the matching PartitionSpec leaf
    of ``specs`` (same structure; specs leaves are PartitionSpec, which is
    itself a tuple pytree — flatten_up_to keeps them atomic)."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_specs = treedef.flatten_up_to(specs)
    placed = [
        jax.device_put(leaf, NamedSharding(mesh, s)) for leaf, s in zip(flat, flat_specs)
    ]
    return jax.tree_util.tree_unflatten(treedef, placed)


def place_params(mesh, rules, params):
    """Commit params to the mesh: experts P(ep_axes, ...) per-device, the
    rest replicated everywhere (paper §5.1 aggregate memory bandwidth)."""
    from repro.parallel.params import param_pspecs

    with use_mesh(mesh, rules):
        specs = param_pspecs(mesh, params, mode="serve")
    return _place(mesh, params, specs)


def place_caches(mesh, rules, caches, *, slots: int, n_pages: Optional[int] = None):
    """Commit KV caches: slot (batch) dim sharded over the EP axes when
    divisible, everything else replicated.  The [n_pages+1, ...] pool leaves
    have no slot dim and replicate; when a degenerate config makes
    ``n_pages + 1 == slots`` the shape test can't tell pool from per-slot
    leaves, so everything replicates (correct, just not slot-parallel)."""
    from repro.parallel.params import cache_pspecs

    batch = -1 if (n_pages is not None and n_pages + 1 == slots) else slots
    with use_mesh(mesh, rules):
        specs = cache_pspecs(mesh, caches, batch)
    return _place(mesh, caches, specs)


def placed_param_bytes(params) -> int:
    """Per-device bytes of a placed param tree (addressable shards only) —
    the benchmark's 'per-device expert bytes' evidence."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        shards = getattr(leaf, "addressable_shards", None)
        if shards:
            sh = shards[0]
            total += sh.data.size * sh.data.dtype.itemsize
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


class MeshCall:
    """Callable wrapper keeping a jitted engine entry point inside the
    engine's mesh context for every interaction the analysis gate and the
    watchdog have with it: __call__ (execution, jax.eval_shape,
    jax.make_jaxpr), lower() (donation audit), and attribute forwarding
    (_cache_size for retrace accounting)."""

    def __init__(self, fn, mesh, rules):
        self._fn = fn
        self._mesh = mesh
        self._rules = rules

    def __call__(self, *args, **kw):
        with use_mesh(self._mesh, self._rules):
            return self._fn(*args, **kw)

    def lower(self, *args, **kw):
        with use_mesh(self._mesh, self._rules):
            return self._fn.lower(*args, **kw)

    def __getattr__(self, name):
        return getattr(self._fn, name)
