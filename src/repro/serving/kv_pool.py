"""Paged KV-cache block pool: host-side page accounting for the serving stack.

The contiguous slot model reserves ``capacity`` cache tokens per slot up
front, so a 12-token request strands the other ``capacity - 12`` tokens of
cache memory for its whole lifetime — the fragmentation problem that caps
how many concurrent sequences a byte of HBM can serve (DeepSpeed-MoE §5
treats aggregate memory bandwidth/capacity as *the* serving resource).  Here
cache memory is instead a pool of fixed-size pages; each sequence owns only
the pages its tokens actually occupy, via a static-shape per-slot block
table.  Effective concurrent sequences per byte scale with 1/avg-seq-pages
rather than 1/capacity, and the win multiplies with the int8 KV cache
(quant/kv.py) since both shrink the same buffer.

This module is pure host-side bookkeeping (numpy + freelist); the device
arrays it indexes into live in the model caches (models/attention.py
``init_paged_kv_cache``).  Two invariants the scheduler relies on:

  * **all-or-nothing alloc** — ``alloc`` either returns exactly ``n`` pages
    or None, so admission by free-block count never half-admits a request;
  * **preemption-safe release** — every page records its owning slot, so
    ``release(owner)`` frees everything a preempted/finished slot holds even
    if the scheduler's own table row has already been reset, and double
    frees raise instead of corrupting the freelist.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np


class KVBlockPool:
    """Fixed pool of ``n_pages`` pages of ``page_size`` cache tokens each.

    Page ids are ``0 .. n_pages-1``.  (The device-side pool tensors carry one
    extra *trash* page at index ``n_pages`` that is never handed out: writes
    for inactive slots and reads through -1 table entries are routed there —
    see models/attention.py.)
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError(f"need n_pages > 0 and page_size > 0, got {n_pages}/{page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # LIFO freelist: recently-freed pages are re-used first (their cache
        # lines are the ones most likely still resident).
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._owner = np.full((n_pages,), -1, np.int64)  # -1 = free

    # -- accounting --------------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.used_count / self.n_pages

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache tokens."""
        return -(-max(n_tokens, 0) // self.page_size)

    def owned_by(self, owner: int) -> List[int]:
        return [int(p) for p in np.nonzero(self._owner == owner)[0]]

    # -- alloc / free ------------------------------------------------------
    def alloc(self, n: int, owner: int) -> Optional[List[int]]:
        """Pop ``n`` pages for ``owner`` (a slot id >= 0), all-or-nothing.
        Returns the page ids, or None if fewer than ``n`` are free."""
        if owner < 0:
            raise ValueError(f"owner must be >= 0, got {owner}")
        if n < 0:
            raise ValueError(f"cannot alloc {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._owner[pages] = owner
        return pages

    def free(self, pages) -> None:
        """Return pages to the pool.  Freeing an already-free page raises —
        a double free means two slots think they own the same page."""
        for p in pages:
            p = int(p)
            if not (0 <= p < self.n_pages):
                raise ValueError(f"page {p} out of range [0, {self.n_pages})")
            if self._owner[p] < 0:
                raise ValueError(f"double free of page {p}")
            self._owner[p] = -1
            self._free.append(p)

    def release(self, owner: int) -> List[int]:
        """Free every page owned by ``owner`` (request completion or
        preemption) and return them.  Safe to call with a stale/unknown
        owner (frees nothing)."""
        pages = self.owned_by(owner)
        if pages:
            self.free(pages)
        return pages


class BlockTables:
    """Static-shape per-slot block tables: an int32 ``[slots, max_pages]``
    array, -1 for unmapped entries.  Fixed shape is what keeps the jitted
    paged decode step from recompiling as sequences grow/shrink: the device
    side always sees the same ``[slots, max_pages]`` operand, and -1 entries
    read the trash page (masked by its ``pos == -1`` fill)."""

    def __init__(self, slots: int, max_pages: int):
        if slots <= 0 or max_pages <= 0:
            raise ValueError(f"need slots > 0 and max_pages > 0, got {slots}/{max_pages}")
        self.max_pages = int(max_pages)
        self.table = np.full((slots, max_pages), -1, np.int32)

    def n_mapped(self, slot: int) -> int:
        return int((self.table[slot] >= 0).sum())

    def append(self, slot: int, pages) -> None:
        """Map ``pages`` into the next unmapped entries of ``slot``'s row."""
        start = self.n_mapped(slot)
        pages = list(pages)
        if start + len(pages) > self.max_pages:
            raise ValueError(
                f"slot {slot} table overflow: {start}+{len(pages)} > {self.max_pages}"
            )
        self.table[slot, start : start + len(pages)] = np.asarray(pages, np.int32)

    def reset(self, slot: int) -> None:
        self.table[slot] = -1

    def row(self, slot: int) -> np.ndarray:
        return self.table[slot]
