"""Paged KV-cache block pool: host-side page accounting for the serving stack.

The contiguous slot model reserves ``capacity`` cache tokens per slot up
front, so a 12-token request strands the other ``capacity - 12`` tokens of
cache memory for its whole lifetime — the fragmentation problem that caps
how many concurrent sequences a byte of HBM can serve (DeepSpeed-MoE §5
treats aggregate memory bandwidth/capacity as *the* serving resource).  Here
cache memory is instead a pool of fixed-size pages; each sequence owns only
the pages its tokens actually occupy, via a static-shape per-slot block
table.  Effective concurrent sequences per byte scale with 1/avg-seq-pages
rather than 1/capacity, and the win multiplies with the int8 KV cache
(quant/kv.py) since both shrink the same buffer.

Pages are **refcounted** so block tables of different slots can point at the
same physical page (prefix sharing / parallel sampling — the PagedAttention
copy-on-write model): ``share`` adds a holder, ``fork`` gives one holder a
private copy slot (the device-side copy is the engine's job), and
``release`` *decrefs* a departing slot's pages, freeing only those whose
refcount hits zero.  A shared page costs one page of memory no matter how
many tables reference it, which is what makes heavy shared-system-prompt
traffic cheap.

This module is pure host-side bookkeeping (numpy + freelist); the device
arrays it indexes into live in the model caches (models/attention.py
``init_paged_kv_cache``).  Invariants the scheduler relies on:

  * **all-or-nothing alloc** — ``alloc`` either returns exactly ``n`` pages
    or None, so admission by free-block count never half-admits a request;
  * **preemption-safe release** — every page records the set of slots
    holding it, so ``release(owner)`` drops everything a preempted/finished
    slot holds even if the scheduler's own table row has already been reset.
    A page another slot still references is decrefed, NOT freed (the old
    exclusive owner-tag model would have yanked it out from under the
    sharer), and freeing an already-free page raises instead of corrupting
    the freelist;
  * **refcounts never negative, free xor referenced** — every page is either
    on the freelist with refcount 0 and no holders, or off it with
    refcount == len(holders) >= 1 (``check()`` asserts this; the property
    fuzz in tests/test_kv_pool_prop.py drives it through random traces).
"""
from __future__ import annotations

from typing import List, Optional, Set

import numpy as np


class KVBlockPool:
    """Fixed pool of ``n_pages`` pages of ``page_size`` cache tokens each.

    Page ids are ``0 .. n_pages-1``.  (The device-side pool tensors carry one
    extra *trash* page at index ``n_pages`` that is never handed out: writes
    for inactive slots and reads through -1 table entries are routed there —
    see models/attention.py.)

    Invariants (asserted by :meth:`check`, driven through randomized
    200-operation alloc/share/fork/drop/release traces against a shadow
    model by ``tests/test_kv_pool_prop.py``):

      * every page is either FREE (on the freelist, refcount 0, no holders)
        or REFERENCED (off it, refcount == len(holders) >= 1) — never both,
        never neither;
      * refcounts never go negative, and occupancy counts a page shared by
        N slots exactly once (``used_count``);
      * a double free raises instead of corrupting the freelist, and a
        blind ``free`` of a still-shared page raises (shared pages are
        ``drop``ped per holder).

    The pool only does *accounting*; the complementary device-side invariant
    — a refcount>1 page is never written — is the scheduler's job (CoW in
    ``ContinuousEngine._ensure_pages``, trash-routing in the prefill
    scatter/chunk writes) and is asserted bit-for-bit by
    ``tests/test_prefix.py::test_cow_never_mutates_page_visible_to_another_slot``.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError(f"need n_pages > 0 and page_size > 0, got {n_pages}/{page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # LIFO freelist: recently-freed pages are re-used first (their cache
        # lines are the ones most likely still resident).
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._refs = np.zeros((n_pages,), np.int64)  # 0 = free
        self._holders: List[Set[int]] = [set() for _ in range(n_pages)]

    # -- accounting --------------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        """Physical pages in use — a page shared by N slots counts ONCE."""
        return self.n_pages - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.used_count / self.n_pages

    @property
    def shared_count(self) -> int:
        """Live pages referenced by more than one slot."""
        return int((self._refs > 1).sum())

    def refcount(self, page: int) -> int:
        return int(self._refs[self._check_page(page)])

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache tokens."""
        return -(-max(n_tokens, 0) // self.page_size)

    def owned_by(self, owner: int) -> List[int]:
        """Pages ``owner`` holds a reference to (exclusive or shared)."""
        return [p for p in range(self.n_pages) if owner in self._holders[p]]

    def _check_page(self, page: int) -> int:
        page = int(page)
        if not (0 <= page < self.n_pages):
            raise ValueError(f"page {page} out of range [0, {self.n_pages})")
        return page

    def check(self) -> None:
        """Assert the pool's internal invariants (test/debug hook)."""
        free = set(self._free)
        assert len(free) == len(self._free), "freelist holds a duplicate page"
        for p in range(self.n_pages):
            refs, holders = int(self._refs[p]), self._holders[p]
            assert refs >= 0, f"page {p} refcount {refs} < 0"
            assert refs == len(holders), f"page {p}: refs {refs} != holders {holders}"
            if p in free:
                assert refs == 0, f"page {p} simultaneously free and referenced"
            else:
                assert refs >= 1, f"page {p} off the freelist with no references"
        assert self.free_count + self.used_count == self.n_pages

    # -- alloc / free ------------------------------------------------------
    def alloc(self, n: int, owner: int) -> Optional[List[int]]:
        """Pop ``n`` pages for ``owner`` (a slot id >= 0), all-or-nothing:
        either exactly ``n`` page ids come back (each refcount 1, held only
        by ``owner``) or None and the pool is untouched — the property that
        lets the scheduler admit by free-block count without ever
        half-admitting a request (``tests/test_kv_pool_prop.py`` fuzzes it;
        ``tests/test_paged.py::test_admission_by_free_block_count`` relies
        on it end-to-end)."""
        if owner < 0:
            raise ValueError(f"owner must be >= 0, got {owner}")
        if n < 0:
            raise ValueError(f"cannot alloc {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
            self._holders[p] = {owner}
        return pages

    def share(self, pages, owner: int) -> None:
        """Add ``owner`` as a holder of each live page (refcount + 1): the
        prefix-sharing / parallel-sampling entry point.  Sharing a free page
        or a page the owner already holds raises — both mean the caller's
        table bookkeeping has diverged from the pool's."""
        if owner < 0:
            raise ValueError(f"owner must be >= 0, got {owner}")
        pages = [self._check_page(p) for p in pages]
        for p in pages:
            if self._refs[p] == 0:
                raise ValueError(f"cannot share free page {p}")
            if owner in self._holders[p]:
                raise ValueError(f"owner {owner} already holds page {p}")
        for p in pages:
            self._refs[p] += 1
            self._holders[p].add(owner)

    def drop(self, page: int, owner: int) -> bool:
        """Remove ``owner``'s reference to ``page``; free it if that was the
        last reference.  Returns True iff the page was freed."""
        page = self._check_page(page)
        if self._refs[page] == 0:
            raise ValueError(f"double free of page {page}")
        if owner not in self._holders[page]:
            raise ValueError(f"owner {owner} does not hold page {page}")
        self._holders[page].discard(owner)
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(page)
            return True
        return False

    def fork(self, page: int, owner: int) -> Optional[int]:
        """Copy-on-write split: give ``owner`` a fresh private page in place
        of its reference to shared ``page``.  Returns the new page id (the
        caller must copy the device-side contents old -> new and remap its
        block table), or None when the pool is dry — the scheduler then
        preempts to make room.  The old page keeps its other holders."""
        page = self._check_page(page)
        if owner not in self._holders[page]:
            raise ValueError(f"owner {owner} does not hold page {page}")
        got = self.alloc(1, owner)
        if got is None:
            return None
        self.drop(page, owner)
        return got[0]

    def free(self, pages) -> None:
        """Return exclusively-held pages to the pool.  Freeing an already-free
        page raises (a double free means two slots think they own the same
        page); freeing a page with other holders raises too — shared pages
        must be ``drop``ed per holder, never blind-freed."""
        for p in pages:
            p = self._check_page(p)
            if self._refs[p] == 0:
                raise ValueError(f"double free of page {p}")
            if self._refs[p] > 1:
                raise ValueError(
                    f"page {p} still referenced by {sorted(self._holders[p])}; "
                    "shared pages are dropped per holder, not freed"
                )
            self._refs[p] = 0
            self._holders[p] = set()
            self._free.append(p)

    def commit_fork_run(self, bases, owner: int) -> List[int]:
        """Commit a run of copy-on-write forks by REFCOUNT HANDOFF: for each
        base page the speculative window forked, drop ``owner``'s reference
        to the base — the fork page (already alloc'd to ``owner``) takes its
        place in the block table, so the owner's page count is conserved.
        Returns the bases actually freed (refcount hit zero: the base was
        shared at fork time, so this is normally empty, but a sharer can
        depart mid-speculation).  Callers must evict freed ids from the
        prefix index and device-invalidate them before reuse."""
        freed = []
        for p in bases:
            if self.drop(p, owner):
                freed.append(p)
        return freed

    def drop_fork_run(self, forks, owner: int) -> List[int]:
        """Roll back a rejected speculative suffix: free a run of fork pages
        that were alloc'd for the verify window and whose contents were
        rejected.  Every page must be a PRIVATE fork of ``owner`` (refcount
        exactly 1) — a shared or foreign page here means the scheduler
        committed it into a table or the prefix index, and freeing it would
        corrupt another sequence.  Returns the freed pages (always all of
        them); callers must device-invalidate them before reuse."""
        for p in forks:
            p = self._check_page(p)
            if self._refs[p] != 1 or owner not in self._holders[p]:
                raise ValueError(
                    f"page {p} is not a private fork of owner {owner} "
                    f"(refs={int(self._refs[p])}, "
                    f"holders={sorted(self._holders[p])})"
                )
        out = []
        for p in forks:
            self.drop(p, owner)
            out.append(p)
        return out

    def release(self, owner: int) -> List[int]:
        """Drop every page reference ``owner`` holds (request completion or
        preemption) and return the pages actually FREED — i.e. those whose
        refcount hit zero.  Pages another slot still references are decrefed
        and stay live (copy-on-write sharing survives the departure — the
        old exclusive owner-tag model yanked them from under sharers;
        ``tests/test_prefix.py::test_preempted_sharer_decrefs_not_frees`` is
        the regression).  Safe to call with a stale/unknown owner (drops
        nothing).  Callers must evict the RETURNED ids from the prefix index
        and device-invalidate them (``paged_reset_pages``) before reuse."""
        freed = []
        for p in self.owned_by(owner):
            if self.drop(p, owner):
                freed.append(p)
        return freed


class BlockTables:
    """Static-shape per-slot block tables: an int32 ``[slots, max_pages]``
    array, -1 for unmapped entries.  Fixed shape is what keeps the jitted
    paged decode step from recompiling as sequences grow/shrink: the device
    side always sees the same ``[slots, max_pages]`` operand, and -1 entries
    read the trash page (masked by its ``pos == -1`` fill).

    Sharing lives entirely in the pool's refcounts: a table row is just
    pointers, so prefix sharing means two rows holding the same page id and
    copy-on-write means rewriting one entry (``set_entry``) after the engine
    copies the device page.

    Invariant: a row's mapped entries are a prefix (position order) — pages
    are appended as the sequence grows and only ever remapped in place
    (CoW) or reset wholesale; the decode/prefill kernels index the row by
    ``position // page_size`` and rely on it.  ``tests/test_paged.py``
    exercises growth/reset; the scheduler fuzz in ``tests/test_prefix.py``
    drives remapping under sharing."""

    def __init__(self, slots: int, max_pages: int):
        if slots <= 0 or max_pages <= 0:
            raise ValueError(f"need slots > 0 and max_pages > 0, got {slots}/{max_pages}")
        self.max_pages = int(max_pages)
        self.table = np.full((slots, max_pages), -1, np.int32)

    def n_mapped(self, slot: int) -> int:
        return int((self.table[slot] >= 0).sum())

    def append(self, slot: int, pages) -> None:
        """Map ``pages`` into the next unmapped entries of ``slot``'s row."""
        start = self.n_mapped(slot)
        pages = list(pages)
        if start + len(pages) > self.max_pages:
            raise ValueError(
                f"slot {slot} table overflow: {start}+{len(pages)} > {self.max_pages}"
            )
        self.table[slot, start : start + len(pages)] = np.asarray(pages, np.int32)

    def set_entry(self, slot: int, idx: int, page: int) -> None:
        """Remap one mapped entry (copy-on-write divergence)."""
        if self.table[slot, idx] < 0:
            raise ValueError(f"slot {slot} entry {idx} is unmapped")
        self.table[slot, idx] = page

    def copy_row(self, dst: int, src: int) -> None:
        """Point ``dst``'s table at the same pages as ``src`` (parallel
        sampling fork — the pool's ``share`` must incref them)."""
        self.table[dst] = self.table[src]

    def reset(self, slot: int) -> None:
        self.table[slot] = -1

    def row(self, slot: int) -> np.ndarray:
        return self.table[slot]
