"""Radix index over full-page token chunks: prompt prefix -> physical pages.

Heavy serving traffic repeats itself — system prompts, few-shot preambles,
retrieval templates — so many concurrent requests begin with the same token
prefix.  Under paged KV serving (serving/kv_pool.py) that prefix's K/V is
bit-identical across requests: attention K/V depend only on (token id,
absolute position) and every request's context starts at position 0, so a
shared prefix occupies identical page contents.  Block tables already make
the sharing *representable* (two rows pointing at one page); this index
makes it *findable*: a trie keyed by ``page_size``-token chunks maps every
indexed full-page prompt prefix to the physical page holding it.

Only FULL pages are indexed — a partially-filled page also holds whatever
the owning sequence appends next, which is exactly where divergence happens
(copy-on-write territory, handled by the scheduler, not the index).

The index holds no references of its own: a mapping is valid precisely while
its page is live in the pool, and the engine calls ``evict_pages`` whenever
pages are freed.  This keeps lifetime trivial (no cache-retention policy:
pages persist while at least one slot holds them, and the pool drains to
empty when traffic does) at the cost of losing reuse across idle gaps — a
retention policy over free pages is a natural follow-on.

Eviction of a mid-chain node leaves a *hole*: descendants may still hold
live pages, but a lookup must stop at the hole because a prefix match is
only as long as its unbroken page chain.  Holes with no descendants are
pruned so the trie's size tracks live pages.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("chunk", "page", "parent", "children")

    def __init__(self, chunk: Tuple[int, ...], parent: Optional["_Node"]):
        self.chunk = chunk
        self.page: Optional[int] = None  # physical page holding this chunk's K/V
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}


class PrefixIndex:
    """Trie of ``page_size``-token chunks -> live physical page ids.

    Caller contract (the index cannot check these itself; the randomized
    scheduler fuzz in ``tests/test_prefix.py`` and the progressive-
    registration tests in ``tests/test_chunked.py`` enforce them through the
    engine):

      * **an indexed page already holds its K/V** — chunked prefill inserts
        each full page only after its chunk is written, because a lookup may
        hand the page to a sharer on the very next admission;
      * **eviction tracks the pool** — ``evict_pages`` must be called with
        exactly the ids ``KVBlockPool.release`` reports freed, so a mapping
        is live iff its page is; the index drains to empty when the pool
        does (asserted at the end of every fuzz run);
      * only FULL pages are ever inserted; a partial page also holds
        whatever its owner appends next (CoW territory, not shareable).
    """

    def __init__(self, page_size: int):
        if page_size <= 0:
            raise ValueError(f"need page_size > 0, got {page_size}")
        self.page_size = int(page_size)
        self._root = _Node((), None)
        self._by_page: Dict[int, _Node] = {}

    def __len__(self) -> int:
        """Number of live (chunk-path -> page) mappings."""
        return len(self._by_page)

    def _chunks(self, tokens: Sequence[int], n: int):
        ps = self.page_size
        for c in range(n):
            yield tuple(int(t) for t in tokens[c * ps : (c + 1) * ps])

    # ------------------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Register the full-page prefix chunks of ``tokens`` as living in
        ``pages`` (``pages[c]`` holds chunk ``c``).  Partial trailing chunks
        are ignored; chunks already mapped keep their existing (live) page —
        first writer wins, and the duplicate physical copy simply never gets
        shared.  Returns the number of newly-registered mappings."""
        n_full = min(len(tokens) // self.page_size, len(pages))
        node, added = self._root, 0
        for c, chunk in enumerate(self._chunks(tokens, n_full)):
            child = node.children.get(chunk)
            if child is None:
                child = node.children[chunk] = _Node(chunk, node)
            if child.page is None:
                page = int(pages[c])
                if page in self._by_page:
                    raise ValueError(f"page {page} already indexed at another path")
                child.page = page
                self._by_page[page] = child
                added += 1
            node = child
        return added

    def lookup(self, tokens: Sequence[int], *, max_tokens: Optional[int] = None) -> List[int]:
        """Longest unbroken chain of indexed full-page chunks matching the
        head of ``tokens``; returns the physical pages in chunk order.
        ``max_tokens`` caps the match (admission passes ``len(ctx) - 1`` so
        at least one context token is left to prefill for last-token
        logits)."""
        limit = len(tokens) if max_tokens is None else min(max_tokens, len(tokens))
        n_full = max(limit, 0) // self.page_size
        node, pages = self._root, []
        for chunk in self._chunks(tokens, n_full):
            child = node.children.get(chunk)
            if child is None or child.page is None:  # miss or evicted hole
                break
            pages.append(child.page)
            node = child
        return pages

    def evict_pages(self, pages: Sequence[int]) -> int:
        """Remove mappings whose page was freed.  Descendant mappings stay
        (their pages are still live) but become unreachable until the hole is
        re-filled by a future insert of the same chunk path.  Returns the
        number of mappings removed."""
        removed = 0
        for p in pages:
            node = self._by_page.pop(int(p), None)
            if node is None:
                continue
            node.page = None
            removed += 1
            # prune childless holes up the chain
            while node.parent is not None and node.page is None and not node.children:
                node.parent.children.pop(node.chunk, None)
                node = node.parent
        return removed
