"""Draft-then-verify speculative decoding over CoW page forks.

The paper's staged MoS distillation (training/distill.py, §4) exists to
produce a cheap dense student of the MoE teacher; the paged KV pool's
refcounted forks (PR 4) make KV rollback nearly free.  This module is the
piece that turns those two halves into a decode-latency win:

  * a small dense **drafter** proposes ``k`` greedy tokens per running slot
    (one jitted ``lax.scan`` over the ragged decode step covers every slot);
  * the target MoE model **verifies** all ``k + 1`` window positions of
    every slot in ONE batched pass (``models.model.paged_verify_chunk_batched``,
    the PR 8 batched-chunk machinery minus admission reset), writing the
    window's K/V into **copy-on-write forks** of each slot's tail pages;
  * the longest agreeing prefix **commits** — fork pages replace the bases
    by refcount handoff (``KVBlockPool.commit_fork_run``) — and the rejected
    suffix **rolls back** by dropping pages (``drop_fork_run``) without the
    base pages ever being touched.

Greedy verification is exact: position ``j`` of the verify logits is the
target's argmax for the token at ``pos + j + 1``, so accepting the longest
prefix where draft and target agree (plus the target's own token at the
first disagreement) reproduces the non-speculative greedy stream token for
token — the drafter's quality moves the ACCEPT RATE, never the output.
``tests/test_spec.py`` pins this parity across arch mixes, int8 KV, prefix
sharing, chunked/batched prefill, page-boundary windows and preemption.

The engine-side state machine lives in ``ContinuousEngine._spec_decode_tick``
(serving/continuous.py); this module owns the drafter: its own contiguous
caches (it is a plain dense model — no pages needed at drafter scale), a
lazily-synchronized per-slot validity watermark, and the propose scan.

Drafter cache discipline — the subtle part.  ``next_pos[i]`` is the number
of sequence tokens the drafter's caches have correctly consumed for slot
``i`` (-1 = invalid).  Each propose step feeds the token at one position and
writes that position's K/V, so after proposing ``k`` tokens from position
``p`` the drafter has consumed positions ``p .. p + k - 1``.  On commit the
watermark becomes ``min(p + k, p')`` (``p'`` = the slot's new position):

  * **full accept** — every consumed token was correct; the drafter still
    needs the bonus token, so the next propose force-feeds 2 tokens;
  * **partial accept** — consumed tokens beyond the accept point were
    wrong.  For attention-only drafters this is still exact: contiguous
    attention masks by position and the next window's steps overwrite the
    stale entries index-by-index before ever attending to them.  Recurrent
    drafters (SSM/LRU/conv mixes) have irreversible state, so a partial
    accept invalidates them and the next tick re-prefills the committed
    sequence (``exact_partial`` below gates this; it only costs draft-side
    FLOPs — parity is untouched either way).

A slot release (completion or preemption) just invalidates the watermark;
the drafter lazily re-prefills at the slot's next speculative tick, which
uniformly covers first admission, fork admission, preemption re-admission
and recurrent-drafter resync without touching any admission path.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import (
    arch_fully_paged,
    init_caches,
    prefill_into_slot,
    ragged_decode_step,
)


def accept_length(proposal: Sequence[int], greedy: Sequence[int]) -> int:
    """Longest prefix of ``proposal`` the target's greedy tokens accept:
    ``greedy[j]`` is the target's argmax for the position AFTER the window's
    j-th input, i.e. exactly the token the draft proposed as
    ``proposal[j]``."""
    a = 0
    while a < len(proposal) and int(proposal[a]) == int(greedy[a]):
        a += 1
    return a


class Drafter:
    """The draft model: contiguous caches over the engine's slot pool, a
    per-slot validity watermark, and two jitted entry points (registered in
    the engine's jit registry as ``draft_prefill`` / ``draft_propose``)."""

    def __init__(self, cfg: ModelConfig, params: dict, *, slots: int,
                 capacity: int, spec_k: int):
        self.cfg = cfg
        self.params = params
        self.n_slots = int(slots)
        self.capacity = int(capacity)
        self.k = int(spec_k)
        self.caches = init_caches(cfg, slots, capacity, kv_bits=0)
        # sequence tokens correctly consumed per slot (-1 = cache invalid)
        self.next_pos = np.full((slots,), -1, np.int64)
        # attention-only drafters stay exact across partial accepts (stale
        # entries are overwritten index-by-index before being attended to);
        # recurrent mixes must resync — see module docstring
        self.exact_partial = arch_fully_paged(cfg)

        def _prefill_fn(params, tokens, positions, slot, caches):
            return prefill_into_slot(cfg, params, tokens, positions, slot,
                                     caches)

        self._prefill = jax.jit(_prefill_fn, donate_argnums=(4,))

        def _propose_fn(params, forced, use_forced, pos, act, caches):
            # T = k + 1 steps of greedy self-feed; per-step force-feed
            # resynchronizes each row onto the committed stream (1 forced
            # token normally, 2 after a full accept — the bonus token)
            def body(carry, xs):
                cur, c = carry
                f, uf, p, a = xs
                inp = jnp.where(uf, f, cur)
                logits, c = ragged_decode_step(cfg, params, inp[:, None], p,
                                               a, c)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (nxt, c), nxt

            (_, caches), outs = jax.lax.scan(
                body, (forced[0], caches), (forced, use_forced, pos, act))
            return outs, caches

        self._propose = jax.jit(_propose_fn, donate_argnums=(5,))

    def invalidate(self, slot: int) -> None:
        self.next_pos[slot] = -1

    def needs_sync(self, slot: int, pos: int) -> bool:
        """True when the slot's next propose cannot be reached by force-feeding
        at most 2 tokens (fresh slot, post-preemption, recurrent resync)."""
        return not (0 <= self.next_pos[slot] and
                    pos + 1 - self.next_pos[slot] <= 2)

    def sync(self, slot: int, seq: Sequence[int], pos: int) -> None:
        """(Re)build the drafter's cache for ``slot``: one prefill of the
        committed tokens below ``pos`` (the unwritten current token at
        ``pos`` is force-fed by the next propose)."""
        toks = jnp.asarray(np.asarray(seq[:pos], np.int32)[None])
        ppos = jnp.arange(pos, dtype=jnp.int32)[None]
        _, self.caches = self._prefill(self.params, toks, ppos,
                                       jnp.asarray(slot, jnp.int32),
                                       self.caches)
        self.next_pos[slot] = pos

    def propose(self, rows: Sequence[Tuple[int, List[int], int]]) -> Dict[int, List[int]]:
        """One jitted scan proposes for every row: ``rows`` is
        ``(slot, forced_tokens, k)`` where ``forced_tokens`` are the committed
        tokens from the validity watermark through the slot's current token
        (length 1 or 2 by the watermark invariant) and ``k >= 1`` is the
        window size.  Returns slot -> k proposed tokens."""
        T, S = self.k + 1, self.n_slots
        forced = np.zeros((T, S), np.int32)
        use_f = np.zeros((T, S), bool)
        pos = np.zeros((T, S), np.int32)
        act = np.zeros((T, S), bool)
        for slot, ftoks, k in rows:
            c = len(ftoks)
            assert 1 <= c <= 2 and c - 1 + k <= T, (c, k, T)
            base = int(self.next_pos[slot])
            for t in range(c - 1 + k):
                act[t, slot] = True
                pos[t, slot] = min(base + t, self.capacity - 1)
                if t < c:
                    use_f[t, slot] = True
                    forced[t, slot] = ftoks[t]
        outs, self.caches = self._propose(
            self.params, jnp.asarray(forced), jnp.asarray(use_f),
            jnp.asarray(pos), jnp.asarray(act), self.caches)
        # analysis: allow(host-asarray) — ONE sync serves every slot's proposal; the engine's accept bookkeeping is host-side by design
        outs = np.asarray(outs)
        return {slot: [int(x) for x in outs[len(ftoks) - 1:len(ftoks) - 1 + k, slot]]
                for slot, ftoks, k in rows}

    def after_commit(self, slot: int, p: int, k: int, accepted_all: bool,
                     new_pos: int) -> bool:
        """Advance the validity watermark after a commit; returns True when
        the drafter was invalidated (recurrent resync needed)."""
        if self.exact_partial or accepted_all:
            self.next_pos[slot] = min(p + k, new_pos)
            return False
        self.invalidate(slot)
        return True
