"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1, MQA)
d_ff=7680 vocab=256000, RG-LRU + local attention, pattern 2 recurrent : 1
local-attn.  [arXiv:2402.19427]"""
from repro.configs.base import AttnSpec, FFNSpec, LayerSpec, LRUSpec, ModelConfig, patterned_segments

_FFN = FFNSpec(kind="dense", d_ff=7680, act="swiglu")
_REC = LayerSpec(LRUSpec(lru_width=2560, conv_dim=4, num_heads=10), _FFN)
_LOC = LayerSpec(AttnSpec(kind="local", window=2048, rope_theta=10_000.0), _FFN)

# Griffin block pattern: (recurrent, recurrent, local attention)
_PATTERN = (_REC, _REC, _LOC)


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        source="[arXiv:2402.19427]",
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        vocab_size=256_000,
        segments=patterned_segments(_PATTERN, 26),
        tie_embeddings=True,
        max_seq_len=1_048_576,
        supports_long_context=True,  # LRU state + bounded window cache
    )
