"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (per expert) vocab=202048, MoE 128 experts top-1 + shared expert
('early fusion' multimodal card; text backbone per assignment carve-out).
[hf:meta-llama/Llama-4-Scout-17B-16E family]

The shared-expert + top-1-routed design is exactly DeepSpeed-MoE's
Residual-MoE (paper §4.1.1): a fixed dense branch plus one routed expert.
MoE on alternating layers (interleave step 2), dense d_ff = 2x expert d_ff.
"""
from repro.configs.base import AttnSpec, FFNSpec, LayerSpec, ModelConfig, patterned_segments

_ATTN = AttnSpec(kind="global", rope_theta=500_000.0)
_DENSE = LayerSpec(_ATTN, FFNSpec(kind="dense", d_ff=16_384, act="swiglu"))
_MOE = LayerSpec(
    _ATTN,
    FFNSpec(
        kind="moe",
        d_ff=8192,
        act="swiglu",
        num_experts=128,
        top_k=1,
        capacity_factor=1.25,
        residual=True,  # shared expert == Residual-MoE
        residual_d_ff=8192,
    ),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        source="[hf:meta-llama/Llama-4-Scout-17B-16E]",
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        vocab_size=202_048,
        segments=patterned_segments((_DENSE, _MOE), 48),
        max_seq_len=131_072,
        supports_long_context=False,  # treated as full attention here
        moe_impl="ep",
    )
