"""mamba2-370m [ssm] — 48L d_model=1024, attention-free, ssm_state=128,
vocab=50280.  SSD (state-space duality).  [arXiv:2405.21060]"""
from repro.configs.base import FFNSpec, LayerSpec, ModelConfig, SSMSpec, uniform_segments

_LAYER = LayerSpec(
    SSMSpec(d_inner=2048, head_dim=64, state_dim=128, conv_dim=4, chunk=256, n_groups=1),
    FFNSpec(kind="none"),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        source="[arXiv:2405.21060]",
        d_model=1024,
        num_heads=32,  # SSD heads (d_inner/head_dim); attention unused
        num_kv_heads=32,
        head_dim=64,
        vocab_size=50_280,
        segments=uniform_segments(_LAYER, 48),
        tie_embeddings=True,
        max_seq_len=1_048_576,
        supports_long_context=True,  # O(1) state decode
    )
