"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per
expert) vocab=163840, MoE 384 experts top-8 + 1 shared expert; first layer
dense (DeepSeek-V3-style).  Trillion-parameter MoE — the paper-table scale
case (DS-MoE Table 6 / Fig. 11 trillion-parameter regime).
[arXiv:2501.kimi2]"""
from repro.configs.base import AttnSpec, FFNSpec, LayerSpec, ModelConfig, Segment

_ATTN = AttnSpec(kind="global", rope_theta=50_000.0)
_DENSE = LayerSpec(_ATTN, FFNSpec(kind="dense", d_ff=18_432, act="swiglu"))
_MOE = LayerSpec(
    _ATTN,
    FFNSpec(
        kind="moe",
        d_ff=2048,
        act="swiglu",
        num_experts=384,
        top_k=8,
        capacity_factor=1.25,
        residual=True,  # shared expert
        residual_d_ff=2048,
    ),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        source="[arXiv:2501.kimi2]",
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        head_dim=112,
        vocab_size=163_840,
        segments=(Segment((_DENSE,), 1), Segment((_MOE,), 60)),
        max_seq_len=131_072,
        supports_long_context=False,
        moe_impl="ep",
    )
