"""Assigned input shapes (public pool) and which entry point each lowers."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(cfg, shape: InputShape) -> tuple[bool, str]:
    """Does this (arch, shape) pair run?  long_500k needs sub-quadratic decode
    support (sliding-window / SSM / LRU) — pure full-attention archs skip it
    (documented in DESIGN.md §5)."""
    if shape.kind == "decode" and shape.seq_len > cfg.max_seq_len:
        if not cfg.supports_long_context:
            return False, f"{cfg.name}: full-attention arch, no sub-quadratic path for {shape.name}"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, f"{cfg.name}: full-attention arch, long_500k skipped per DESIGN.md"
    return True, ""
