"""Config dataclasses for the repro framework.

A model is described structurally as a sequence of *segments*; each segment is a
``(pattern, repeats)`` pair where ``pattern`` is a tuple of :class:`LayerSpec`.
Segments are executed with ``jax.lax.scan`` over ``repeats`` (params stacked on a
leading axis), which keeps the lowered HLO size proportional to the number of
*unique* layer kinds rather than the depth.  This is also exactly the structure
needed for DeepSpeed-MoE's PR-MoE (pyramid = segments with growing expert counts,
each trained/served with its own expert-parallel degree).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Mixer specs (the sequence-mixing half of a block)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttnSpec:
    """Multi-head (GQA) attention.

    kind: "global" (full causal), "local" (sliding window), "cross"
          (encoder-decoder cross attention; not causal, attends to memory).
    """

    kind: str = "global"
    window: int = 0  # sliding-window size for kind == "local"
    rope_theta: float = 10_000.0
    use_rope: bool = True
    causal: bool = True
    logit_softcap: float = 0.0  # gemma-style soft capping, 0 = off
    qk_norm: bool = False


@dataclass(frozen=True)
class SSMSpec:
    """Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060]."""

    kind: str = "ssm"
    d_inner: int = 0  # typically 2 * d_model
    head_dim: int = 64
    state_dim: int = 128
    conv_dim: int = 4
    chunk: int = 256
    n_groups: int = 1

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim


@dataclass(frozen=True)
class LRUSpec:
    """RG-LRU recurrence (RecurrentGemma / Griffin) [arXiv:2402.19427]."""

    kind: str = "lru"
    lru_width: int = 0
    conv_dim: int = 4
    num_heads: int = 1  # block-diagonal input/forget gates


# ---------------------------------------------------------------------------
# FFN specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FFNSpec:
    """Feed-forward half of a block.

    kind: "dense"  — a single (Swi)GLU/GELU MLP.
          "moe"    — top-k gated mixture of experts (DeepSpeed-MoE §3).
          "none"   — no FFN (mamba2 blocks are mixer-only).
    residual: if True, adds a fixed dense MLP branch alongside the routed
          expert(s) — the paper's Residual-MoE (§4.1.1, Phenomenon-II); also
          models "shared expert" architectures (llama4, kimi-k2).
    """

    kind: str = "dense"
    d_ff: int = 0
    act: str = "swiglu"  # "swiglu" | "gelu" | "relu"
    # --- MoE fields ---
    num_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    residual: bool = False
    residual_d_ff: int = 0  # dense branch width (defaults to d_ff)
    aux_loss_coef: float = 0.01  # Table 1: "MoE loss coefficient"


@dataclass(frozen=True)
class QuantConfig:
    """Weight-only PTQ recipe (MoQ, paper §4; see ``repro/quant``).

    bits:       8 or 4 (int4 packed two nibbles per byte).
    group_size: contraction inputs sharing one scale (0 = one scale per
                output channel); must divide the contraction dim (and be
                even for int4).  Applies to both int8 and int4; leaves with
                two contraction axes (attention out-proj) always use
                per-output-channel scales.
    policy:     which matmul weights to quantize —
                "experts"       routed expert mats only (the ~3.7x win:
                                experts are >90% of MoE params),
                "experts_attn"  + attention projections,
                "all"           every matmul weight (router/norms stay fp).
    kv_cache_bits: serving-time KV-cache quantization — 0 (fp, default) or
                8 (int8 with per-head, per-timestep f32 scales; see
                repro/quant/kv.py).  Orthogonal to the weight policy: the
                cache is activation state, quantized on write during
                prefill/decode, not by quantize_params.  Engines read this
                knob when allocating caches (EngineConfig.kv_cache_bits /
                ContinuousEngine(kv_cache_bits=...)).
    """

    bits: int = 8
    group_size: int = 0
    policy: str = "experts"
    kv_cache_bits: int = 0


@dataclass(frozen=True)
class PagedKVConfig:
    """Paged KV-cache serving (§5 memory economics; see ``serving/kv_pool.py``).

    Instead of reserving a contiguous ``capacity``-token cache row per slot,
    cache memory is a shared pool of fixed-size pages and each sequence owns
    only the pages its tokens occupy, through a static-shape block table.
    Effective concurrent sequences per cache byte then scale with the *actual*
    average sequence length rather than the worst case, and compose with
    ``kv_cache_bits=8`` (int8 pages).

    page_size: cache tokens per page.  Smaller pages pack tighter (≤
               ``page_size - 1`` tokens wasted per sequence) but mean more
               gather steps per decode; 16-128 is the practical range.
    n_pages:   total pages in the pool.  0 = auto-size to
               ``slots * ceil(capacity / page_size)`` (no oversubscription —
               same worst-case bytes as contiguous).  Provisioning fewer
               pages than the worst case is the point: admission goes by
               free-block count and the scheduler preempts the youngest slot
               if traffic outruns the pool.
    prefix_sharing: refcounted copy-on-write page sharing
               (serving/prefix_index.py): admissions whose context repeats an
               indexed full-page prefix (shared system prompts, few-shot
               preambles) point their block table at the existing physical
               pages instead of allocating and re-writing them, and parallel
               samples (``ContinuousEngine.submit_n`` / serve.py
               ``--n-samples``) share ALL prompt pages, diverging via
               copy-on-write.  Greedy outputs are token-identical to the
               non-shared paged engine; the win is pages — a prefix shared by
               N sequences costs 1/N of the pages per sequence.  Under
               chunked prefill the shared prefix's K/V is also read in place
               instead of recomputed, so sharing saves prefill FLOPs too
               (saved fraction = prefix_len / prompt_len).
    prefill_chunk: tokens of admission-prefill compute per engine tick
               (chunked prefill-into-pages; 0 = auto: max(64, page_size)).
               Admission still reserves all the prompt's pages up front
               (all-or-nothing, free-block admission unchanged), but the
               compute is spread one page-aligned chunk per ``step()``,
               interleaved with decode — a long prompt can never stall
               running decodes for more than one chunk of compute, and the
               temp contiguous prefill buffer of the old scatter path is
               gone.  Must be >= page_size.
    """

    page_size: int = 16
    n_pages: int = 0
    prefix_sharing: bool = False
    prefill_chunk: int = 0


@dataclass(frozen=True)
class LayerSpec:
    mixer: object  # AttnSpec | SSMSpec | LRUSpec
    ffn: FFNSpec
    # Optional cross-attention sub-block (decoder layers of enc-dec models):
    # runs self-attn (mixer) -> cross-attn -> ffn.
    cross: Optional["AttnSpec"] = None


@dataclass(frozen=True)
class Segment:
    pattern: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder-decoder models (seamless-m4t)."""

    segments: Tuple[Segment, ...]
    max_source_len: int = 4096


@dataclass(frozen=True)
class FrontendSpec:
    """Stubbed modality frontend: ``input_specs`` provides precomputed
    embeddings of shape [batch, n_tokens, embed_dim] (assignment carve-out)."""

    kind: str  # "audio" | "vision"
    n_tokens: int = 256
    embed_dim: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    source: str  # citation bracket from the assignment
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    vocab_size: int
    segments: Tuple[Segment, ...]
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendSpec] = None
    tie_embeddings: bool = False
    rms_eps: float = 1e-6
    max_seq_len: int = 131_072
    supports_long_context: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # Implementation selector for MoE dispatch:
    #   "einsum"  = sparse one-hot einsum (the paper's *baseline*),
    #   "dense"   = dense mapping-table dispatch (paper §5.4),
    #   "grouped" = dropless expert-sorted dispatch — no expert_capacity, no
    #               token drops, tile-level padding only (MegaBlocks-style;
    #               core/dispatch_grouped.py + kernels/expert_mlp_grouped.py),
    #   "ep"      = dense dispatch + explicit expert-parallel all-to-all
    #               under shard_map (paper §5.2-5.3).
    moe_impl: str = "dense"
    # --- expert-parallel serving mesh (serving/ep.py) ---
    # () = single-device serving.  (8,) = flat EP over 8 devices.  (4, 2) =
    # two-axis ("pod", ep_axis) mesh: hierarchical two-hop all-to-all (paper
    # Fig. 8) when experts shard over both axes.  The engines build the mesh,
    # place expert weights per-device, and rewrite moe_impl to the serving EP
    # schedule ("ep_serve"/"ep_grouped"); the scheduler stays host-side and
    # mesh-agnostic.
    ep_mesh: Tuple[int, ...] = ()
    ep_axis: str = "data"

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.segments)

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        out = []
        for seg in self.segments:
            out.extend(list(seg.pattern) * seg.repeats)
        return tuple(out)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Helpers used by the per-arch config modules
# ---------------------------------------------------------------------------


def uniform_segments(layer: LayerSpec, n_layers: int) -> Tuple[Segment, ...]:
    return (Segment(pattern=(layer,), repeats=n_layers),)


def patterned_segments(pattern: Tuple[LayerSpec, ...], n_layers: int) -> Tuple[Segment, ...]:
    """Tile ``pattern`` to cover ``n_layers``; remainder becomes a repeat-1 tail."""
    p = len(pattern)
    reps, rem = divmod(n_layers, p)
    segs = []
    if reps:
        segs.append(Segment(pattern=pattern, repeats=reps))
    if rem:
        segs.append(Segment(pattern=pattern[:rem], repeats=1))
    return tuple(segs)


# ---------------------------------------------------------------------------
# Closed-form parameter counting (used for PR-MoE / MoS size claims)
# ---------------------------------------------------------------------------


def _ffn_matrices(act: str) -> int:
    return 3 if act == "swiglu" else 2


def ffn_param_count(cfg: "ModelConfig", f: FFNSpec, active: bool = False) -> int:
    d = cfg.d_model
    if f.kind == "none":
        return 0
    per_expert = _ffn_matrices(f.act) * d * f.d_ff
    if f.kind == "dense":
        return per_expert + d  # + pre-norm scale
    n_experts = f.top_k if active else f.num_experts
    total = n_experts * per_expert
    total += d * f.num_experts  # router (always fully read for gating)
    if f.residual:
        rdf = f.residual_d_ff or f.d_ff
        total += _ffn_matrices(f.act) * d * rdf
    return total + d


def mixer_param_count(cfg: "ModelConfig", m) -> int:
    d = cfg.d_model
    if isinstance(m, AttnSpec):
        qo = d * cfg.num_heads * cfg.head_dim * 2
        kv = d * cfg.num_kv_heads * cfg.head_dim * 2
        return qo + kv + d  # + pre-norm
    if isinstance(m, SSMSpec):
        di, s = m.d_inner, m.state_dim
        n = d * (2 * di + 2 * m.n_groups * s + m.num_heads)  # in_proj (z,x,B,C,dt)
        n += (di + 2 * m.n_groups * s) * m.conv_dim  # temporal conv
        n += m.num_heads * 3  # A_log, D, dt_bias
        n += di * d  # out_proj
        return n + d
    if isinstance(m, LRUSpec):
        w = m.lru_width
        n = 2 * d * w  # x & gate input projections
        n += w * m.conv_dim  # temporal conv
        n += 2 * ((w // m.num_heads) * w + w)  # block-diag input/forget gates
        n += w  # Lambda param
        n += w * d  # out proj
        return n + d
    raise TypeError(f"unknown mixer {m!r}")


def _stack_params(cfg: "ModelConfig", segs: Tuple[Segment, ...], active: bool) -> int:
    t = 0
    for seg in segs:
        for ls in seg.pattern:
            per = mixer_param_count(cfg, ls.mixer) + ffn_param_count(cfg, ls.ffn, active)
            if ls.cross is not None:
                per += mixer_param_count(cfg, ls.cross)
            t += per * seg.repeats
    return t


def count_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    n = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2) + d  # embed/unembed + final norm
    n += _stack_params(cfg, cfg.segments, active=False)
    if cfg.encoder is not None:
        n += _stack_params(cfg, cfg.encoder.segments, active=False)
    return n


def count_active_params(cfg: ModelConfig) -> int:
    """Per-token activated parameters — the MoE 'critical data path' (paper §5.1)."""
    d = cfg.d_model
    n = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2) + d
    n += _stack_params(cfg, cfg.segments, active=True)
    if cfg.encoder is not None:
        n += _stack_params(cfg, cfg.encoder.segments, active=True)
    return n
