"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552,
RoPE + GQA.  [hf:THUDM/glm-4-9b]"""
from repro.configs.base import AttnSpec, FFNSpec, LayerSpec, ModelConfig, uniform_segments

_LAYER = LayerSpec(
    AttnSpec(kind="global", rope_theta=10_000.0),
    FFNSpec(kind="dense", d_ff=13_696, act="swiglu"),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        source="[hf:THUDM/glm-4-9b]",
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        vocab_size=151_552,
        segments=uniform_segments(_LAYER, 40),
        max_seq_len=131_072,
        supports_long_context=False,  # pure full attention -> long_500k skipped
    )
