"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256.  [arXiv:2407.21783]"""
from repro.configs.base import AttnSpec, FFNSpec, LayerSpec, ModelConfig, uniform_segments

_LAYER = LayerSpec(
    AttnSpec(kind="global", rope_theta=500_000.0),
    FFNSpec(kind="dense", d_ff=14_336, act="swiglu"),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b",
        family="dense",
        source="[arXiv:2407.21783]",
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        vocab_size=128_256,
        segments=uniform_segments(_LAYER, 32),
        max_seq_len=131_072,
        supports_long_context=False,
    )
