"""deepseek-67b [dense] — 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400, llama-architecture.  [arXiv:2401.02954]"""
from repro.configs.base import AttnSpec, FFNSpec, LayerSpec, ModelConfig, uniform_segments

_LAYER = LayerSpec(
    AttnSpec(kind="global", rope_theta=10_000.0),
    FFNSpec(kind="dense", d_ff=22_016, act="swiglu"),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        family="dense",
        source="[arXiv:2401.02954]",
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        vocab_size=102_400,
        segments=uniform_segments(_LAYER, 95),
        max_seq_len=131_072,
        supports_long_context=False,
    )
