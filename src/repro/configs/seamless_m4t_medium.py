"""seamless-m4t-medium [audio] — enc-dec, 12L encoder + 12L decoder,
d_model=1024 16H (kv=16, MHA) d_ff=4096 vocab=256206.  The speech frontend
(mel + conformer feature extractor) is STUBBED per the assignment carve-out:
``input_specs`` supplies precomputed frame embeddings [B, T_src, 1024].
[arXiv:2308.11596]"""
from repro.configs.base import (
    AttnSpec,
    EncoderConfig,
    FFNSpec,
    FrontendSpec,
    LayerSpec,
    ModelConfig,
    uniform_segments,
)

_FFN = FFNSpec(kind="dense", d_ff=4096, act="relu")
_ENC_LAYER = LayerSpec(AttnSpec(kind="global", causal=False, rope_theta=10_000.0), _FFN)
_DEC_LAYER = LayerSpec(
    AttnSpec(kind="global", rope_theta=10_000.0),
    _FFN,
    cross=AttnSpec(kind="cross", causal=False, use_rope=False),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        source="[arXiv:2308.11596]",
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        vocab_size=256_206,
        segments=uniform_segments(_DEC_LAYER, 12),
        encoder=EncoderConfig(segments=uniform_segments(_ENC_LAYER, 12), max_source_len=4096),
        frontend=FrontendSpec(kind="audio", n_tokens=1024, embed_dim=1024),
        max_seq_len=32_768,
        supports_long_context=False,
    )
