"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655; InternViT vision encoder STUBBED per assignment carve-out:
``input_specs`` supplies precomputed patch embeddings [B, 256, 1024] that a
learned projector maps to d_model and prepends to the token sequence.
[arXiv:2404.16821]"""
from repro.configs.base import AttnSpec, FFNSpec, FrontendSpec, LayerSpec, ModelConfig, uniform_segments

_LAYER = LayerSpec(
    AttnSpec(kind="global", rope_theta=1_000_000.0),
    FFNSpec(kind="dense", d_ff=4864, act="swiglu"),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        source="[arXiv:2404.16821]",
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        vocab_size=151_655,
        segments=uniform_segments(_LAYER, 24),
        frontend=FrontendSpec(kind="vision", n_tokens=256, embed_dim=1024),
        tie_embeddings=True,
        max_seq_len=32_768,
        supports_long_context=False,
    )
