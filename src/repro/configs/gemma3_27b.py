"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global interleave, 128k context.
[hf:google/gemma-3-1b-pt family card, scaled per assignment]"""
from repro.configs.base import AttnSpec, FFNSpec, LayerSpec, ModelConfig, patterned_segments

_LOCAL = AttnSpec(kind="local", window=1024, rope_theta=10_000.0, qk_norm=True)
_GLOBAL = AttnSpec(kind="global", rope_theta=1_000_000.0, qk_norm=True)
_FFN = FFNSpec(kind="dense", d_ff=21_504, act="swiglu")

# 5 local : 1 global, tiled over 62 layers (10 full periods + 2 local tail)
_PATTERN = tuple(LayerSpec(m, _FFN) for m in (_LOCAL,) * 5 + (_GLOBAL,))


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        source="[hf:google/gemma-3-1b-pt]",
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        vocab_size=262_144,
        segments=patterned_segments(_PATTERN, 62),
        tie_embeddings=True,
        max_seq_len=131_072,
        # local layers have a 1024 ring cache; the single global-layer class
        # decodes linearly in S -> long_500k is runnable (DESIGN.md §5).
        supports_long_context=True,
    )
