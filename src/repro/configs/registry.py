"""Architecture registry: the 10 assigned architectures + the paper's own
NLG/MoE model zoo, and the reduced-variant builder used by smoke tests."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs import (
    deepseek_67b,
    gemma3_27b,
    glm4_9b,
    internvl2_1b,
    kimi_k2_1t_a32b,
    llama3_8b,
    llama4_maverick_400b_a17b,
    mamba2_370m,
    recurrentgemma_2b,
    seamless_m4t_medium,
)
from repro.configs.base import (
    AttnSpec,
    EncoderConfig,
    FFNSpec,
    FrontendSpec,
    LayerSpec,
    LRUSpec,
    ModelConfig,
    Segment,
    SSMSpec,
)
from repro.core.prmoe import paper_models

ASSIGNED = [
    "gemma3-27b",
    "glm4-9b",
    "llama4-maverick-400b-a17b",
    "kimi-k2-1t-a32b",
    "deepseek-67b",
    "mamba2-370m",
    "llama3-8b",
    "recurrentgemma-2b",
    "seamless-m4t-medium",
    "internvl2-1b",
]

_MODULES = {
    "gemma3-27b": gemma3_27b,
    "glm4-9b": glm4_9b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "deepseek-67b": deepseek_67b,
    "mamba2-370m": mamba2_370m,
    "llama3-8b": llama3_8b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "internvl2-1b": internvl2_1b,
}


def all_configs() -> Dict[str, ModelConfig]:
    out = {name: mod.config() for name, mod in _MODULES.items()}
    out.update(paper_models())
    return out


def get_config(name: str) -> ModelConfig:
    cfgs = all_configs()
    if name not in cfgs:
        raise KeyError(f"unknown arch '{name}'; available: {sorted(cfgs)}")
    return cfgs[name]


# ---------------------------------------------------------------------------
# Reduced variants (smoke tests: ≤2-ish layers, d_model≤512, ≤4 experts)
# ---------------------------------------------------------------------------


def _reduce_mixer(m, d_model: int):
    if isinstance(m, AttnSpec):
        return dataclasses.replace(m, window=min(m.window, 8) if m.window else 0)
    if isinstance(m, SSMSpec):
        return dataclasses.replace(m, d_inner=2 * d_model, head_dim=16, state_dim=16, chunk=8)
    if isinstance(m, LRUSpec):
        return dataclasses.replace(m, lru_width=d_model, num_heads=2)
    raise TypeError(m)


def _reduce_ffn(f: FFNSpec) -> FFNSpec:
    kw = dict(d_ff=64 if f.d_ff else 0)
    if f.kind == "moe":
        kw.update(num_experts=min(f.num_experts, 4), top_k=min(f.top_k, 2), capacity_factor=2.0)
        if f.residual:
            kw.update(residual_d_ff=64)
    return dataclasses.replace(f, **kw)


def with_capacity_factor(cfg: ModelConfig, cf: float) -> ModelConfig:
    """Rebuild a config with every MoE layer's capacity factor replaced —
    perf knob for the §Perf iterations (capacity padding scales every
    dispatch buffer, a2a and expert-slicing reduction linearly)."""
    def seg_map(segs):
        out = []
        for seg in segs:
            pat = tuple(
                LayerSpec(
                    ls.mixer,
                    dataclasses.replace(ls.ffn, capacity_factor=cf) if ls.ffn.kind == "moe" else ls.ffn,
                    cross=ls.cross,
                )
                for ls in seg.pattern
            )
            out.append(Segment(pat, seg.repeats))
        return tuple(out)

    enc = None
    if cfg.encoder is not None:
        enc = EncoderConfig(segments=seg_map(cfg.encoder.segments), max_source_len=cfg.encoder.max_source_len)
    return cfg.replace(segments=seg_map(cfg.segments), encoder=enc)


def with_moe_ffn(cfg: ModelConfig, **kw) -> ModelConfig:
    """Rebuild a config with every MoE layer's FFNSpec fields overridden
    (num_experts=8, capacity_factor=8.0, ...).  The EP serving tests use it
    to make reduced expert counts divisible by a device mesh and to give the
    a2a schedule drop-free capacity headroom."""
    def seg_map(segs):
        out = []
        for seg in segs:
            pat = tuple(
                LayerSpec(
                    ls.mixer,
                    dataclasses.replace(ls.ffn, **kw) if ls.ffn.kind == "moe" else ls.ffn,
                    cross=ls.cross,
                )
                for ls in seg.pattern
            )
            out.append(Segment(pat, seg.repeats))
        return tuple(out)

    enc = None
    if cfg.encoder is not None:
        enc = EncoderConfig(segments=seg_map(cfg.encoder.segments), max_source_len=cfg.encoder.max_source_len)
    return cfg.replace(segments=seg_map(cfg.segments), encoder=enc)


def make_reduced(cfg: ModelConfig, d_model: int = 128) -> ModelConfig:
    """Same family/pattern, tiny dims: one repeat of each segment pattern."""
    heads = 4
    segs = []
    for seg in cfg.segments:
        pat = tuple(
            LayerSpec(
                _reduce_mixer(ls.mixer, d_model),
                _reduce_ffn(ls.ffn),
                cross=ls.cross,
            )
            for ls in seg.pattern
        )
        segs.append(Segment(pat, 1))
    enc = None
    if cfg.encoder is not None:
        epat = []
        for seg in cfg.encoder.segments:
            epat.append(
                Segment(
                    tuple(
                        LayerSpec(_reduce_mixer(ls.mixer, d_model), _reduce_ffn(ls.ffn), cross=ls.cross)
                        for ls in seg.pattern
                    ),
                    1,
                )
            )
        enc = EncoderConfig(segments=tuple(epat), max_source_len=32)
    fe = None
    if cfg.frontend is not None:
        fe = FrontendSpec(kind=cfg.frontend.kind, n_tokens=8, embed_dim=32)
    return cfg.replace(
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=max(1, heads * cfg.num_kv_heads // max(cfg.num_heads, 1)),
        head_dim=32,
        vocab_size=512,
        segments=tuple(segs),
        encoder=enc,
        frontend=fe,
        max_seq_len=4096,
        param_dtype="float32",
        compute_dtype="float32",
        # moe_impl is preserved: without a mesh, "ep" falls back to dense
        # dispatch anyway, and under a mesh the shard_map EP path is the one
        # that partitions correctly (the GSPMD-partitioned dense scatter/
        # gather dispatch miscomputes under grad on older XLA SPMD — see
        # core/dispatch.py::combine_dense).
    )
