"""Mixture-of-Students: MoE-to-MoE knowledge distillation with *staged* KD
(DeepSpeed-MoE §4.2).

Loss (Eq. 1):  L = CE(x; θ) + α · KL(teacher ∥ student)

The paper's key finding: running KD for the whole of training *hurts* a
capacity-reduced student (underfitting regime); stopping KD partway (staged
KD, e.g. at 400K/600K steps) recovers the benefit.  ``kd_alpha`` therefore
multiplies α by (step < kd_stop_step), implemented branch-free for jit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward
from repro.training.optimizer import AdamWConfig, adamw_update
from repro.training.schedule import warmup_cosine
from repro.training.trainer import TrainConfig, cross_entropy, moe_aux_coef


@dataclass
class KDConfig:
    alpha: float = 1.0  # KD loss weight
    temperature: float = 1.0
    kd_stop_step: int = -1  # -1 = never stop ("full KD" baseline in Table 5)


def kd_alpha(kdc: KDConfig, step: jax.Array) -> jax.Array:
    a = jnp.asarray(kdc.alpha, jnp.float32)
    if kdc.kd_stop_step >= 0:
        a = a * (step < kdc.kd_stop_step).astype(jnp.float32)
    return a


def kd_kl(student_logits: jax.Array, teacher_logits: jax.Array, tau: float) -> jax.Array:
    """KL(teacher ∥ student) with temperature, mean over tokens."""
    t = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / tau, axis=-1)
    s = jax.nn.log_softmax(student_logits.astype(jnp.float32) / tau, axis=-1)
    kl = jnp.sum(jnp.exp(t) * (t - s), axis=-1)
    return jnp.mean(kl) * tau**2


def make_distill_step(
    student_cfg: ModelConfig,
    teacher_cfg: ModelConfig,
    tc: TrainConfig,
    kdc: KDConfig,
) -> Callable:
    """Returns step(params, opt_state, teacher_params, tokens, labels)."""
    opt = AdamWConfig(lr=tc.lr, weight_decay=tc.weight_decay, grad_clip=tc.grad_clip)

    def step_fn(params, opt_state, teacher_params, tokens, labels):
        t_logits, _ = forward(teacher_cfg, teacher_params, tokens)
        t_logits = jax.lax.stop_gradient(t_logits)
        a = kd_alpha(kdc, opt_state.step)

        def total_loss(p):
            s_logits, aux = forward(student_cfg, p, tokens)
            ce = cross_entropy(s_logits, labels)
            kl = kd_kl(s_logits, t_logits, kdc.temperature)
            loss = ce + a * kl + moe_aux_coef(student_cfg) * aux
            return loss, {"ce": ce, "kl": kl, "aux": aux}

        (loss, metrics), grads = jax.value_and_grad(total_loss, has_aux=True)(params)
        lr_scale = warmup_cosine(
            opt_state.step, warmup_steps=tc.warmup_steps, decay_steps=tc.decay_steps, min_ratio=tc.min_lr_ratio
        )
        params, opt_state, stats = adamw_update(opt, grads, opt_state, params, lr_scale)
        return params, opt_state, dict(metrics, loss=loss, kd_alpha=a, **stats)

    return step_fn


def make_student_config(teacher: ModelConfig, depth_ratio: float = 0.875) -> ModelConfig:
    """Depth-reduce a teacher (paper: 24 -> 21 layers, 12.5% off) by trimming
    segment repeats from the top, preserving the MoE/dense interleave."""
    target = max(1, round(teacher.num_layers * depth_ratio))
    drop = teacher.num_layers - target
    segs = list(teacher.segments)
    out = []
    for seg in reversed(segs):
        if drop <= 0:
            out.append(seg)
            continue
        take_layers = max(seg.num_layers - drop, 0)
        drop -= seg.num_layers - take_layers
        reps = take_layers // len(seg.pattern)
        rem = take_layers % len(seg.pattern)
        if reps:
            out.append(type(seg)(seg.pattern, reps))
        if rem:
            out.append(type(seg)(seg.pattern[:rem], 1))
    return teacher.replace(segments=tuple(reversed(out)), name=teacher.name + "-mos")
