"""AdamW in pure JAX with optional sharded (ZeRO-1-style) optimizer state.

The paper trains with Adam under ZeRO data parallelism [23]; in GSPMD terms
ZeRO-1 is simply 'optimizer state sharded over the data axis', which we
express by giving m/v the same PartitionSpec as the params but with the
leading dim additionally sharded over 'data' when divisible (launch/train.py
wires that up).  The math here is plain AdamW + global-norm clipping.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # [] int32
    m: dict
    v: dict


class AdamWConfig(NamedTuple):
    lr: float = 1e-4  # peak; multiplied by schedule(step)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_adamw(params: dict) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32), jax.tree.map(zeros, params), jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    cfg: AdamWConfig,
    grads: dict,
    state: AdamWState,
    params: dict,
    lr_scale: jax.Array,
):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m_ = cfg.b1 * m + (1 - cfg.b1) * g
        v_ = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_ / b1c
        vhat = v_ / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_, v_

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
