"""Training loop: loss, train_step factory (jit/pjit-ready), and a small
driver used by examples and launch/train.py."""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.gating import summarize_routing
from repro.models.model import forward, init_params
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw
from repro.training.schedule import warmup_cosine


def moe_aux_coef(cfg: ModelConfig) -> float:
    for ls in cfg.layer_specs():
        if ls.ffn.kind == "moe":
            return ls.ffn.aux_loss_coef
    return 0.0


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE in f32. logits [B,S,V], labels [B,S] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(cfg: ModelConfig, params, tokens, labels, *, remat: bool = False,
            memory=None, prefix_embeds=None, return_routing: bool = False):
    """``return_routing=True`` (static) adds a ``"routing"`` entry to the aux
    metrics: the per-layer RoutingStats tree from the forward pass (same
    gating decisions the aux loss is built from — telemetry cannot drift
    from the loss)."""
    routing = None
    if return_routing:
        logits, aux, routing = forward(
            cfg, params, tokens, remat=remat, memory=memory,
            prefix_embeds=prefix_embeds, return_routing=True,
        )
    else:
        logits, aux = forward(cfg, params, tokens, remat=remat, memory=memory, prefix_embeds=prefix_embeds)
    if prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1] :]
    ce = cross_entropy(logits, labels)
    loss = ce + moe_aux_coef(cfg) * aux
    metrics = {"ce": ce, "aux": aux}
    if return_routing:
        metrics["routing"] = routing
    return loss, metrics


@dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 50
    decay_steps: int = 1000
    min_lr_ratio: float = 0.1
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    remat: bool = False


def make_train_step(cfg: ModelConfig, tc: TrainConfig, *, with_routing: bool = False) -> Callable:
    opt = AdamWConfig(lr=tc.lr, weight_decay=tc.weight_decay, grad_clip=tc.grad_clip)

    def train_step(params, opt_state: AdamWState, tokens, labels):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, labels, remat=tc.remat,
                              return_routing=with_routing),
            has_aux=True,
        )(params)
        lr_scale = warmup_cosine(
            opt_state.step, warmup_steps=tc.warmup_steps, decay_steps=tc.decay_steps, min_ratio=tc.min_lr_ratio
        )
        params, opt_state, stats = adamw_update(opt, grads, opt_state, params, lr_scale)
        metrics = dict(metrics, loss=loss, lr_scale=lr_scale, **stats)
        return params, opt_state, metrics

    return train_step


def train_loop(
    cfg: ModelConfig,
    tc: TrainConfig,
    data_iter,
    num_steps: int,
    *,
    seed: int = 0,
    params=None,
    log_every: int = 10,
    log_fn=print,
    routing_stats: bool = False,
    metrics_sink: Optional[Callable[[dict], None]] = None,
):
    """Returns (params, opt_state, history).

    ``routing_stats=True`` collects per-layer MoE routing telemetry in the
    jitted train step (RoutingStats — dropped-token fraction, gate entropy,
    f·P imbalance, per-expert token counts) and folds the host-side summary
    into the periodic log line and ``history`` rows.  ``metrics_sink``, if
    given, receives every logged row as a structured dict (floats + the
    routing summary) — the machine-readable twin of ``log_fn``."""
    if params is None:
        params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_adamw(params)
    step_fn = jax.jit(make_train_step(cfg, tc, with_routing=routing_stats))
    history = []
    t0 = time.time()
    for step in range(num_steps):
        tokens, labels = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, tokens, labels)
        if step % log_every == 0 or step == num_steps - 1:
            routing = metrics.pop("routing", None)
            m = {k: float(v) for k, v in metrics.items()}
            row = {"step": step, **m}
            line = (
                f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                f"aux {m['aux']:.4f} gnorm {m['grad_norm']:.3f}"
            )
            if routing:
                summ = summarize_routing(routing)
                row["routing"] = summ
                line += (
                    f" drop {summ['dropped_frac']:.3f} "
                    f"imb {summ['imbalance']:.3f} ent {summ['entropy']:.3f}"
                )
            line += f" ({time.time()-t0:.1f}s)"
            history.append(row)
            log_fn(line)
            if metrics_sink is not None:
                metrics_sink(row)
    return params, opt_state, history
