"""LR schedules matching the paper's Table 1 recipe: linear warmup over a
token budget, cosine decay to a floor, measured in *tokens* (we convert to
steps at call-sites via tokens_per_step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup_steps: int, decay_steps: int, min_ratio: float = 0.1):
    """Returns multiplier in [min_ratio, 1]."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
    prog = jnp.clip((step - warmup_steps) / jnp.maximum(decay_steps - warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = min_ratio + (1.0 - min_ratio) * cos
    return warm * decay


def batch_rampup(step, *, rampup_steps: int, start_frac: float = 0.25):
    """Paper Table 1 'batch size rampup tokens' — returns the fraction of the
    global batch to use (we implement it as a loss mask, keeping shapes
    static for jit)."""
    if rampup_steps <= 0:
        return jnp.asarray(1.0, jnp.float32)
    step = jnp.asarray(step, jnp.float32)
    f = start_frac + (1.0 - start_frac) * jnp.minimum(step / rampup_steps, 1.0)
    return jnp.minimum(f, 1.0)
