"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm (quadratic within a chunk,
linear across chunks); decode is the O(1)-per-token recurrence on the
[B, H, P, N] state.  A naive sequential-scan oracle is provided for tests.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMSpec
from repro.models.modules import dense_init


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_ssm(key, cfg: ModelConfig, spec: SSMSpec, dtype) -> dict:
    d = cfg.d_model
    di, g, n, h = spec.d_inner, spec.n_groups, spec.state_dim, spec.num_heads
    ks = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * g * n + h  # z, x, B, C, dt
    conv_ch = di + 2 * g * n
    return {
        "in_proj": dense_init(ks[0], d, in_dim, dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.conv_dim, conv_ch), jnp.float32) * 0.1).astype(dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "out_proj": dense_init(ks[2], di, d, dtype),
        "norm_scale": jnp.zeros((di,), dtype),  # gated RMSNorm before out_proj
    }


def init_ssm_cache(batch: int, spec: SSMSpec, dtype) -> dict:
    conv_ch = spec.d_inner + 2 * spec.n_groups * spec.state_dim
    return {
        "state": jnp.zeros((batch, spec.num_heads, spec.head_dim, spec.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_dim - 1, conv_ch), dtype),
        # 'pos' kept for interface parity with KV caches (unused numerically)
        "pos": jnp.zeros((batch, 1), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array, prefix: Optional[jax.Array] = None,
                 valid_len: Optional[jax.Array] = None):
    """Depthwise causal conv. x: [B, L, C]; w: [K, C]; prefix: [B, K-1, C].

    ``valid_len`` [B] (batched chunk prefill): each row's inputs are a valid
    prefix of length n_i followed by padding; the returned conv prefix must
    then be the K-1 inputs ENDING AT the last valid position — xp[n_i :
    n_i + K-1] per row — not the tail of the padded buffer.  n_i == L
    reproduces the default tail; n_i == 0 returns the incoming prefix
    unchanged (identity for inactive rows).  Outputs at valid positions are
    never contaminated by padding: the conv is causal and valid positions
    precede all padding in the row."""
    K = w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)  # [B, L+K-1, C]
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    if valid_len is None:
        new_prefix = xp[:, xp.shape[1] - (K - 1) :]
    else:
        idx = valid_len.astype(jnp.int32)[:, None] + jnp.arange(K - 1, dtype=jnp.int32)
        new_prefix = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return out, new_prefix


def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., L] -> [..., L, L] lower-triangular pairwise sums
    S[i, j] = sum(a[j+1..i]) for j < i, 0 on diag, -inf above."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


# ---------------------------------------------------------------------------
# SSD core: chunked (training / prefill)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, Bmat, Cmat, chunk: int, init_state=None):
    """x: [B,L,H,P], dt: [B,L,H] (post-softplus), A: [H] (negative),
    Bmat/Cmat: [B,L,G,N].  Returns (y [B,L,H,P], final_state [B,H,P,N])."""
    Bsz, L0, H, Pdim = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    Q = min(chunk, L0)
    # Pad L to a chunk multiple.  dt=0 on pad positions is exact: decay
    # exp(0)=1 leaves the state untouched and x*dt=0 adds nothing.
    pad = (-L0) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = L0 + pad
    nc = L // Q
    rep = H // G

    def toc(t):  # [B, L, ...] -> [B, nc, Q, ...]
        return t.reshape((Bsz, nc, Q) + t.shape[2:])

    xc = toc(x * dt[..., None])  # pre-scale x by dt (standard SSD form)
    dA = toc(dt * A[None, None, :])  # [B,nc,Q,H]
    Bc = toc(Bmat)
    Cc = toc(Cmat)
    # expand groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3) if G != H else Bc  # [B,nc,Q,H,N]
    Ch = jnp.repeat(Cc, rep, axis=3) if G != H else Cc

    dA_cs = jnp.cumsum(dA, axis=2)  # [B,nc,Q,H]

    # --- intra-chunk (diagonal blocks) ---
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, 3, 2)))  # [B,nc,H,Q,Q]
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)  # C_q . B_k
    M = scores * Lmat.astype(scores.dtype)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", M, xc)

    # --- chunk states ---
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # [B,nc,Q,H]
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bh, decay_to_end, xc)  # [B,nc,H,P,N]

    # --- inter-chunk recurrence over nc ---
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # [B,nc,H]
    s0 = (
        init_state.astype(states.dtype)
        if init_state is not None
        else jnp.zeros((Bsz, H, Pdim, N), states.dtype)
    )

    def step(h, inp):
        dec, s = inp  # dec: [B,H], s: [B,H,P,N]
        h_new = h * dec[..., None, None] + s
        return h_new, h  # emit state *entering* the chunk

    (final_state, prev_states) = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [B,nc,H,P,N]

    # --- inter-chunk contribution ---
    state_decay = jnp.exp(dA_cs)  # decay from chunk start to position q
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, L, H, Pdim)
    return y[:, :L0], final_state


def ssd_reference(x, dt, A, Bmat, Cmat, init_state=None):
    """Naive sequential recurrence oracle (tests)."""
    Bsz, L, H, Pdim = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bmat, rep, axis=2) if G != H else Bmat
    Ch = jnp.repeat(Cmat, rep, axis=2) if G != H else Cmat
    h = init_state if init_state is not None else jnp.zeros((Bsz, H, Pdim, N), jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # [B,H,P], [B,H], [B,H,N], [B,H,N]
        decay = jnp.exp(dtt * A[None, :])  # [B,H]
        h = h * decay[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], Bt)
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct)
        return h, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bh, 1, 0),
        jnp.moveaxis(Ch, 1, 0),
    )
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h


# ---------------------------------------------------------------------------
# Layer apply
# ---------------------------------------------------------------------------


def ssm_layer(
    cfg: ModelConfig,
    spec: SSMSpec,
    params: dict,
    x: jax.Array,  # [B, S, D]
    *,
    cache: Optional[dict] = None,
    mode: str = "train",
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    di, g, n, h, p = spec.d_inner, spec.n_groups, spec.state_dim, spec.num_heads, spec.head_dim

    proj = x @ params["in_proj"]  # [B,S, 2di+2gn+h]
    z, xin, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    # decode AND chunked-prefill resume carry state across calls: the conv
    # prefix and SSD state picked up mid-sequence make chunk-by-chunk
    # processing exact (ssd_chunked takes an init_state for precisely this)
    resume = cache is not None and (
        mode.startswith("decode") or mode in ("prefill_chunk", "prefill_chunk_batched")
    )
    # batched multi-slot chunk prefill: positions [B, S] carry -1 for padded /
    # inactive entries.  dt = 0 there makes the SSD step the identity (decay
    # exp(0) = 1, input x*dt = 0 — the same exactness argument as
    # ssd_chunked's chunk padding), and the conv prefix is extracted at each
    # row's last VALID input.
    batched = mode == "prefill_chunk_batched" and positions is not None
    valid = (positions >= 0) if batched else None  # [B, S]
    valid_len = jnp.sum(valid, axis=1) if batched else None
    prefix = cache["conv"] if resume else None
    conv_out, new_prefix = _causal_conv(conv_in, params["conv_w"], prefix, valid_len)
    conv_out = jax.nn.silu(conv_out)
    xin, Bm, Cm = jnp.split(conv_out, [di, di + g * n], axis=-1)

    xh = xin.reshape(B, S, h, p)
    Bh = Bm.reshape(B, S, g, n).astype(jnp.float32)
    Ch = Cm.reshape(B, S, g, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,h]
    if batched:
        dt = jnp.where(valid[:, :, None], dt, 0.0)  # identity step on padding
    A = -jnp.exp(params["A_log"])  # [h], negative

    init_state = cache["state"] if resume else None
    if mode.startswith("decode") and S == 1:
        # single-step recurrence
        y, state = ssd_reference(xh.astype(jnp.float32), dt, A, Bh, Ch, init_state)
    else:
        y, state = ssd_chunked(xh.astype(jnp.float32), dt, A, Bh, Ch, spec.chunk, init_state)

    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)

    # gated RMSNorm (mamba2)
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + cfg.rms_eps) * (1.0 + params["norm_scale"].astype(jnp.float32))).astype(x.dtype)

    out = y @ params["out_proj"]

    new_cache = None
    if cache is not None:
        new_cache = {"state": state.astype(jnp.float32), "conv": new_prefix.astype(cache["conv"].dtype), "pos": cache["pos"] + S}
    return out, new_cache
