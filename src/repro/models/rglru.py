"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Linear recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) with
input-dependent gates; computed with ``jax.lax.associative_scan`` (parallel,
O(L log L)) for train/prefill and an O(1) step for decode.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LRUSpec, ModelConfig
from repro.models.modules import dense_init

C_EXP = 8.0  # Griffin's fixed exponent scaling


def init_lru(key, cfg: ModelConfig, spec: LRUSpec, dtype) -> dict:
    d, w, h = cfg.d_model, spec.lru_width, spec.num_heads
    bw = w // h
    ks = jax.random.split(key, 6)
    return {
        "in_x": dense_init(ks[0], d, w, dtype),
        "in_gate": dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (spec.conv_dim, w), jnp.float32) * 0.1).astype(dtype),
        # block-diagonal gates: [H, bw, bw]
        "w_input_gate": (jax.random.normal(ks[3], (h, bw, bw), jnp.float32) / jnp.sqrt(bw)).astype(dtype),
        "b_input_gate": jnp.zeros((h, bw), dtype),
        "w_forget_gate": (jax.random.normal(ks[4], (h, bw, bw), jnp.float32) / jnp.sqrt(bw)).astype(dtype),
        "b_forget_gate": jnp.zeros((h, bw), dtype),
        # Lambda parametrizes a = sigmoid(Lambda) in (0, 1); init near 0.9-0.999
        "Lambda": jnp.linspace(2.2, 6.9, w).astype(jnp.float32),
        "out_proj": dense_init(ks[5], w, d, dtype),
    }


def init_lru_cache(batch: int, spec: LRUSpec, dtype) -> dict:
    return {
        "state": jnp.zeros((batch, spec.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_dim - 1, spec.lru_width), dtype),
        "pos": jnp.zeros((batch, 1), jnp.int32),
    }


def _block_diag(xh: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """xh: [B,S,H,bw] @ w: [H,bw,bw] + b -> [B,S,H,bw]."""
    return jnp.einsum("bshi,hij->bshj", xh, w) + b


def _gates(params, x, h: int):
    B, S, W = x.shape
    bw = W // h
    xh = x.reshape(B, S, h, bw)
    i_t = jax.nn.sigmoid(_block_diag(xh, params["w_input_gate"], params["b_input_gate"]))
    r_t = jax.nn.sigmoid(_block_diag(xh, params["w_forget_gate"], params["b_forget_gate"]))
    i_t = i_t.reshape(B, S, W).astype(jnp.float32)
    r_t = r_t.reshape(B, S, W).astype(jnp.float32)
    log_a = -C_EXP * r_t * jax.nn.softplus(params["Lambda"])  # log a_t <= 0
    a = jnp.exp(log_a)
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, multiplier * i_t * x.astype(jnp.float32)


def lru_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None):
    """h_t = a_t h_{t-1} + b_t over axis 1 via associative scan."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh


def lru_layer(
    cfg: ModelConfig,
    spec: LRUSpec,
    params: dict,
    x: jax.Array,  # [B,S,D]
    *,
    cache: Optional[dict] = None,
    mode: str = "train",
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    gate = jax.nn.gelu(x @ params["in_gate"])  # [B,S,W]
    xb = x @ params["in_x"]

    # decode AND chunked-prefill resume carry state across calls (conv prefix
    # + recurrence state entering the chunk)
    resume = cache is not None and (
        mode.startswith("decode") or mode in ("prefill_chunk", "prefill_chunk_batched")
    )
    # batched multi-slot chunk prefill: positions [B, S] carry -1 at padded /
    # inactive entries.  a=1, b=0 there freezes the recurrence (h_t = h_{t-1})
    # so hs[:, -1] IS the state at each row's last valid position; the conv
    # prefix is extracted at the last valid input (see _causal_conv).
    batched = mode == "prefill_chunk_batched" and positions is not None
    valid = (positions >= 0) if batched else None  # [B, S]
    valid_len = jnp.sum(valid, axis=1) if batched else None
    prefix = cache["conv"] if resume else None
    from repro.models.ssm import _causal_conv

    xb, new_prefix = _causal_conv(xb, params["conv_w"], prefix, valid_len)

    a, b = _gates(params, xb, spec.num_heads)  # [B,S,W] f32 each
    if batched:
        a = jnp.where(valid[..., None], a, 1.0)
        b = jnp.where(valid[..., None], b, 0.0)

    if mode.startswith("decode") and S == 1:
        h0 = cache["state"]
        h = a[:, 0] * h0 + b[:, 0]
        hs = h[:, None]
        final = h
    else:
        h0 = cache["state"] if resume else None
        hs = lru_scan(a, b, h0)
        final = hs[:, -1]

    y = (hs.astype(x.dtype) * gate) @ params["out_proj"]

    new_cache = None
    if cache is not None:
        new_cache = {"state": final.astype(jnp.float32), "conv": new_prefix.astype(cache["conv"].dtype), "pos": cache["pos"] + S}
    return y, new_cache
