"""Top-level model: embeddings -> segments -> final norm -> logits.

Entry points used by training / serving / dry-run:

  * ``forward``      — teacher-forced logits (training / eval)
  * ``prefill``      — forward + build caches
  * ``decode_step``  — one token with caches
  * ``encode``       — encoder stack (enc-dec models)

Frontend-stub models (audio/vlm): callers pass precomputed frame/patch
embeddings (see ``FrontendSpec``); a learned projector maps them to d_model
and they are prepended to the token embeddings (vlm) or fed to the encoder
(audio enc-dec).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttnSpec, ModelConfig
from repro.models.attention import spec_is_paged
from repro.models.modules import dense_init, embed_init, init_rmsnorm, rmsnorm
from repro.models.transformer import apply_segment, init_segment, init_segment_cache
from repro.parallel.sharding import shard_hint
from repro.quant.kv import QuantizedKV
from repro.quant.qarrays import materialize


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_rmsnorm(cfg.d_model, dt),
        "segments": {
            f"seg{i}": init_segment(jax.random.fold_in(ks[1], i), cfg, seg, dt)
            for i, seg in enumerate(cfg.segments)
        },
    }
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(ks[2], cfg.vocab_size, cfg.d_model, dt).T  # [D, V]
    if cfg.encoder is not None:
        p["encoder"] = {
            "segments": {
                f"seg{i}": init_segment(jax.random.fold_in(ks[3], i), cfg, seg, dt)
                for i, seg in enumerate(cfg.encoder.segments)
            },
            "final_norm": init_rmsnorm(cfg.d_model, dt),
        }
    if cfg.frontend is not None:
        p["frontend_proj"] = dense_init(ks[4], cfg.frontend.embed_dim, cfg.d_model, dt)
    return p


def init_caches(cfg: ModelConfig, batch: int, capacity: int, *, cross_len: int = 0, kv_bits: int = 0) -> dict:
    """``kv_bits=8`` allocates int8 QuantizedKV self-attention caches
    (quantize-on-write; see repro/quant/kv.py), 0 = full precision."""
    dt = _dtype(cfg.param_dtype)
    return {
        f"seg{i}": init_segment_cache(cfg, seg, batch, capacity, dt, cross_len=cross_len, kv_bits=kv_bits)
        for i, seg in enumerate(cfg.segments)
    }


def init_paged_caches(
    cfg: ModelConfig, slots: int, capacity: int, *, n_pages: int, page_size: int,
    cross_len: int = 0, kv_bits: int = 0,
) -> dict:
    """Paged serving caches: global-context self-attention K/V live in shared
    page pools ``[n_pages + 1, page_size, H_kv, dh]`` addressed through
    per-slot block tables, instead of reserving ``capacity`` tokens per slot
    (serving/kv_pool.py).  Window rings, cross caches, and SSM/LRU states
    stay per-slot (``slots`` batch rows) — they are fixed-size already.
    ``capacity`` remains the per-sequence context bound (it sizes the block
    tables: ``ceil(capacity / page_size)`` entries per slot)."""
    dt = _dtype(cfg.param_dtype)
    return {
        f"seg{i}": init_segment_cache(
            cfg, seg, slots, capacity, dt, cross_len=cross_len, kv_bits=kv_bits,
            pages=(n_pages, page_size),
        )
        for i, seg in enumerate(cfg.segments)
    }


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]  # [B, S, D]
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)  # gemma-style scale
    return shard_hint(x, "batch", "seq", "embed")


def logits_out(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    w = params["embed"].T if cfg.tie_embeddings else materialize(params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return shard_hint(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Encoder (enc-dec)
# ---------------------------------------------------------------------------


def encode(cfg: ModelConfig, params: dict, source: jax.Array) -> jax.Array:
    """source: [B, T, frontend.embed_dim] (stubbed frontend embeddings) or
    token ids [B, T] if no frontend."""
    if cfg.frontend is not None and source.ndim == 3:
        x = source.astype(_dtype(cfg.compute_dtype)) @ materialize(params["frontend_proj"])
    else:
        x = embed_tokens(cfg, params, source)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
    enc = params["encoder"]
    for i, seg in enumerate(cfg.encoder.segments):
        x, _, _ = apply_segment(cfg, seg, enc["segments"][f"seg{i}"], x, pos, mode="train")
    return rmsnorm(enc["final_norm"], x, cfg.rms_eps)


# ---------------------------------------------------------------------------
# Decoder / LM entry points
# ---------------------------------------------------------------------------


def _run_segments(cfg, params, x, positions, caches, mode, memory, remat,
                  block_table=None, collect_stats=False):
    """With ``collect_stats=True`` returns a 4th element: ``{seg{i}: {pos{j}:
    RoutingStats[repeats, ...]}}`` for every MoE position — the per-layer
    routing telemetry tree (jit-returnable; host side aggregates via
    ``core.gating.summarize_routing``)."""
    aux = jnp.zeros((), jnp.float32)
    new_caches = {}
    stats = {}
    for i, seg in enumerate(cfg.segments):
        c = caches.get(f"seg{i}") if caches is not None else None
        out = apply_segment(
            cfg, seg, params["segments"][f"seg{i}"], x, positions,
            caches=c, mode=mode, memory=memory, remat=remat, block_table=block_table,
            collect_stats=collect_stats,
        )
        if collect_stats:
            x, c_new, a, seg_stats = out
            # analysis: allow(tracer-branch) — dict-emptiness check on a stats pytree (structure is static under tracing)
            if seg_stats:
                stats[f"seg{i}"] = seg_stats
        else:
            x, c_new, a = out
        aux = aux + a
        if caches is not None:
            new_caches[f"seg{i}"] = c_new
    res = (x, (new_caches if caches is not None else None), aux)
    return res + (stats,) if collect_stats else res


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # [B, S] int32
    *,
    positions: Optional[jax.Array] = None,
    memory: Optional[jax.Array] = None,
    prefix_embeds: Optional[jax.Array] = None,  # vlm patch embeddings [B, P, De]
    remat: bool = False,
    return_routing: bool = False,
) -> Tuple[jax.Array, ...]:
    """Teacher-forced logits [B, S(+P), V]; returns (logits, aux_loss).
    ``return_routing=True`` (static) appends the per-layer routing-stats
    tree (see ``_run_segments``) as a third element."""
    x = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        pre = prefix_embeds.astype(x.dtype) @ materialize(params["frontend_proj"])
        x = jnp.concatenate([pre, x], axis=1)
    S = x.shape[1]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None]
    if return_routing:
        x, _, aux, routing = _run_segments(
            cfg, params, x, positions, None, "train", memory, remat, collect_stats=True
        )
        return logits_out(cfg, params, x), aux, routing
    x, _, aux = _run_segments(cfg, params, x, positions, None, "train", memory, remat)
    return logits_out(cfg, params, x), aux


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    caches: dict,
    *,
    memory: Optional[jax.Array] = None,
    prefix_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """Returns (logits for the last position [B, V], filled caches)."""
    x = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        pre = prefix_embeds.astype(x.dtype) @ materialize(params["frontend_proj"])
        x = jnp.concatenate([pre, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    x, new_caches, _ = _run_segments(cfg, params, x, positions, caches, "prefill", memory, False)
    logits = logits_out(cfg, params, x[:, -1:])[:, 0]
    return logits, new_caches


def decode_step(
    cfg: ModelConfig,
    params: dict,
    token: jax.Array,  # [B, 1] int32
    index: jax.Array,  # [] int32 — current absolute position
    caches: dict,
    *,
    memory: Optional[jax.Array] = None,
    return_routing: bool = False,
) -> Tuple:
    """One decode step: returns (logits [B, V], updated caches);
    ``return_routing=True`` appends the routing-stats tree."""
    x = embed_tokens(cfg, params, token)
    B = x.shape[0]
    positions = jnp.broadcast_to(index.astype(jnp.int32), (B, 1))
    if return_routing:
        x, new_caches, _, routing = _run_segments(
            cfg, params, x, positions, caches, "decode", memory, False, collect_stats=True
        )
        return logits_out(cfg, params, x)[:, 0], new_caches, routing
    x, new_caches, _ = _run_segments(cfg, params, x, positions, caches, "decode", memory, False)
    logits = logits_out(cfg, params, x)[:, 0]
    return logits, new_caches


def ragged_decode_step(
    cfg: ModelConfig,
    params: dict,
    token: jax.Array,  # [B, 1] int32
    positions: jax.Array,  # [B] int32 — PER-ROW absolute position
    active: jax.Array,  # [B] bool — rows with live requests
    caches: dict,
    *,
    memory: Optional[jax.Array] = None,
    return_routing: bool = False,
) -> Tuple:
    """Continuous-batching decode tick: each slot/row decodes at its own
    position; inactive rows' caches are left untouched (masked merge).
    ``return_routing=True`` appends the routing-stats tree (stats cover
    every slot row, active or not — padding rows route too; host side
    treats the per-tick stats as a load-shape sample, not exact counts)."""
    x = embed_tokens(cfg, params, token)
    pos2d = positions.astype(jnp.int32)[:, None]
    routing = None
    if return_routing:
        x, new_caches, _, routing = _run_segments(
            cfg, params, x, pos2d, caches, "decode_ragged", memory, False,
            collect_stats=True,
        )
    else:
        x, new_caches, _ = _run_segments(
            cfg, params, x, pos2d, caches, "decode_ragged", memory, False
        )
    logits = logits_out(cfg, params, x)[:, 0]

    def _merge(new, old):
        # cache leaves: [layers, B, ...] — select on the batch axis
        mask = active.reshape((1, -1) + (1,) * (new.ndim - 2))
        return jnp.where(mask, new, old)

    merged = jax.tree.map(_merge, new_caches, caches)
    if return_routing:
        return logits, merged, routing
    return logits, merged


def prefill_into_slot(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # [1, S] int32 — a single request's prompt
    positions: jax.Array,  # [1, S] int32
    slot: jax.Array,  # [] int32 — batch row in the pooled caches
    caches: dict,
    *,
    memory: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """Prefill one request and write its cache state into row ``slot`` of the
    pooled slot caches (continuous batching admission)."""
    x = embed_tokens(cfg, params, tokens)
    one_caches = init_caches(cfg, 1, _pool_capacity(caches), kv_bits=_pool_kv_bits(caches))
    x, filled, _ = _run_segments(cfg, params, x, positions, one_caches, "prefill", memory, False)
    logits = logits_out(cfg, params, x[:, -1:])[:, 0]

    def _write(pool, one):
        return jax.lax.dynamic_update_slice_in_dim(pool, one.astype(pool.dtype), slot, axis=1)

    merged = jax.tree.map(_write, caches, filled)
    return logits, merged


# ---------------------------------------------------------------------------
# Paged serving entry points (shared page pool + per-slot block tables)
# ---------------------------------------------------------------------------


def _layer_entries(cfg: ModelConfig):
    """Yield (seg_key, pos_key, LayerSpec, paged_self) over the decoder."""
    for i, seg in enumerate(cfg.segments):
        for j, ls in enumerate(seg.pattern):
            paged = isinstance(ls.mixer, AttnSpec) and spec_is_paged(ls.mixer)
            yield f"seg{i}", f"pos{j}", ls, paged


def arch_fully_paged(cfg: ModelConfig) -> bool:
    """True iff every sequence-mixing layer's state lives in the shared page
    pool under paged serving — i.e. no window rings and no SSM/LRU states.

    This is the condition for prefix sharing to skip the shared prefix's
    *prefill compute* (chunked prefill reads the shared pages in place): any
    non-paged sequential state must be rebuilt by actually running the
    prefix, so mixed archs (gemma3 ring mixes, hybrids) still compute it —
    they keep the page-sharing memory win, write nothing to shared pages
    (trash-routed), and only fully-paged archs get the FLOPs win too."""
    for _, _, ls, paged in _layer_entries(cfg):
        if not paged:
            return False
    return True


def paged_ragged_decode_step(
    cfg: ModelConfig,
    params: dict,
    token: jax.Array,  # [B, 1] int32
    positions: jax.Array,  # [B] int32 — PER-ROW absolute position
    active: jax.Array,  # [B] bool — rows with live requests
    caches: dict,  # from init_paged_caches
    block_table: jax.Array,  # [B, max_pages] int32, -1 = unmapped
    *,
    memory: Optional[jax.Array] = None,
    return_routing: bool = False,
) -> Tuple:
    """Continuous-batching decode tick over paged caches.  Pool writes are
    self-masking (inactive slots' table rows are all -1, so their writes land
    in the trash page); the per-slot leaves (window rings, SSM/LRU states,
    cross caches) get the same masked merge as ``ragged_decode_step``.
    ``return_routing=True`` appends the routing-stats tree."""
    x = embed_tokens(cfg, params, token)
    pos2d = positions.astype(jnp.int32)[:, None]
    routing = None
    if return_routing:
        x, new_caches, _, routing = _run_segments(
            cfg, params, x, pos2d, caches, "decode_paged", memory, False,
            block_table=block_table, collect_stats=True,
        )
    else:
        x, new_caches, _ = _run_segments(
            cfg, params, x, pos2d, caches, "decode_paged", memory, False,
            block_table=block_table,
        )
    logits = logits_out(cfg, params, x)[:, 0]

    def _merge(new, old):
        # per-slot leaves: [layers, B, ...] — select on the batch axis
        mask = active.reshape((1, -1) + (1,) * (new.ndim - 2))
        return jnp.where(mask, new, old)

    merged = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c_new, c_old = new_caches[sk][pk], caches[sk][pk]
        out = {}
        for key in c_new:
            if key == "self" and paged:
                out[key] = c_new[key]  # pool — already masked via trash routing
            else:
                out[key] = jax.tree.map(_merge, c_new[key], c_old[key])
        merged.setdefault(sk, {})[pk] = out
    if return_routing:
        return logits, merged, routing
    return logits, merged


def paged_reset_pages(cfg: ModelConfig, caches: dict, page_mask: jax.Array) -> dict:
    """Invalidate pages returned to the pool: ``page_mask`` [n_pages + 1]
    bool -> those pages' ``pos`` entries become -1 in every layer's pool.

    Required for correctness, not hygiene: page reuse only overwrites the
    entries the new sequence actually fills, so without this a recycled
    page's leftover positions (which can be <= the new sequence's query
    position) would unmask the previous occupant's K/V."""
    out = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c = dict(caches[sk][pk])
        if paged:
            self_c = dict(c["self"])
            # pos: [repeats, n_pages + 1, page_size]
            self_c["pos"] = jnp.where(page_mask[None, :, None], -1, self_c["pos"])
            c["self"] = self_c
        out.setdefault(sk, {})[pk] = c
    return out


def _copy_axis1(buf: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Copy one index of axis 1 — the page axis of pool leaves
    ``[repeats, n_pages + 1, page_size, ...]`` and the batch axis of
    per-slot leaves ``[repeats, slots, ...]``."""
    one = jax.lax.dynamic_slice_in_dim(buf, src, 1, axis=1)
    return jax.lax.dynamic_update_slice_in_dim(buf, one, dst, axis=1)


def paged_copy_page(cfg: ModelConfig, caches: dict, src, dst) -> dict:
    """Copy one physical page's contents ``src -> dst`` in every paged
    layer's pool (k, v, and pos; (q, scale) pairs verbatim for int8 pools) —
    the device half of copy-on-write.  The scheduler calls this after
    ``KVBlockPool.fork`` hands the diverging slot a fresh page and before the
    slot's next append, so the shared original is never written.  ``src`` /
    ``dst`` are traced scalars: every CoW hits one compilation."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    out = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c = dict(caches[sk][pk])
        if paged:
            c["self"] = jax.tree.map(lambda b: _copy_axis1(b, src, dst), c["self"])
        out.setdefault(sk, {})[pk] = c
    return out


def paged_copy_slot_leaves(cfg: ModelConfig, caches: dict, src, dst) -> dict:
    """Copy every PER-SLOT cache leaf's row ``src -> dst``: window rings,
    SSM/LRU states, cross caches — everything that is not in a shared page
    pool.  Parallel sampling forks a freshly-admitted slot this way: the
    fork's block table points at the base's pages (pool ``share``), and the
    non-paged state is duplicated row-wise so both samples carry identical
    prompt context.  ``src`` / ``dst`` are traced scalars."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    out = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c_old = caches[sk][pk]
        c = {}
        for key in c_old:
            if key == "self" and paged:
                c[key] = c_old[key]  # shared pool — the table carries the fork
            else:
                c[key] = jax.tree.map(lambda b: _copy_axis1(b, src, dst), c_old[key])
        out.setdefault(sk, {})[pk] = c
    return out


def paged_prefill_chunk(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # [1, C] int32 — one page-aligned chunk of the prompt
    positions: jax.Array,  # [1, C] int32 — absolute positions (chunk start..end-1)
    slot: jax.Array,  # [] int32 — batch row for the per-slot leaves
    caches: dict,  # from init_paged_caches
    table_row: jax.Array,  # [max_pages] int32 — the slot's block table, -1 unmapped
    *,
    capacity: int,
    kv_bits: int = 0,
    page_size: int,
    reset: bool = False,  # static: True for an admission's FIRST chunk
    memory: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """One chunk of a resumable admission prefill, written DIRECTLY into pool
    pages — the chunked replacement for ``paged_prefill_into_slot``'s
    temp-contiguous-then-scatter path.  Per chunk:

      * paged self-attention layers attend over (the sequence's
        already-written pages — earlier chunks AND shared prefix pages, read
        in place through ``table_row`` — ++ the chunk's in-flight K/V) and
        write the chunk's K/V straight into its destination pages
        (models/attention.py ``prefill_chunk`` mode; Pallas kernel in
        kernels/attention_prefill_paged.py, int8 pools dequantized in VMEM);
      * per-slot leaves (window rings, SSM/LRU states, cross caches) are
        sliced out at row ``slot``, advanced by the chunk (rings append at
        ``pos % cap``; SSM/LRU resume from their carried state), and written
        back — so the state machine is fully resumable across engine ticks.

    The scheduler must have mapped every page the chunk writes into
    ``table_row`` before the first chunk, and chunks must be submitted in
    position order starting at the first non-shared position (a
    prefix-sharing admission starts AFTER the shared pages, which is what
    turns page sharing into prefill-FLOPs sharing).  Returns (last-chunk-
    position logits [1, V], updated caches); only the final chunk's logits
    seed the first sampled token.

    ``reset=True`` (an admission's FIRST chunk) starts the per-slot leaves
    from their freshly-initialized values — zero SSM/LRU state, empty conv
    prefixes, rings with ``pos == -1`` — instead of resuming row ``slot``'s
    contents: the row still holds the slot's PREVIOUS occupant's state (the
    scatter path rewrote the whole row implicitly; the chunked state machine
    must reset explicitly or a reused slot leaks its predecessor's
    recurrence into the new request's first chunk).  Later chunks resume.

    There is no temp contiguous cache anywhere in this path: peak admission
    memory is the chunk activations, not a ``capacity``-token double buffer.
    """
    x = embed_tokens(cfg, params, tokens)

    def _slice_row(leaf):
        return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=1)

    fresh = (
        init_paged_caches(cfg, 1, capacity, n_pages=1, page_size=page_size,
                          kv_bits=kv_bits)
        if reset else None
    )  # paged pool leaves of `fresh` are unused (DCE'd); per-slot rows are
    one = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c = caches[sk][pk]
        o = {}
        for key in c:
            if key == "self" and paged:
                o[key] = c[key]  # shared pool — addressed via the table
            elif reset:
                o[key] = fresh[sk][pk][key]  # init-valued row (ring pos -1)
            else:
                o[key] = jax.tree.map(_slice_row, c[key])
        one.setdefault(sk, {})[pk] = o

    x, updated, _ = _run_segments(
        cfg, params, x, positions, one, "prefill_chunk", memory, False,
        block_table=table_row[None],
    )
    logits = logits_out(cfg, params, x[:, -1:])[:, 0]

    def _write_row(pool, row):
        return jax.lax.dynamic_update_slice_in_dim(pool, row.astype(pool.dtype), slot, axis=1)

    merged = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c_pool, c_new = caches[sk][pk], updated[sk][pk]
        o = {}
        for key in c_pool:
            if key == "self" and paged:
                o[key] = c_new[key]  # pool pages were written by the chunk
            else:
                o[key] = jax.tree.map(_write_row, c_pool[key], c_new[key])
        merged.setdefault(sk, {})[pk] = o
    return logits, merged


def paged_prefill_chunk_batched(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # [S, C] int32 — one chunk per slot, 0-padded
    positions: jax.Array,  # [S, C] int32 — absolute positions, -1 at padding
    reset: jax.Array,  # [S] bool — row runs its admission's FIRST chunk
    active: jax.Array,  # [S] bool — row has a chunk this tick
    last_idx: jax.Array,  # [S] int32 — index of each row's last valid token
    caches: dict,  # from init_paged_caches
    block_tables: jax.Array,  # [S, max_pages] int32 — -1 unmapped; all -1 when inactive
    *,
    capacity: int,
    kv_bits: int = 0,
    page_size: int,
    memory: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """ALL mid-prefill slots advance one chunk in a single jitted call — the
    batched replacement for looping ``paged_prefill_chunk`` per slot.  With N
    admissions mid-prefill, the per-slot loop issues N dispatches per engine
    tick; this issues ONE, making a tick at most {one batched prefill, one
    batched decode} (the "fused tick").  Numerics per row are identical to
    the per-slot path (tests/test_chunked.py asserts token-exact parity):

      * rows' chunks may have different lengths — each row is a valid prefix
        (positions >= 0) followed by -1 padding.  Padding is inert by
        construction, not by masking outputs: paged attention writes route
        invalid positions to the trash page, ring writes drop them via the
        scatter's out-of-bounds semantics, SSM steps use dt = 0 (identity),
        LRU gates freeze (a = 1, b = 0), and conv prefixes are extracted at
        each row's last valid input;
      * INACTIVE rows (no chunk this tick) carry all--1 table rows, so their
        pool writes also land in the trash page, and their per-slot leaves
        (rings, SSM/LRU states, cross caches) are restored from the incoming
        caches by the ``active`` masked merge below;
      * ``reset`` rows start their per-slot leaves from freshly-initialized
        values (zero recurrence state, ring pos -1) exactly as
        ``paged_prefill_chunk(reset=True)`` does — a reused slot must not
        leak its previous occupant's state.

    Distinct rows never write the same pool entry: the scheduler maps each
    page to exactly one owner, and a page written this tick cannot appear in
    another row's table as a shared prefix (sharing only covers pages
    completed on a PRIOR tick).  Trash-page collisions are order-independent
    (every trash write stores pos = -1).

    Returns (logits at each row's last valid position [S, V], updated
    caches); only rows finishing their prompt this tick use their logits (to
    seed the first sampled token) — the rest are discarded by the engine.
    """
    x = embed_tokens(cfg, params, tokens)
    S = tokens.shape[0]

    fresh = init_paged_caches(
        cfg, S, capacity, n_pages=1, page_size=page_size, kv_bits=kv_bits
    )  # pool leaves unused (DCE'd); per-slot leaves give reset rows' values

    def _reset_rows(cur, fr):
        mask = reset.reshape((1, -1) + (1,) * (cur.ndim - 2))
        return jnp.where(mask, fr.astype(cur.dtype), cur)

    one = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c = caches[sk][pk]
        o = {}
        for key in c:
            if key == "self" and paged:
                o[key] = c[key]  # shared pool — addressed via the tables
            else:
                o[key] = jax.tree.map(_reset_rows, c[key], fresh[sk][pk][key])
        one.setdefault(sk, {})[pk] = o

    x, updated, _ = _run_segments(
        cfg, params, x, positions, one, "prefill_chunk_batched", memory, False,
        block_table=block_tables,
    )
    xe = jnp.take_along_axis(x, last_idx.astype(jnp.int32)[:, None, None], axis=1)
    logits = logits_out(cfg, params, xe)[:, 0]  # [S, V]

    def _merge(new, old):
        # per-slot leaves: [repeats, S, ...] — select on the batch axis
        mask = active.reshape((1, -1) + (1,) * (new.ndim - 2))
        return jnp.where(mask, new, old)

    merged = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c_new, c_old = updated[sk][pk], caches[sk][pk]
        o = {}
        for key in c_new:
            if key == "self" and paged:
                o[key] = c_new[key]  # pool — inactive rows trash-routed
            else:
                o[key] = jax.tree.map(_merge, c_new[key], c_old[key])
        merged.setdefault(sk, {})[pk] = o
    return logits, merged


def paged_verify_chunk_batched(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # [S, C] int32 — cur token + k drafted tokens per slot
    positions: jax.Array,  # [S, C] int32 — absolute positions, -1 at padding
    active: jax.Array,  # [S] bool — row has a speculation window this tick
    caches: dict,  # from init_paged_caches
    block_tables: jax.Array,  # [S, max_pages] int32 — tail entries point at CoW forks
    *,
    capacity: int,
    kv_bits: int = 0,
    page_size: int,
    memory: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """Speculative VERIFY: score all k + 1 window positions of every
    decoding slot in one batched pass — ``paged_prefill_chunk_batched``
    specialised for draft-then-verify:

      * logits are returned for EVERY chunk position (not just the last):
        position j's logits are the target model's distribution over the
        token at ``positions[:, j] + 1``, which is what accepts/rejects the
        drafted token at that position;
      * there is no ``reset`` — every verified slot is long past admission;
      * per-slot leaves (window rings, SSM/LRU states, conv prefixes) are
        returned UNCHANGED: verify is a read that must not advance recurrent
        state, because a rejection would have no way to roll it back.  Only
        pool pages are written — and the scheduler points the window's table
        entries at CoW fork pages precisely so that rejected writes can be
        rolled back by dropping pages (accepted ones commit by refcount
        handoff).  Non-fully-paged archs re-run the ACCEPTED tokens through
        a separate committed chunk pass to advance their recurrent leaves;
        its pool writes are inert (the `already`-stored guard in
        models/attention.py trash-routes rewrites of a stored position).

    Rows' windows may have different lengths (k is clamped near the budget
    end): a valid prefix followed by -1 position padding, inert exactly as
    in the batched prefill chunk.  Inactive rows carry all--1 tables.

    Returns (logits at every window position [S, C, V], updated caches).
    """
    x = embed_tokens(cfg, params, tokens)

    x, updated, _ = _run_segments(
        cfg, params, x, positions, caches, "prefill_chunk_batched", memory,
        False, block_table=block_tables,
    )
    logits = logits_out(cfg, params, x)  # [S, C, V]

    merged = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c_new, c_old = updated[sk][pk], caches[sk][pk]
        o = {}
        for key in c_new:
            if key == "self" and paged:
                o[key] = c_new[key]  # pool — fork-page writes, trash-routed when inactive
            else:
                o[key] = c_old[key]  # recurrent state must survive rejection
        merged.setdefault(sk, {})[pk] = o
    return logits, merged


def paged_reset_page_tails(
    cfg: ModelConfig,
    caches: dict,
    pages: jax.Array,  # [S] int32 — last committed page per slot, -1 = no-op row
    start_offs: jax.Array,  # [S] int32 — first in-page offset to invalidate
) -> dict:
    """Invalidate the TAIL of each slot's last committed page: offsets
    >= ``start_offs[i]`` of page ``pages[i]`` get ``pos = -1`` in every
    layer's pool.

    Required for speculative-decoding correctness, not hygiene: a committed
    window page still carries the verify pass's writes BEYOND the accepted
    point (rejected draft positions).  Those entries would satisfy the
    `already`-stored write guard (models/attention.py) when the NEXT verify
    round writes the same positions for real, silently trash-routing the
    real K/V.  Invalidating the tail restores the invariant the guard
    depends on: a live page never stores a position >= its slot's current
    length.  One fixed-shape call per commit tick covers every slot
    (``pages[i] = -1`` rows match nothing; ``start_offs[i] = page_size`` is
    a row-level no-op)."""
    out = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c = dict(caches[sk][pk])
        if paged:
            self_c = dict(c["self"])
            pos = self_c["pos"]  # [repeats, n_pages + 1, page_size]
            n_pages, ps = pos.shape[1], pos.shape[2]
            hit = jnp.arange(n_pages)[None, :] == pages[:, None]  # [S, P]
            offm = jnp.arange(ps)[None, :] >= start_offs[:, None]  # [S, ps]
            mask = (hit[:, :, None] & offm[:, None, :]).any(axis=0)  # [P, ps]
            self_c["pos"] = jnp.where(mask[None], -1, pos)
            c["self"] = self_c
        out.setdefault(sk, {})[pk] = c
    return out


def paged_prefill_into_slot(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # [1, S] int32 — a single request's prompt
    positions: jax.Array,  # [1, S] int32
    slot: jax.Array,  # [] int32 — batch row for the per-slot leaves
    caches: dict,  # from init_paged_caches
    table_row: jax.Array,  # [max_pages] int32 — the slot's block table, -1 unmapped
    *,
    capacity: int,
    kv_bits: int = 0,
    memory: Optional[jax.Array] = None,
    scatter_start=0,  # [] int32 (traced ok) — first position written to pages
) -> Tuple[jax.Array, dict]:
    """One-shot admission prefill via temp-contiguous-then-scatter: run the
    ordinary contiguous prefill into a temporary single-sequence cache
    (identical numerics to the non-paged path), then scatter the filled K/V
    into the slot's block-table pages and dynamic-update the per-slot leaves
    at ``slot``.  The scheduler must have mapped ``ceil(S / page_size)``
    pages into ``table_row``.

    This is no longer the default admission path — ``paged_prefill_chunk``
    writes pages directly, with no temp buffer and no recompute of shared
    prefixes.  It is retained as the *parity oracle* for chunked prefill
    (``ContinuousEngine(prefill_mode="scatter")``; tests/test_chunked.py
    asserts token-identical greedy outputs between the two) and as the
    reference for the scatter semantics below.

    ``scatter_start`` supports prefix sharing: positions below it already
    live in pages SHARED with other slots (mapped into ``table_row`` by the
    scheduler), so their writes are routed to the trash page — a shared page
    is never mutated by an admission, only read through the table.  The
    prefill compute still covers the full context here (the chunked path is
    the one that also skips the shared prefix's FLOPs).  It is a traced
    scalar, so varying prefix lengths hit one compilation per prompt
    length."""
    S = tokens.shape[1]
    assert S <= capacity, f"prompt {S} exceeds per-sequence capacity {capacity}"
    x = embed_tokens(cfg, params, tokens)
    one_caches = init_caches(cfg, 1, capacity, kv_bits=kv_bits)
    x, filled, _ = _run_segments(cfg, params, x, positions, one_caches, "prefill", memory, False)
    logits = logits_out(cfg, params, x[:, -1:])[:, 0]
    pos_vec = positions[0].astype(jnp.int32)  # [S]
    start = jnp.asarray(scatter_start, jnp.int32)

    def _write_slot(pool, one):
        return jax.lax.dynamic_update_slice_in_dim(pool, one.astype(pool.dtype), slot, axis=1)

    def _scatter_self(pool, tmp):
        # pool: {"k","v","pos"} with leading repeats axis, pool tensors
        # [R, Pt, ps, ...]; tmp: contiguous [R, 1, capacity, ...] with the
        # prompt written at 0..S-1
        Pt, ps = pool["pos"].shape[1], pool["pos"].shape[2]
        pages = table_row[pos_vec // ps]
        pages = jnp.where((pages < 0) | (pos_vec < start), Pt - 1, pages).astype(jnp.int32)
        offs = pos_vec % ps

        def scat(buf, vals):
            return buf.at[:, pages, offs].set(vals)

        def scat_kv(old, tmp_kv):
            if isinstance(old, QuantizedKV):
                # tmp was quantized on write during prefill — copy (q, scale)
                # pairs verbatim, no requantization
                return QuantizedKV(
                    scat(old.q, tmp_kv.q[:, 0, :S]),
                    scat(old.scale, tmp_kv.scale[:, 0, :S]),
                    old.orig_dtype,
                )
            return scat(old, tmp_kv[:, 0, :S].astype(old.dtype))

        pos_val = jnp.where(pages == Pt - 1, -1, pos_vec)
        return {
            "k": scat_kv(pool["k"], tmp["k"]),
            "v": scat_kv(pool["v"], tmp["v"]),
            "pos": scat(pool["pos"], jnp.broadcast_to(pos_val, (pool["pos"].shape[0], S))),
        }

    merged = {}
    for sk, pk, ls, paged in _layer_entries(cfg):
        c_pool, c_tmp = caches[sk][pk], filled[sk][pk]
        out = {}
        for key in c_pool:
            if key == "self" and paged:
                out[key] = _scatter_self(c_pool[key], c_tmp[key])
            else:
                out[key] = jax.tree.map(_write_slot, c_pool[key], c_tmp[key])
        merged.setdefault(sk, {})[pk] = out
    return logits, merged


def _pool_capacity(caches: dict) -> int:
    """Original capacity the pooled caches were built with: the largest KV
    seq dim across layers (window layers hold smaller rings)."""
    caps = [leaf.shape[2] for leaf in jax.tree.leaves(caches) if leaf.ndim == 5]
    return max(caps) if caps else 1


def _pool_kv_bits(caches: dict) -> int:
    """KV quantization of an existing cache pool (so per-request prefill
    caches in continuous batching are allocated with a matching layout)."""
    from repro.quant.kv import QuantizedKV

    leaves = jax.tree.leaves(caches, is_leaf=lambda l: isinstance(l, QuantizedKV))
    return 8 if any(isinstance(l, QuantizedKV) for l in leaves) else 0
