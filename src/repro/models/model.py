"""Top-level model: embeddings -> segments -> final norm -> logits.

Entry points used by training / serving / dry-run:

  * ``forward``      — teacher-forced logits (training / eval)
  * ``prefill``      — forward + build caches
  * ``decode_step``  — one token with caches
  * ``encode``       — encoder stack (enc-dec models)

Frontend-stub models (audio/vlm): callers pass precomputed frame/patch
embeddings (see ``FrontendSpec``); a learned projector maps them to d_model
and they are prepended to the token embeddings (vlm) or fed to the encoder
(audio enc-dec).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.modules import dense_init, embed_init, init_rmsnorm, rmsnorm
from repro.models.transformer import apply_segment, init_segment, init_segment_cache
from repro.parallel.sharding import shard_hint
from repro.quant.qarrays import materialize


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_rmsnorm(cfg.d_model, dt),
        "segments": {
            f"seg{i}": init_segment(jax.random.fold_in(ks[1], i), cfg, seg, dt)
            for i, seg in enumerate(cfg.segments)
        },
    }
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(ks[2], cfg.vocab_size, cfg.d_model, dt).T  # [D, V]
    if cfg.encoder is not None:
        p["encoder"] = {
            "segments": {
                f"seg{i}": init_segment(jax.random.fold_in(ks[3], i), cfg, seg, dt)
                for i, seg in enumerate(cfg.encoder.segments)
            },
            "final_norm": init_rmsnorm(cfg.d_model, dt),
        }
    if cfg.frontend is not None:
        p["frontend_proj"] = dense_init(ks[4], cfg.frontend.embed_dim, cfg.d_model, dt)
    return p


def init_caches(cfg: ModelConfig, batch: int, capacity: int, *, cross_len: int = 0, kv_bits: int = 0) -> dict:
    """``kv_bits=8`` allocates int8 QuantizedKV self-attention caches
    (quantize-on-write; see repro/quant/kv.py), 0 = full precision."""
    dt = _dtype(cfg.param_dtype)
    return {
        f"seg{i}": init_segment_cache(cfg, seg, batch, capacity, dt, cross_len=cross_len, kv_bits=kv_bits)
        for i, seg in enumerate(cfg.segments)
    }


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]  # [B, S, D]
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)  # gemma-style scale
    return shard_hint(x, "batch", "seq", "embed")


def logits_out(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    w = params["embed"].T if cfg.tie_embeddings else materialize(params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return shard_hint(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Encoder (enc-dec)
# ---------------------------------------------------------------------------


def encode(cfg: ModelConfig, params: dict, source: jax.Array) -> jax.Array:
    """source: [B, T, frontend.embed_dim] (stubbed frontend embeddings) or
    token ids [B, T] if no frontend."""
    if cfg.frontend is not None and source.ndim == 3:
        x = source.astype(_dtype(cfg.compute_dtype)) @ materialize(params["frontend_proj"])
    else:
        x = embed_tokens(cfg, params, source)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
    enc = params["encoder"]
    for i, seg in enumerate(cfg.encoder.segments):
        x, _, _ = apply_segment(cfg, seg, enc["segments"][f"seg{i}"], x, pos, mode="train")
    return rmsnorm(enc["final_norm"], x, cfg.rms_eps)


# ---------------------------------------------------------------------------
# Decoder / LM entry points
# ---------------------------------------------------------------------------


def _run_segments(cfg, params, x, positions, caches, mode, memory, remat):
    aux = jnp.zeros((), jnp.float32)
    new_caches = {}
    for i, seg in enumerate(cfg.segments):
        c = caches.get(f"seg{i}") if caches is not None else None
        x, c_new, a = apply_segment(
            cfg, seg, params["segments"][f"seg{i}"], x, positions,
            caches=c, mode=mode, memory=memory, remat=remat,
        )
        aux = aux + a
        if caches is not None:
            new_caches[f"seg{i}"] = c_new
    return x, (new_caches if caches is not None else None), aux


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # [B, S] int32
    *,
    positions: Optional[jax.Array] = None,
    memory: Optional[jax.Array] = None,
    prefix_embeds: Optional[jax.Array] = None,  # vlm patch embeddings [B, P, De]
    remat: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced logits [B, S(+P), V]; returns (logits, aux_loss)."""
    x = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        pre = prefix_embeds.astype(x.dtype) @ materialize(params["frontend_proj"])
        x = jnp.concatenate([pre, x], axis=1)
    S = x.shape[1]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None]
    x, _, aux = _run_segments(cfg, params, x, positions, None, "train", memory, remat)
    return logits_out(cfg, params, x), aux


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    caches: dict,
    *,
    memory: Optional[jax.Array] = None,
    prefix_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """Returns (logits for the last position [B, V], filled caches)."""
    x = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        pre = prefix_embeds.astype(x.dtype) @ materialize(params["frontend_proj"])
        x = jnp.concatenate([pre, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    x, new_caches, _ = _run_segments(cfg, params, x, positions, caches, "prefill", memory, False)
    logits = logits_out(cfg, params, x[:, -1:])[:, 0]
    return logits, new_caches


def decode_step(
    cfg: ModelConfig,
    params: dict,
    token: jax.Array,  # [B, 1] int32
    index: jax.Array,  # [] int32 — current absolute position
    caches: dict,
    *,
    memory: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """One decode step: returns (logits [B, V], updated caches)."""
    x = embed_tokens(cfg, params, token)
    B = x.shape[0]
    positions = jnp.broadcast_to(index.astype(jnp.int32), (B, 1))
    x, new_caches, _ = _run_segments(cfg, params, x, positions, caches, "decode", memory, False)
    logits = logits_out(cfg, params, x)[:, 0]
    return logits, new_caches


def ragged_decode_step(
    cfg: ModelConfig,
    params: dict,
    token: jax.Array,  # [B, 1] int32
    positions: jax.Array,  # [B] int32 — PER-ROW absolute position
    active: jax.Array,  # [B] bool — rows with live requests
    caches: dict,
    *,
    memory: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """Continuous-batching decode tick: each slot/row decodes at its own
    position; inactive rows' caches are left untouched (masked merge)."""
    x = embed_tokens(cfg, params, token)
    pos2d = positions.astype(jnp.int32)[:, None]
    x, new_caches, _ = _run_segments(
        cfg, params, x, pos2d, caches, "decode_ragged", memory, False
    )
    logits = logits_out(cfg, params, x)[:, 0]

    def _merge(new, old):
        # cache leaves: [layers, B, ...] — select on the batch axis
        mask = active.reshape((1, -1) + (1,) * (new.ndim - 2))
        return jnp.where(mask, new, old)

    merged = jax.tree.map(_merge, new_caches, caches)
    return logits, merged


def prefill_into_slot(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # [1, S] int32 — a single request's prompt
    positions: jax.Array,  # [1, S] int32
    slot: jax.Array,  # [] int32 — batch row in the pooled caches
    caches: dict,
    *,
    memory: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """Prefill one request and write its cache state into row ``slot`` of the
    pooled slot caches (continuous batching admission)."""
    x = embed_tokens(cfg, params, tokens)
    one_caches = init_caches(cfg, 1, _pool_capacity(caches), kv_bits=_pool_kv_bits(caches))
    x, filled, _ = _run_segments(cfg, params, x, positions, one_caches, "prefill", memory, False)
    logits = logits_out(cfg, params, x[:, -1:])[:, 0]

    def _write(pool, one):
        return jax.lax.dynamic_update_slice_in_dim(pool, one.astype(pool.dtype), slot, axis=1)

    merged = jax.tree.map(_write, caches, filled)
    return logits, merged


def _pool_capacity(caches: dict) -> int:
    """Original capacity the pooled caches were built with: the largest KV
    seq dim across layers (window layers hold smaller rings)."""
    caps = [leaf.shape[2] for leaf in jax.tree.leaves(caches) if leaf.ndim == 5]
    return max(caps) if caps else 1


def _pool_kv_bits(caches: dict) -> int:
    """KV quantization of an existing cache pool (so per-request prefill
    caches in continuous batching are allocated with a matching layout)."""
    from repro.quant.kv import QuantizedKV

    leaves = jax.tree.leaves(caches, is_leaf=lambda l: isinstance(l, QuantizedKV))
    return 8 if any(isinstance(l, QuantizedKV) for l in leaves) else 0
