"""Block / segment assembly.

A block = pre-norm mixer (attention | SSD | RG-LRU) [+ pre-norm cross-attn]
+ pre-norm FFN (dense | MoE | none), all residual.  Segments stack identical
patterns under ``jax.lax.scan`` with parameters (and caches) stacked on a
leading ``repeats`` axis — HLO size stays O(unique layer kinds) regardless of
depth, which is what makes 512-device dry-run compiles of 62-95 layer models
tractable, and is the natural representation for PR-MoE's pyramid segments.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttnSpec, FFNSpec, LayerSpec, LRUSpec, ModelConfig, Segment, SSMSpec
from repro.core.moe import init_moe, moe_layer
from repro.models.attention import (
    attention,
    init_attention,
    init_kv_cache,
    init_paged_kv_cache,
    spec_is_paged,
)
from repro.models.modules import init_mlp, init_rmsnorm, mlp, rmsnorm
from repro.models.rglru import init_lru, init_lru_cache, lru_layer
from repro.models.ssm import init_ssm, init_ssm_cache, ssm_layer
from repro.parallel.sharding import shard_hint


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, ls: LayerSpec, dtype) -> dict:
    ks = jax.random.split(key, 6)
    p = {"norm_mixer": init_rmsnorm(cfg.d_model, dtype)}
    m = ls.mixer
    if isinstance(m, AttnSpec):
        p["attn"] = init_attention(ks[0], cfg, m, dtype)
    elif isinstance(m, SSMSpec):
        p["ssm"] = init_ssm(ks[0], cfg, m, dtype)
    elif isinstance(m, LRUSpec):
        p["lru"] = init_lru(ks[0], cfg, m, dtype)
    else:
        raise TypeError(m)
    if ls.cross is not None:
        p["norm_cross"] = init_rmsnorm(cfg.d_model, dtype)
        p["cross"] = init_attention(ks[1], cfg, ls.cross, dtype)
    if ls.ffn.kind == "dense":
        p["norm_ffn"] = init_rmsnorm(cfg.d_model, dtype)
        p["ffn"] = init_mlp(ks[2], cfg.d_model, ls.ffn.d_ff, ls.ffn.act, dtype)
    elif ls.ffn.kind == "moe":
        p["norm_ffn"] = init_rmsnorm(cfg.d_model, dtype)
        p["moe"] = init_moe(ks[2], cfg, ls.ffn, dtype)
    return p


def init_layer_cache(cfg: ModelConfig, ls: LayerSpec, batch: int, capacity: int, dtype, *, cross_len: int = 0, kv_bits: int = 0, pages=None):
    """Cache pytree for one layer.  ``capacity`` = full-context length for
    global attention; local layers get a ring of size window.  ``kv_bits=8``
    stores self-attention K/V as int8 QuantizedKV (cross caches and SSM/LRU
    states stay fp — they are tiny by comparison).

    ``pages = (n_pages, page_size)`` switches global-context self caches to a
    shared page pool addressed through block tables (paged serving); window
    rings, cross caches, and SSM/LRU states stay per-slot."""
    c = {}
    m = ls.mixer
    if isinstance(m, AttnSpec):
        if pages is not None and spec_is_paged(m):
            n_pages, page_size = pages
            c["self"] = init_paged_kv_cache(
                n_pages, page_size, cfg.num_kv_heads, cfg.head_dim, dtype, kv_bits=kv_bits
            )
        else:
            cap = min(m.window, capacity) if (m.kind == "local" and m.window > 0) else capacity
            c["self"] = init_kv_cache(batch, cap, cfg.num_kv_heads, cfg.head_dim, dtype, kv_bits=kv_bits)
    elif isinstance(m, SSMSpec):
        c["self"] = init_ssm_cache(batch, m, dtype)
    elif isinstance(m, LRUSpec):
        c["self"] = init_lru_cache(batch, m, dtype)
    if ls.cross is not None:
        c["cross"] = init_kv_cache(batch, max(cross_len, 1), cfg.num_kv_heads, cfg.head_dim, dtype)
    return c


def apply_layer(
    cfg: ModelConfig,
    ls: LayerSpec,
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[dict] = None,
    mode: str = "train",
    memory: Optional[jax.Array] = None,
    block_table: Optional[jax.Array] = None,
    collect_stats: bool = False,
) -> Tuple:
    """Returns (x, new_cache, aux_loss); with ``collect_stats=True`` a 4th
    element — the MoE layer's jit-returnable ``RoutingStats`` (None for
    non-MoE layers).  The flag is static (part of the trace), so telemetry
    collection is decided when the caller builds its jitted step."""
    aux = jnp.zeros((), jnp.float32)
    stats = None
    h = rmsnorm(params["norm_mixer"], x, cfg.rms_eps)
    sc = cache.get("self") if cache is not None else None
    m = ls.mixer
    if isinstance(m, AttnSpec):
        y, new_self = attention(
            cfg, m, params["attn"], h, positions, cache=sc, mode=mode,
            block_table=block_table,
        )
    elif isinstance(m, SSMSpec):
        y, new_self = ssm_layer(
            cfg, m, params["ssm"], h, cache=sc, mode=mode, positions=positions
        )
    elif isinstance(m, LRUSpec):
        y, new_self = lru_layer(
            cfg, m, params["lru"], h, cache=sc, mode=mode, positions=positions
        )
    else:
        raise TypeError(m)
    x = x + y
    new_cache = dict(cache) if cache is not None else None

    if ls.cross is not None:
        h = rmsnorm(params["norm_cross"], x, cfg.rms_eps)
        cc = cache.get("cross") if cache is not None else None
        y, new_cross = attention(
            cfg, ls.cross, params["cross"], h, positions, memory=memory, cache=cc, mode=mode
        )
        x = x + y
        if new_cache is not None:
            new_cache["cross"] = new_cross

    if ls.ffn.kind == "dense":
        h = rmsnorm(params["norm_ffn"], x, cfg.rms_eps)
        x = x + mlp(params["ffn"], h, ls.ffn.act)
    elif ls.ffn.kind == "moe":
        h = rmsnorm(params["norm_ffn"], x, cfg.rms_eps)
        if collect_stats:
            y, aux, stats = moe_layer(cfg, ls.ffn, params["moe"], h, with_stats=True)
        else:
            y, aux = moe_layer(cfg, ls.ffn, params["moe"], h)
        x = x + y

    if new_cache is not None and "self" in new_cache:
        new_cache["self"] = new_self
    x = shard_hint(x, "batch", "seq", "embed")
    if BF16_BWD[0]:
        from repro.models.modules import grad_cast

        x = grad_cast(x)
    if collect_stats:
        return x, new_cache, aux, stats
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Segment (scan over repeats)
# ---------------------------------------------------------------------------


def init_segment(key, cfg: ModelConfig, seg: Segment, dtype) -> dict:
    """Params: {"pos{j}": stacked-over-repeats layer params}."""
    out = {}
    for j, ls in enumerate(seg.pattern):
        keys = jax.random.split(jax.random.fold_in(key, j), seg.repeats)
        out[f"pos{j}"] = jax.vmap(lambda k: init_layer(k, cfg, ls, dtype))(keys)
    return out


def init_segment_cache(cfg: ModelConfig, seg: Segment, batch: int, capacity: int, dtype, *, cross_len: int = 0, kv_bits: int = 0, pages=None):
    out = {}
    for j, ls in enumerate(seg.pattern):
        one = init_layer_cache(cfg, ls, batch, capacity, dtype, cross_len=cross_len, kv_bits=kv_bits, pages=pages)
        out[f"pos{j}"] = jax.tree.map(lambda a: jnp.broadcast_to(a, (seg.repeats,) + a.shape), one)
    return out


def apply_segment(
    cfg: ModelConfig,
    seg: Segment,
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    caches: Optional[dict] = None,
    mode: str = "train",
    memory: Optional[jax.Array] = None,
    remat: bool = False,
    block_table: Optional[jax.Array] = None,
    collect_stats: bool = False,
):
    """Scan the segment.  caches (if given) mirror the params structure with a
    leading ``repeats`` axis.  Returns (x, new_caches, aux_sum); with
    ``collect_stats=True`` a 4th element — ``{pos{j}: RoutingStats}`` for the
    pattern's MoE positions, each leaf stacked ``[repeats, ...]`` by the scan
    (per-layer telemetry falls out of the scan's ys stacking for free).

    ``block_table`` (paged decode) is layer-invariant: every layer's page
    pool shares one table, so it rides into the scan body as a capture."""
    has_cache = caches is not None

    def body(carry, xs):
        x, aux = carry
        new_caches = {}
        stats_out = {}
        for j, ls in enumerate(seg.pattern):
            pkey = f"pos{j}"
            c = xs[1][pkey] if has_cache else None
            out = apply_layer(
                cfg, ls, xs[0][pkey], x, positions, cache=c, mode=mode, memory=memory,
                block_table=block_table, collect_stats=collect_stats,
            )
            if collect_stats:
                x, c_new, a, st = out
                if st is not None:
                    stats_out[pkey] = st
            else:
                x, c_new, a = out
            if has_cache:
                new_caches[pkey] = c_new
            aux = aux + a
        ys = new_caches if has_cache else 0
        if collect_stats:
            ys = (ys, stats_out)
        return (x, aux), ys

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    # scan requires every xs leaf to have leading dim == repeats
    xs = (params, caches if has_cache else jnp.zeros((seg.repeats,), jnp.int8))
    (x, aux), ys = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs, unroll=_SCAN_UNROLL[0] or 1
    )
    if collect_stats:
        ys, stats = ys
        return x, (ys if has_cache else None), aux, stats
    return x, (ys if has_cache else None), aux


# Dry-run knob: setting _SCAN_UNROLL[0] = True fully unrolls segment scans so
# XLA cost_analysis counts every layer (it otherwise counts a while-loop body
# once).  Compile is slower; used by launch/dryrun.py only.
_SCAN_UNROLL = [False]


def set_scan_unroll(full: bool) -> None:
    _SCAN_UNROLL[0] = bool(full)


# Perf toggle (EXPERIMENTS.md §Perf): cast the residual-stream cotangent back
# to the activation dtype at every layer boundary — halves backward-pass
# collective and HBM traffic that JAX's f32 cotangent promotion otherwise
# doubles.  Enabled via launch/dryrun --train-opt bf16_bwd.
BF16_BWD = [False]


def set_bf16_bwd(on: bool) -> None:
    BF16_BWD[0] = bool(on)
