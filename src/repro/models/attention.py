"""GQA attention with global / sliding-window / cross variants, KV caches
(full and ring-buffer window), and query-chunked computation so 32k-prefill
fits device memory and *local* layers cost O(S·W) FLOPs rather than O(S²).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnSpec, ModelConfig
from repro.models.modules import apply_rope, dense_init, init_rmsnorm, rmsnorm, softcap
from repro.parallel.sharding import shard_hint
from repro.quant.kv import QuantizedKV, kv_quantize_values, materialize_kv
from repro.quant.qarrays import materialize

NEG_INF = -1e30

# Query-chunk size for long-sequence attention (multiple of 128 for MXU).
Q_CHUNK = 1024


def _context_parallel_size(cfg) -> int:
    """>1 when attention must be distributed over 'model' via the query
    sequence because the head count doesn't divide the TP axis."""
    from repro.parallel.sharding import get_mesh

    mesh = get_mesh()
    if mesh is None:
        return 1
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if tp > 1 and cfg.num_heads % tp != 0:
        return tp
    return 1


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, spec: AttnSpec, dtype) -> dict:
    ks = jax.random.split(key, 5)
    H, Hkv, dh, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": dense_init(ks[0], d, (H, dh), dtype),
        "wk": dense_init(ks[1], d, (Hkv, dh), dtype),
        "wv": dense_init(ks[2], d, (Hkv, dh), dtype),
        "wo": dense_init(ks[3], H * dh, d, dtype).reshape(H, dh, d),
    }
    if spec.qk_norm:
        p["q_norm"] = init_rmsnorm(dh, dtype)
        p["k_norm"] = init_rmsnorm(dh, dtype)
    return p


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------


def init_kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int, dtype, *, kv_bits: int = 0) -> dict:
    """Ring-buffer KV cache.  ``pos`` holds the absolute position stored in
    each slot (-1 = empty), which doubles as the validity/window mask source.
    A full-context cache is simply capacity == max_seq_len.

    ``kv_bits=8`` stores K/V as :class:`~repro.quant.kv.QuantizedKV` (int8
    values + f32 per-(timestep, head) scales, quantize-on-write): ~4x fewer
    cache bytes streamed per decode step, the §5 memory-bound lever after
    MoQ expert weights.  0 = full precision."""
    shape = (batch, capacity, n_kv, head_dim)
    if kv_bits == 8:
        k = QuantizedKV.zeros(shape, dtype)
        v = QuantizedKV.zeros(shape, dtype)
    elif kv_bits == 0:
        k = jnp.zeros(shape, dtype)
        v = jnp.zeros(shape, dtype)
    else:
        raise ValueError(f"kv_bits must be 0 (fp) or 8 (int8), got {kv_bits}")
    return {"k": k, "v": v, "pos": jnp.full((batch, capacity), -1, jnp.int32)}


def spec_is_paged(spec: AttnSpec) -> bool:
    """Whether a self-attention layer's cache goes into the shared page pool
    under paged serving.  Sliding-window layers keep per-slot rings — a ring
    of ``window`` tokens is already fixed-size and fragmentation-free, and
    paging it would buy nothing; paging targets the unbounded global-context
    caches whose worst-case reservation is what strands memory."""
    return not (spec.kind == "local" and spec.window > 0)


def init_paged_kv_cache(n_pages: int, page_size: int, n_kv: int, head_dim: int, dtype, *, kv_bits: int = 0) -> dict:
    """Shared page-pool KV cache: ``[n_pages + 1, page_size, n_kv, head_dim]``
    with NO batch axis — sequences own pages through per-slot block tables
    (serving/kv_pool.py) instead of reserving a contiguous capacity row.

    The extra last page is the *trash* page: never handed out by the
    allocator, its ``pos`` stays -1 forever.  Unmapped (-1) block-table
    entries are clamped to it on read (contributing nothing, masked by
    ``pos == -1``) and inactive-slot decode writes are routed into it, which
    is what lets the jitted decode step keep fully static shapes with no
    per-row masking of the pool.

    ``kv_bits=8`` stores pages as int8 :class:`~repro.quant.kv.QuantizedKV`
    — the two serving memory levers compose: ~4x fewer bytes per cache
    token × fragmentation-free packing of those tokens."""
    shape = (n_pages + 1, page_size, n_kv, head_dim)
    if kv_bits == 8:
        k = QuantizedKV.zeros(shape, dtype)
        v = QuantizedKV.zeros(shape, dtype)
    elif kv_bits == 0:
        k = jnp.zeros(shape, dtype)
        v = jnp.zeros(shape, dtype)
    else:
        raise ValueError(f"kv_bits must be 0 (fp) or 8 (int8), got {kv_bits}")
    return {"k": k, "v": v, "pos": jnp.full((n_pages + 1, page_size), -1, jnp.int32)}


def _write_kv(old, new_vals, write_fn):
    """Apply ``write_fn(buffer, values)`` to a cache tensor: directly for fp
    caches, to the (q, scale) pair for QuantizedKV (quantize-on-write — each
    token's scale is self-contained, so slot overwrites need no rescaling)."""
    if isinstance(old, QuantizedKV):
        q_new, s_new = kv_quantize_values(new_vals)
        return QuantizedKV(
            write_fn(old.q, q_new), write_fn(old.scale, s_new), old.orig_dtype
        )
    return write_fn(old, new_vals.astype(old.dtype))


def _cache_write_decode(cache: dict, k_new, v_new, index) -> dict:
    """Write one token per row at ring slot ``index % capacity``.
    index: [] int32 (uniform batch) or [B] int32 (ragged / continuous
    batching — each row at its own position)."""
    cap = cache["k"].shape[1]
    B = cache["k"].shape[0]
    if jnp.ndim(index) == 0:
        slot = jnp.mod(index, cap)
        write = lambda buf, vals: jax.lax.dynamic_update_slice_in_dim(buf, vals, slot, axis=1)
        k = _write_kv(cache["k"], k_new, write)
        v = _write_kv(cache["v"], v_new, write)
        pos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.broadcast_to(index, (B, 1)).astype(jnp.int32), slot, axis=1
        )
        return {"k": k, "v": v, "pos": pos}
    # ragged: per-row batch-indexed scatter
    rows = jnp.arange(B)
    slot = jnp.mod(index.astype(jnp.int32), cap)  # [B]
    write = lambda buf, vals: buf.at[rows, slot].set(vals[:, 0])
    k = _write_kv(cache["k"], k_new, write)
    v = _write_kv(cache["v"], v_new, write)
    pos = cache["pos"].at[rows, slot].set(index.astype(jnp.int32))
    return {"k": k, "v": v, "pos": pos}


def _cache_write_prefill(cache: dict, k, v, positions) -> dict:
    """Fill the cache from a prefill of S tokens (positions [B, S]).  If the
    cache is a window ring (capacity < S) only the last ``capacity`` tokens
    are retained, laid out so slot == pos % capacity."""
    cap = cache["k"].shape[1]
    S = k.shape[1]
    if cap >= S:
        write = lambda buf, vals: jax.lax.dynamic_update_slice_in_dim(buf, vals, 0, axis=1)
        k_ = _write_kv(cache["k"], k, write)
        v_ = _write_kv(cache["v"], v, write)
        pos_ = jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions.astype(jnp.int32), 0, axis=1)
        return {"k": k_, "v": v_, "pos": pos_}
    # keep last `cap` tokens; place token p at slot p % cap
    k_tail = k[:, S - cap :]
    v_tail = v[:, S - cap :]
    p_tail = positions[:, S - cap :].astype(jnp.int32)
    slots = jnp.mod(p_tail[0], cap)  # same for every batch row
    # `slots` is a permutation of 0..cap-1, so scattering into the existing
    # ring writes every slot — same result as rebuilding via gather, but the
    # old buffer stays live in the graph and the caller's donate_argnums can
    # alias it (a gather rebuild leaves the donated input unused: jax prunes
    # it and the donation is silently dropped for every window-ring layer)
    write = lambda buf, vals: buf.at[:, slots].set(vals)
    return {
        "k": _write_kv(cache["k"], k_tail, write),
        "v": _write_kv(cache["v"], v_tail, write),
        "pos": cache["pos"].at[:, slots].set(p_tail),
    }


# Process-wide default for decode over a quantized KV cache: None = auto
# (Pallas dequant-in-kernel on TPU, dequantize-into-_sdpa reference elsewhere
# — interpret-mode Pallas is a correctness tool, far too slow to serve from).
# "kernel" / "ref" force.  Mirrors core.moe.set_quant_expert_backend.
KV_QUANT_BACKEND = [None]


def set_kv_quant_backend(mode) -> None:
    """Test/benchmark knob; read at trace time (not part of jit cache keys),
    so switching drops all cached compilations."""
    assert mode in (None, "kernel", "ref"), mode
    if KV_QUANT_BACKEND[0] == mode:
        return
    KV_QUANT_BACKEND[0] = mode
    jax.clear_caches()


def _decode_attend_quant(q, cache: dict, row_pos, spec: AttnSpec, scale: float):
    """One-token decode over a QuantizedKV cache.  q: [B, 1, H, dh]."""
    mode = KV_QUANT_BACKEND[0]
    if mode is None:
        mode = "kernel" if jax.default_backend() == "tpu" else "ref"
    window = spec.window if spec.kind == "local" else 0
    if mode == "kernel":
        from repro.kernels.ops import fused_decode_attention_quant

        B, S, H, dh = q.shape
        Hkv = cache["k"].shape[2]
        qg = q[:, 0].reshape(B, Hkv, H // Hkv, dh)
        y = fused_decode_attention_quant(
            qg,
            cache["k"].q, cache["k"].scale, cache["v"].q, cache["v"].scale,
            cache["pos"], row_pos[:, None],
            scale=scale, causal=spec.causal, window=window,
            softcap=spec.logit_softcap,
        )
        return y.reshape(B, 1, H, dh)
    mask = _window_causal_mask(row_pos[:, None], cache["pos"], window, spec.causal)
    return _sdpa(
        q, materialize_kv(cache["k"]), materialize_kv(cache["v"]),
        mask, scale, spec.logit_softcap,
    )


# Process-wide default for decode over a *paged* KV pool: None = auto
# (Pallas block-table gather kernel on TPU, gather-into-_sdpa reference
# elsewhere).  "kernel" / "ref" force.  Mirrors set_kv_quant_backend.
PAGED_BACKEND = [None]


def set_paged_backend(mode) -> None:
    """Test/benchmark knob; read at trace time (not part of jit cache keys),
    so switching drops all cached compilations."""
    assert mode in (None, "kernel", "ref"), mode
    if PAGED_BACKEND[0] == mode:
        return
    PAGED_BACKEND[0] = mode
    jax.clear_caches()


def _paged_clamp_table(table: jax.Array, n_pages_total: int) -> jax.Array:
    """-1 (unmapped) entries -> the trash page, whose pos is pinned at -1."""
    return jnp.where(table < 0, n_pages_total - 1, table).astype(jnp.int32)


def _paged_cache_write_decode(cache: dict, k_new, v_new, row_pos, table) -> dict:
    """Write one token per row into its block-table page.  Rows whose table
    entry for ``row_pos // page_size`` is unmapped (inactive slots, whose
    table rows the scheduler resets to -1) land in the trash page with a -1
    position — self-masking, so no post-hoc merge of the pool is needed."""
    Pt, ps = cache["pos"].shape
    B = row_pos.shape[0]
    rows = jnp.arange(B)
    entry = row_pos.astype(jnp.int32) // ps
    offs = row_pos.astype(jnp.int32) % ps
    pages = _paged_clamp_table(table[rows, entry], Pt)
    write = lambda buf, vals: buf.at[pages, offs].set(vals[:, 0])
    k = _write_kv(cache["k"], k_new, write)
    v = _write_kv(cache["v"], v_new, write)
    pos_val = jnp.where(pages == Pt - 1, -1, row_pos.astype(jnp.int32))
    pos = cache["pos"].at[pages, offs].set(pos_val)
    return {"k": k, "v": v, "pos": pos}


def _paged_gather(pool, table):
    """[Pt, ps, ...] pool + [B, nt] clamped table -> [B, nt*ps, ...]."""
    g = pool[table]
    return g.reshape((table.shape[0], table.shape[1] * pool.shape[1]) + g.shape[3:])


def _paged_cache_write_chunk(cache: dict, k_new, v_new, positions, table_row) -> dict:
    """Write one prefill chunk's K/V (a single sequence, C tokens) straight
    into its block-table pages — the direct-write half of chunked prefill.
    ``positions`` [C] are consecutive, so every (page, offset) target is
    distinct; unmapped entries (never produced by a correct scheduler, which
    pre-allocates the prompt's pages at admission) clamp to the trash page
    with a -1 position.  Quantized pools quantize on write, same as decode.

    SHARED prefix pages are never written, with no extra plumbing: a chunk
    position whose destination entry already holds that exact position can
    only be a prefix page shared from another admission (fresh and recycled
    pages carry ``pos == -1``, and a chunk never revisits its own earlier
    positions), so its write is routed to the trash page.  This arises when
    an arch with non-paged sequential state (window rings, SSM/LRU) must
    recompute the shared prefix to rebuild that state — the refcount>1 page
    stays bit-identical, which tests/test_prefix.py asserts."""
    Pt, ps = cache["pos"].shape
    pos = positions.astype(jnp.int32)  # [C]
    entry = pos // ps
    offs = pos % ps
    pages = _paged_clamp_table(table_row[entry], Pt)
    already = cache["pos"][pages, offs] == pos  # shared-prefix entries
    pages = jnp.where(already, Pt - 1, pages)
    write = lambda buf, vals: buf.at[pages, offs].set(vals[0])
    k = _write_kv(cache["k"], k_new, write)
    v = _write_kv(cache["v"], v_new, write)
    pos_val = jnp.where(pages == Pt - 1, -1, pos)
    pos_arr = cache["pos"].at[pages, offs].set(pos_val)
    return {"k": k, "v": v, "pos": pos_arr}


def _paged_cache_write_chunk_batched(cache: dict, k_new, v_new, positions, tables) -> dict:
    """Multi-slot variant of ``_paged_cache_write_chunk``: every mid-prefill
    slot's chunk lands in ONE scatter.  positions: [B, C] with -1 marking
    invalid entries (rows past their chunk end, fully inactive rows); tables:
    [B, max_pages].  Invalid entries and shared-prefix re-writes (the
    ``already`` detection, same rule as the single-slot path) route to the
    trash page with a -1 position.  Distinct valid entries never collide: a
    page written this tick cannot yet be prefix-indexed, so no two slots
    target it (the scheduler only maps shared — i.e. fully-written — pages
    into more than one table row)."""
    Pt, ps = cache["pos"].shape
    B, C = positions.shape
    pos = positions.astype(jnp.int32)
    valid = pos >= 0
    entry = jnp.where(valid, pos // ps, 0)
    offs = jnp.where(valid, pos % ps, 0)
    pages = _paged_clamp_table(jnp.take_along_axis(tables, entry, axis=1), Pt)
    already = cache["pos"][pages, offs] == pos  # shared-prefix entries
    pages = jnp.where(already | ~valid, Pt - 1, pages)
    flat_p = pages.reshape(-1)
    flat_o = offs.reshape(-1)
    write = lambda buf, vals: buf.at[flat_p, flat_o].set(
        vals.reshape((B * C,) + vals.shape[2:])
    )
    k = _write_kv(cache["k"], k_new, write)
    v = _write_kv(cache["v"], v_new, write)
    # every trash-page write carries -1, so colliding invalid entries are
    # order-independent: the trash page's pos stays pinned at -1
    pos_val = jnp.where(pages == Pt - 1, -1, pos)
    pos_arr = cache["pos"].at[flat_p, flat_o].set(pos_val.reshape(-1))
    return {"k": k, "v": v, "pos": pos_arr}


def _paged_prefill_chunk_attend_batched(q, k, v, cache: dict, positions, tables, spec: AttnSpec, scale: float):
    """Multi-slot variant of ``_paged_prefill_chunk_attend``: each row's chunk
    queries attend over that row's pages ++ its own in-flight K/V.  q/k/v:
    [B, C, ...]; positions [B, C] (-1 invalid); tables [B, max_pages].  Rows
    mask their pool history at positions >= their OWN chunk start
    (``positions[:, 0]``); invalid queries see an all-masked score row —
    finite uniform softmax garbage that the caller's active-mask merge and
    last-valid-token logit gather never read."""
    mode = PAGED_BACKEND[0]
    if mode is None:
        mode = "kernel" if jax.default_backend() == "tpu" else "ref"
    window = spec.window if spec.kind == "local" else 0
    Pt = cache["pos"].shape[0]
    tbl = _paged_clamp_table(tables, Pt)  # [B, nt]
    quant = isinstance(cache["k"], QuantizedKV)
    B, C, H, dh = q.shape
    Hkv = k.shape[2]
    if mode == "kernel":
        from repro.kernels.ops import fused_prefill_attention_paged

        # statically unrolled per-row kernel launches — all inside the ONE
        # jitted batched-prefill call (a single host dispatch per tick)
        if quant:
            args = (cache["k"].q, cache["k"].scale, cache["v"].q, cache["v"].scale)
        else:
            args = (cache["k"], None, cache["v"], None)
        ys = []
        for b in range(B):
            qg = q[b].reshape(C, Hkv, H // Hkv, dh)
            ys.append(fused_prefill_attention_paged(
                qg, *args, cache["pos"], tbl[b], positions[b], k[b], v[b],
                scale=scale, causal=spec.causal, window=window,
                softcap=spec.logit_softcap,
            ))
        return jnp.stack(ys).reshape(B, C, H, dh)
    if quant:
        kh = materialize_kv(QuantizedKV(
            _paged_gather(cache["k"].q, tbl), _paged_gather(cache["k"].scale, tbl),
            cache["k"].orig_dtype,
        ))
        vh = materialize_kv(QuantizedKV(
            _paged_gather(cache["v"].q, tbl), _paged_gather(cache["v"].scale, tbl),
            cache["v"].orig_dtype,
        ))
    else:
        kh = _paged_gather(cache["k"], tbl)
        vh = _paged_gather(cache["v"], tbl)
    kcat = jnp.concatenate([kh.astype(k.dtype), k], axis=1)
    vcat = jnp.concatenate([vh.astype(v.dtype), v], axis=1)
    hist_pos = _paged_gather(cache["pos"], tbl)  # [B, nt*ps]
    start = positions[:, :1]  # per-row chunk start (-1 rows mask everything)
    hist_pos = jnp.where(hist_pos >= start, -1, hist_pos)  # pool = strictly pre-chunk
    k_pos = jnp.concatenate([hist_pos, positions], axis=1)
    mask = _window_causal_mask(positions, k_pos, window, spec.causal)
    return _sdpa(q, kcat, vcat, mask, scale, spec.logit_softcap)


def _paged_prefill_chunk_attend(q, k, v, cache: dict, positions, table_row, spec: AttnSpec, scale: float):
    """Chunk queries attend over (already-written pool pages: earlier chunks
    + shared prefix, read in place) ++ (the chunk's own in-flight fp K/V,
    causal).  q/k/v: [1, C, ...]; ``cache`` is the PRE-write pool.  Pool keys
    at positions >= the chunk start are masked out: when a shared-prefix
    admission recomputes the prefix (archs with window rings / SSM state),
    those positions are live in the shared pages AND in flight — the
    in-flight copy is the single source, counted once."""
    mode = PAGED_BACKEND[0]
    if mode is None:
        mode = "kernel" if jax.default_backend() == "tpu" else "ref"
    window = spec.window if spec.kind == "local" else 0
    Pt = cache["pos"].shape[0]
    tbl = _paged_clamp_table(table_row, Pt)
    quant = isinstance(cache["k"], QuantizedKV)
    B, C, H, dh = q.shape
    Hkv = k.shape[2]
    if mode == "kernel":
        from repro.kernels.ops import fused_prefill_attention_paged

        qg = q[0].reshape(C, Hkv, H // Hkv, dh)
        if quant:
            args = (cache["k"].q, cache["k"].scale, cache["v"].q, cache["v"].scale)
        else:
            args = (cache["k"], None, cache["v"], None)
        y = fused_prefill_attention_paged(
            qg, *args, cache["pos"], tbl, positions[0], k[0], v[0],
            scale=scale, causal=spec.causal, window=window,
            softcap=spec.logit_softcap,
        )
        return y.reshape(1, C, H, dh)
    tbl2 = tbl[None]  # [1, nt]
    if quant:
        kh = materialize_kv(QuantizedKV(
            _paged_gather(cache["k"].q, tbl2), _paged_gather(cache["k"].scale, tbl2),
            cache["k"].orig_dtype,
        ))
        vh = materialize_kv(QuantizedKV(
            _paged_gather(cache["v"].q, tbl2), _paged_gather(cache["v"].scale, tbl2),
            cache["v"].orig_dtype,
        ))
    else:
        kh = _paged_gather(cache["k"], tbl2)
        vh = _paged_gather(cache["v"], tbl2)
    kcat = jnp.concatenate([kh.astype(k.dtype), k], axis=1)
    vcat = jnp.concatenate([vh.astype(v.dtype), v], axis=1)
    hist_pos = _paged_gather(cache["pos"], tbl2)
    hist_pos = jnp.where(hist_pos >= positions[0, 0], -1, hist_pos)  # pool = strictly pre-chunk
    k_pos = jnp.concatenate([hist_pos, positions], axis=1)
    mask = _window_causal_mask(positions, k_pos, window, spec.causal)
    return _sdpa(q, kcat, vcat, mask, scale, spec.logit_softcap)


def _cache_write_chunk(cache: dict, k, v, positions) -> dict:
    """Append one prefill chunk into a contiguous/ring cache that already
    holds earlier chunks (chunked-prefill resume for per-slot window rings).
    For C <= cap the consecutive positions map to DISTINCT ring slots
    (``pos % cap``), so a scatter preserves the ring invariant slot ==
    pos % cap even when the chunk starts mid-ring; for C > cap the ring is
    rebuilt from the chunk's last ``cap`` tokens — everything older just
    fell out of the ring, and ``_cache_write_prefill``'s rebuild lays them
    out at slot == pos % cap too."""
    cap = cache["k"].shape[1]
    S = k.shape[1]
    if S > cap:
        return _cache_write_prefill(cache, k, v, positions)
    slots = jnp.mod(positions[0].astype(jnp.int32), cap)  # same for every row
    write = lambda buf, vals: buf.at[:, slots].set(vals)
    k_ = _write_kv(cache["k"], k, write)
    v_ = _write_kv(cache["v"], v, write)
    pos_ = cache["pos"].at[:, slots].set(positions.astype(jnp.int32))
    return {"k": k_, "v": v_, "pos": pos_}


def _cache_write_chunk_batched(cache: dict, k, v, positions) -> dict:
    """Multi-slot variant of ``_cache_write_chunk`` for per-slot window rings:
    positions [B, C] per row, -1 invalid.  Each row keeps only its last
    ``cap`` valid tokens (everything older just fell out of the ring) laid
    out at slot == pos % cap; invalid/older entries get slot index ``cap``,
    which is out of bounds and therefore DROPPED by the scatter (JAX's
    default OOB-scatter semantics) — the ring row is untouched by them."""
    cap = cache["k"].shape[1]
    B, C = positions.shape
    pos = positions.astype(jnp.int32)
    row_max = jnp.max(pos, axis=1, keepdims=True)
    keep = (pos >= 0) & (pos > row_max - cap)
    slots = jnp.where(keep, jnp.mod(pos, cap), cap)  # cap == OOB -> dropped
    rows = jnp.arange(B)[:, None]
    write = lambda buf, vals: buf.at[rows, slots].set(vals)
    k_ = _write_kv(cache["k"], k, write)
    v_ = _write_kv(cache["v"], v, write)
    pos_ = cache["pos"].at[rows, slots].set(pos)
    return {"k": k_, "v": v_, "pos": pos_}


def _paged_decode_attend(q, cache: dict, row_pos, table, spec: AttnSpec, scale: float):
    """One-token decode over a paged pool.  q: [B, 1, H, dh]."""
    mode = PAGED_BACKEND[0]
    if mode is None:
        mode = "kernel" if jax.default_backend() == "tpu" else "ref"
    window = spec.window if spec.kind == "local" else 0
    Pt = cache["pos"].shape[0]
    tbl = _paged_clamp_table(table, Pt)
    quant = isinstance(cache["k"], QuantizedKV)
    if mode == "kernel":
        from repro.kernels.ops import fused_decode_attention_paged

        B, S, H, dh = q.shape
        Hkv = cache["k"].shape[2]
        qg = q[:, 0].reshape(B, Hkv, H // Hkv, dh)
        if quant:
            args = (cache["k"].q, cache["k"].scale, cache["v"].q, cache["v"].scale)
        else:
            args = (cache["k"], None, cache["v"], None)
        y = fused_decode_attention_paged(
            qg, *args, cache["pos"], tbl, row_pos[:, None],
            scale=scale, causal=spec.causal, window=window,
            softcap=spec.logit_softcap,
        )
        return y.reshape(B, 1, H, dh)
    if quant:
        k = materialize_kv(QuantizedKV(
            _paged_gather(cache["k"].q, tbl), _paged_gather(cache["k"].scale, tbl),
            cache["k"].orig_dtype,
        ))
        v = materialize_kv(QuantizedKV(
            _paged_gather(cache["v"].q, tbl), _paged_gather(cache["v"].scale, tbl),
            cache["v"].orig_dtype,
        ))
    else:
        k = _paged_gather(cache["k"], tbl)
        v = _paged_gather(cache["v"], tbl)
    k_pos = _paged_gather(cache["pos"], tbl)
    mask = _window_causal_mask(row_pos[:, None], k_pos, window, spec.causal)
    return _sdpa(q, k, v, mask, scale, spec.logit_softcap)


# ---------------------------------------------------------------------------
# Core scaled-dot-product with GQA + masking
# ---------------------------------------------------------------------------


def _sdpa(q, k, v, mask, scale: float, cap: float):
    """q: [B,S,H,dh], k/v: [B,T,Hkv,dh], mask: [B,1,1,S,T] or broadcastable.
    Returns [B,S,H,dh].  Softmax in f32."""
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    logits = softcap(logits, cap)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(B, S, H, dh)


def _window_causal_mask(q_pos, k_pos, window: int, causal: bool):
    """q_pos: [B,S] or [S]; k_pos: [B,T] or [T] -> bool [B,1,1,S,T]."""
    if q_pos.ndim == 1:
        q_pos = q_pos[None]
    if k_pos.ndim == 1:
        k_pos = k_pos[None]
    q = q_pos[:, :, None]  # [B,S,1]
    k = k_pos[:, None, :]  # [B,1,T]
    m = k >= 0  # slot validity (ring caches store -1 for empty)
    if causal:
        m = m & (k <= q)
    if window > 0:
        m = m & (q - k < window)
    return m[:, None, None]  # [B,1,1,S,T]


def attend_full(q, k, v, q_pos, k_pos, spec: AttnSpec, scale: float):
    mask = _window_causal_mask(q_pos, k_pos, spec.window if spec.kind == "local" else 0, spec.causal)
    return _sdpa(q, k, v, mask, scale, spec.logit_softcap)


def attend_chunked(q, k, v, q_pos, k_pos, spec: AttnSpec, scale: float, q_chunk: int = Q_CHUNK):
    """Query-chunked attention.  For local layers each query chunk only reads
    the K/V slice [chunk_start - window, chunk_end), so HLO FLOPs are O(S·W)."""
    B, S, H, dh = q.shape
    if S <= q_chunk or S % q_chunk != 0:
        return attend_full(q, k, v, q_pos, k_pos, spec, scale)
    n_chunks = S // q_chunk
    local = spec.kind == "local" and spec.window > 0
    if local:
        # k-slice length: window rounded up to chunk multiple + chunk
        w_pad = ((spec.window + q_chunk - 1) // q_chunk) * q_chunk
        k_len = w_pad + q_chunk

    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (B, S))
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None], (B, k.shape[1]))

    def body(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * q_chunk, q_chunk, axis=1)
        if local:
            start = jnp.maximum(i * q_chunk - w_pad, 0)
            ks = jax.lax.dynamic_slice_in_dim(k, start, k_len, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, k_len, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, start, k_len, axis=1)
            # dynamic_slice clamps at the end; mask handles any overlap dupes
            # because positions beyond the causal frontier are masked anyway.
            mask = _window_causal_mask(qp, kp, spec.window, spec.causal)
        else:
            ks, vs, kp = k, v, k_pos
            mask = _window_causal_mask(qp, kp, 0, spec.causal)
        return _sdpa(qs, ks, vs, mask, scale, spec.logit_softcap)

    out = jax.lax.map(body, jnp.arange(n_chunks))  # [n, B, c, H, dh]
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, dh)


# ---------------------------------------------------------------------------
# Layer-level apply
# ---------------------------------------------------------------------------


def attention(
    cfg: ModelConfig,
    spec: AttnSpec,
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    memory: Optional[jax.Array] = None,
    memory_positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,
    mode: str = "train",
    block_table: Optional[jax.Array] = None,
):
    """Returns (y, new_cache).  mode: train | prefill | decode.

    - train:   full self-attention over x (no cache IO).
    - prefill: same as train but also fills and returns the cache.
    - decode:  x is [B, 1, d]; reads cache, writes the new token into it.
    - decode_paged: like decode_ragged, but global-context caches are shared
      page pools addressed through ``block_table`` [B, max_pages] (window
      layers keep their per-slot rings; see ``spec_is_paged``).
    - prefill_chunk: one page-aligned chunk of a resumable admission prefill
      (x is [1, C, d], positions are absolute).  Paged layers attend over
      (already-written pool pages ++ in-flight chunk K/V) and write the chunk
      STRAIGHT into its block-table pages — no temp contiguous cache; window
      rings (and any contiguous cache) resume by attending over (cache
      pre-write ++ chunk) and appending.  The cache must already hold every
      position below the chunk start (earlier chunks / shared prefix pages).
    - cross (spec.kind == 'cross'): attends to ``memory`` (no cache mutation
      for train; serving caches projected memory K/V once at prefill).
    """
    B, S, d = x.shape
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    scale = 1.0 / math.sqrt(dh)

    # materialize: dequantizes MoQ-quantized projections, passthrough otherwise
    q = jnp.einsum("bsd,dhe->bshe", x, materialize(params["wq"]))
    if spec.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.rms_eps)

    if spec.kind == "cross":
        if cache is not None and mode.startswith("decode"):
            k, v = materialize_kv(cache["k"]), materialize_kv(cache["v"])
            k_pos = cache["pos"]
        else:
            assert memory is not None
            k = jnp.einsum("btd,dhe->bthe", memory, materialize(params["wk"]))
            v = jnp.einsum("btd,dhe->bthe", memory, materialize(params["wv"]))
            if spec.qk_norm:
                k = rmsnorm(params["k_norm"], k, cfg.rms_eps)
            k_pos = (
                memory_positions
                if memory_positions is not None
                else jnp.arange(k.shape[1], dtype=jnp.int32)[None]
            )
        mask = _window_causal_mask(
            jnp.zeros((B, S), jnp.int32), jnp.broadcast_to(k_pos, (B, k.shape[1])), 0, causal=False
        )
        y = _sdpa(q, k, v, mask, scale, spec.logit_softcap)
        new_cache = (
            {"k": k, "v": v, "pos": jnp.broadcast_to(k_pos, (B, k.shape[1])).astype(jnp.int32)}
            if mode in ("prefill", "prefill_chunk", "prefill_chunk_batched")  # chunk re-writes: idempotent
            else cache
        )
        out = jnp.einsum("bshe,hed->bsd", y, materialize(params["wo"]))
        return out, new_cache

    k = jnp.einsum("bsd,dhe->bshe", x, materialize(params["wk"]))
    v = jnp.einsum("bsd,dhe->bshe", x, materialize(params["wv"]))
    if spec.qk_norm:
        k = rmsnorm(params["k_norm"], k, cfg.rms_eps)
    if spec.use_rope:
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_theta)
    q = shard_hint(q, "batch", "seq", "heads", "head_dim")
    k = shard_hint(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard_hint(v, "batch", "seq", "kv_heads", "head_dim")

    # Context-parallel fallback: heads that don't divide the TP axis would
    # leave attention replicated across 'model' ranks (16x redundant compute
    # and score traffic).  Shard the *query sequence* over 'model' instead;
    # K/V stay replicated across TP (each rank attends its S/L query slice
    # against the full keys).
    cp = _context_parallel_size(cfg)
    if cp > 1 and mode != "decode" and S % cp == 0:
        q = shard_hint(q, "batch", "q_seq", None, None)

    if mode in ("prefill_chunk", "prefill_chunk_batched"):
        assert cache is not None
        batched = mode == "prefill_chunk_batched"
        pos2d = positions if positions.ndim == 2 else positions[None]
        pos2d = jnp.broadcast_to(pos2d, (B, S)).astype(jnp.int32)
        if spec_is_paged(spec) and block_table is not None:
            # paged layer: attend over the pre-write pool + in-flight chunk,
            # then write the chunk's K/V straight into its pages
            if batched:
                # block_table is [B, max_pages] — one row per mid-prefill slot
                y = _paged_prefill_chunk_attend_batched(q, k, v, cache, pos2d, block_table, spec, scale)
                new_cache = _paged_cache_write_chunk_batched(cache, k, v, pos2d, block_table)
            else:
                table_row = block_table[0] if block_table.ndim == 2 else block_table
                y = _paged_prefill_chunk_attend(q, k, v, cache, pos2d, table_row, spec, scale)
                new_cache = _paged_cache_write_chunk(cache, k, v, pos2d[0], table_row)
        else:
            # window ring (or contiguous cache) resume: earlier chunks are in
            # the cache, the current chunk is in flight.  This attend is
            # already per-row (cache/pos/mask all carry the batch axis), so
            # the batched mode shares it — only the write-back differs
            # (-1-aware per-row scatter vs the single-row slot map).
            kcat = jnp.concatenate([materialize_kv(cache["k"]).astype(k.dtype), k], axis=1)
            vcat = jnp.concatenate([materialize_kv(cache["v"]).astype(v.dtype), v], axis=1)
            k_pos = jnp.concatenate([cache["pos"], pos2d], axis=1)
            mask = _window_causal_mask(
                pos2d, k_pos, spec.window if spec.kind == "local" else 0, spec.causal
            )
            y = _sdpa(q, kcat, vcat, mask, scale, spec.logit_softcap)
            if batched:
                new_cache = _cache_write_chunk_batched(cache, k, v, pos2d)
            else:
                new_cache = _cache_write_chunk(cache, k, v, pos2d)
        y = shard_hint(y, "batch", "seq", "heads", "head_dim")
        out = jnp.einsum("bshe,hed->bsd", y, materialize(params["wo"]))
        return out, new_cache

    if mode.startswith("decode"):
        assert cache is not None and S == 1
        # positions: [B, 1]; mode == "decode" assumes a uniform batch index
        # (dynamic-update-slice — partitions best under GSPMD);
        # "decode_ragged" supports per-row positions (continuous batching).
        row_pos = positions[:, 0] if positions.ndim == 2 else positions
        row_pos = jnp.broadcast_to(row_pos, (B,)).astype(jnp.int32)
        if mode == "decode_paged" and spec_is_paged(spec):
            assert block_table is not None, "decode_paged needs a block table"
            new_cache = _paged_cache_write_decode(cache, k, v, row_pos, block_table)
            y = _paged_decode_attend(q, new_cache, row_pos, block_table, spec, scale)
        else:
            idx = row_pos if mode in ("decode_ragged", "decode_paged") else row_pos[0]
            new_cache = _cache_write_decode(cache, k, v, idx)
            if isinstance(new_cache["k"], QuantizedKV):
                # the just-written token is read back quantized too, so decode
                # sees exactly what the Pallas kernel streams from HBM
                y = _decode_attend_quant(q, new_cache, row_pos, spec, scale)
            else:
                mask = _window_causal_mask(
                    row_pos[:, None],
                    new_cache["pos"],
                    spec.window if spec.kind == "local" else 0,
                    spec.causal,
                )
                y = _sdpa(q, new_cache["k"], new_cache["v"], mask, scale, spec.logit_softcap)
    else:
        pos2d = positions if positions.ndim == 2 else positions[None]
        pos2d = jnp.broadcast_to(pos2d, (B, S))
        if cp > 1 and S % cp == 0:
            # keep the q-seq sharding intact (query chunking would slice
            # across shard boundaries and force gathers)
            y = attend_full(q, k, v, pos2d, pos2d, spec, scale)
        else:
            y = attend_chunked(q, k, v, pos2d, pos2d, spec, scale)
        new_cache = _cache_write_prefill(cache, k, v, pos2d) if (mode == "prefill" and cache is not None) else cache

    if cp > 1 and mode != "decode" and S % cp == 0:
        y = shard_hint(y, "batch", "q_seq", None, None)
    else:
        y = shard_hint(y, "batch", "seq", "heads", "head_dim")
    out = jnp.einsum("bshe,hed->bsd", y, materialize(params["wo"]))
    return out, new_cache
