"""Primitive neural-net modules in pure JAX: params are nested dicts, every
module is an ``init_*`` / ``apply`` function pair.  No framework dependency."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.qarrays import materialize

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_shape, dtype) -> jax.Array:
    """Truncated-normal fan-in init (matches Megatron/GPT-3 recipes)."""
    shape = (in_dim,) + tuple(out_shape) if isinstance(out_shape, (tuple, list)) else (in_dim, out_shape)
    std = 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype) -> dict:
    return {"scale": jnp.zeros((dim,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def act_fn(name: str):
    return {"gelu": jax.nn.gelu, "relu": jax.nn.relu, "silu": jax.nn.silu, "swiglu": jax.nn.silu}[name]


# ---------------------------------------------------------------------------
# MLP (dense FFN): SwiGLU / GELU / ReLU
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d_model, d_ff, dtype), "wo": dense_init(ks[1], d_ff, d_model, dtype)}
    if act == "swiglu":
        p["wg"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(params: dict, x: jax.Array, act: str) -> jax.Array:
    # materialize: dequantizes MoQ-quantized weights, passthrough otherwise
    h = x @ materialize(params["wi"])
    if act == "swiglu":
        h = jax.nn.silu(x @ materialize(params["wg"])) * h
    else:
        h = act_fn(act)(h)
    return h @ materialize(params["wo"])


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # [dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    sin = jnp.sin(angles)[..., None, :]  # [..., S, 1, dh/2]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Softcap (gemma) and misc
# ---------------------------------------------------------------------------


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# bf16 backward pass (EXPERIMENTS.md §Perf): JAX's VJP promotes cotangents to
# f32 as soon as an f32 loss head is involved, and the f32 activation
# gradients then flow through every layer's collectives and HBM traffic at
# twice the bytes.  ``grad_cast`` is an identity whose backward casts the
# cotangent to the primal dtype (the standard mixed-precision recipe).
# ---------------------------------------------------------------------------


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_cast(x: jax.Array, dtype_str: str) -> jax.Array:
    return x


def _grad_cast_fwd(x, dtype_str):
    return x, None


def _grad_cast_bwd(dtype_str, _res, g):
    return (g.astype(dtype_str),)


_grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


def grad_cast(x: jax.Array) -> jax.Array:
    return _grad_cast(x, str(x.dtype))
