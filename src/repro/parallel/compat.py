"""Version-compat shims over JAX APIs that moved/renamed across releases.

The repo targets current JAX but must run on older installs (0.4.x):

  * ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)`` —
    explicit axis types don't exist before 0.5; :func:`make_mesh` forwards
    ``axis_types`` only when the installed ``jax.make_mesh`` accepts it
    (every mesh in this repo uses Auto axes, which is the old default).
  * ``jax.shard_map`` — top-level export (with ``check_vma=``) is new;
    older installs have ``jax.experimental.shard_map.shard_map`` with the
    same semantics under ``check_rep=``.

Everything mesh/shard_map-shaped in the repo (and the subprocess scripts in
``tests/test_dist.py``) goes through these two helpers so a JAX upgrade or
downgrade is a no-op for callers.
"""
from __future__ import annotations

import inspect
from typing import Optional, Sequence

import jax

# Guarded: jax.make_mesh itself only appeared in 0.4.35 — importing this
# module (e.g. for axis_size/shard_map alone) must not crash on installs
# without it.
_MAKE_MESH_TAKES_AXIS_TYPES = (
    hasattr(jax, "make_mesh")
    and "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def default_axis_types(n: int):
    """``(AxisType.Auto,) * n`` when the installed JAX has explicit axis
    types, else None (old JAX: every mesh axis is implicitly Auto)."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return None
    return (at.Auto,) * n


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Optional[tuple] = None,
    devices=None,
):
    """``jax.make_mesh`` that tolerates installs without ``axis_types``.

    ``axis_types=None`` means "Auto for every axis" — passed explicitly on
    new JAX, omitted on old JAX where Auto is the only behavior.
    """
    shapes = tuple(axis_shapes)
    if not hasattr(jax, "make_mesh"):
        import numpy as np

        devs = devices if devices is not None else jax.devices()
        n = int(np.prod(shapes))
        return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shapes), tuple(axis_names))
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _MAKE_MESH_TAKES_AXIS_TYPES:
        if axis_types is None:
            axis_types = default_axis_types(len(shapes))
        kw["axis_types"] = axis_types
    return jax.make_mesh(shapes, tuple(axis_names), **kw)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (new) / psum-of-ones (old) inside shard_map or
    any other named-axis context."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    import numpy as np

    # analysis: allow(host-cast) — compat shim; psum-of-ones is concrete in the eager named-axis contexts old jax exposes
    return int(np.prod(jax.lax.psum(1, axis_name)))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new JAX; the ``jax.experimental`` one (with
    ``check_vma`` mapped onto its older ``check_rep`` spelling) otherwise."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )
