"""Communication schedules for expert parallelism (DeepSpeed-MoE §5.3).

Three all-to-all schedules over the expert dimension of a [E, C, D] dispatch
buffer (E = total experts, C = per-source capacity):

  * ``flat_all_to_all``        — one a2a over the full EP axis group
                                 (the torch.distributed baseline shape:
                                 O(p) hops at small message sizes).
  * ``coordinated``            — (in core/moe_parallel.py) a2a over the
                                 16-wide 'data' axis only; tensor-parallel
                                 ranks replicate, so group size is p/L.
  * ``hierarchical_all_to_all``— the paper's two-step intra-node/inter-node
                                 factoring: a2a over the fast inner axis
                                 (ICI within a pod), a data-layout transform,
                                 then a2a over the slow outer axis (DCI
                                 across pods).  2× communication volume but
                                 O(G + p/G) serialized hops instead of O(p),
                                 a win in the latency-bound decode regime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.compat import axis_size


def flat_all_to_all(x: jax.Array, axis_names) -> jax.Array:
    """x: [E, C, D] with E == prod(axis sizes) * E_loc.
    Returns [E_loc, P*C, D]."""
    return jax.lax.all_to_all(x, axis_names, split_axis=0, concat_axis=1, tiled=True)


def flat_all_to_all_back(x: jax.Array, axis_names) -> jax.Array:
    return jax.lax.all_to_all(x, axis_names, split_axis=1, concat_axis=0, tiled=True)


def hierarchical_all_to_all(x: jax.Array, inner_axis: str, outer_axis: str) -> jax.Array:
    """Two-stage a2a (paper Fig. 8).  x: [E, C, D],
    E = Go * Gi * E_loc laid out with the *outer* axis major.
    Returns [E_loc, Go*Gi*C, D] — same result as flat_all_to_all over
    (outer, inner), via intra-inner exchange + layout transform + inter-outer
    exchange."""
    Go = axis_size(outer_axis)
    Gi = axis_size(inner_axis)
    E, C, D = x.shape
    E_loc = E // (Go * Gi)

    # [Go', Gi', E_loc, C, D]: destination-indexed blocks
    xv = x.reshape(Go, Gi, E_loc, C, D)
    # Stage 1: exchange within the inner (fast, intra-pod) axis on the Gi' dim.
    # After this, member i of each inner group holds the blocks destined for
    # inner-rank i of *every* outer group, from all its inner peers.
    s1 = jax.lax.all_to_all(xv, inner_axis, split_axis=1, concat_axis=3, tiled=True)
    # s1: [Go', 1, E_loc, Gi_src*C, D] -> squeeze
    s1 = s1.reshape(Go, E_loc, Gi * C, D)
    # Data-layout transformation between the two steps (paper's explicit
    # transform): nothing to permute here because the reshape above already
    # groups by destination outer rank; the transform cost shows up as the
    # reshape/copy in HLO.
    # Stage 2: exchange across the outer (slow, inter-pod) axis.
    s2 = jax.lax.all_to_all(s1, outer_axis, split_axis=0, concat_axis=2, tiled=True)
    # s2: [1, E_loc, Go_src*Gi_src*C, D]
    return s2.reshape(E_loc, Go * Gi * C, D)


def hierarchical_all_to_all_back(y: jax.Array, inner_axis: str, outer_axis: str) -> jax.Array:
    """Inverse of hierarchical_all_to_all: [E_loc, Go*Gi*C, D] -> [E, C, D]."""
    Go = axis_size(outer_axis)
    Gi = axis_size(inner_axis)
    E_loc, PC, D = y.shape
    C = PC // (Go * Gi)
    yv = y.reshape(1, E_loc, Go, Gi * C, D)
    s1 = jax.lax.all_to_all(yv, outer_axis, split_axis=2, concat_axis=0, tiled=True)
    # s1: [Go, E_loc, 1, Gi*C, D]
    s1 = s1.reshape(Go, E_loc, Gi, C, D)
    s2 = jax.lax.all_to_all(s1, inner_axis, split_axis=2, concat_axis=1, tiled=True)
    # s2: [Go, Gi*E_loc? ...] -> [Go, Gi, E_loc, C, D]
    s2 = s2.reshape(Go, Gi, E_loc, C, D)
    return s2.reshape(Go * Gi * E_loc, C, D)
