"""Parameter PartitionSpec assignment (rule-based, path-driven).

Every model parameter gets a spec according to DESIGN.md §4.  Rules respect
divisibility (glm4's 2 KV heads or llama4's 40 Q heads cannot shard over a
16-wide 'model' axis); when the preferred logical axis does not divide, a
fallback axis is tried (e.g. llama4 shards head_dim instead of heads), else
the dim is replicated.  Leaves under ``segments/`` carry a leading stacked
scan ('layers') dim which is never sharded.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import get_rules


def _axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _pick(mesh, rules, options: Sequence[str], dim: int, taken: set) -> Optional[object]:
    sizes = _axis_sizes(mesh)
    for name in options:
        axes = rules.get(name)
        if axes is None:
            continue
        if isinstance(axes, str):
            axes = (axes,)
        picked = []
        prod = 1
        ok = True
        for a in axes:
            if a in taken or a not in sizes:
                ok = False
                break
            prod *= sizes[a]
            picked.append(a)
        if not ok or prod == 1:
            continue
        if dim % prod == 0:
            for a in picked:
                taken.add(a)
            return tuple(picked) if len(picked) > 1 else picked[0]
    return None


def _leaf_spec(mesh, rules, parent: str, name: str, shape: Tuple[int, ...], stacked: bool) -> P:
    """dim_options: per-dim tuple of logical-axis names to try in order."""
    core = shape[1:] if stacked else shape
    nd = len(core)

    def opts() -> list:
        if parent in ("attn", "cross"):
            if name == "wq":
                return [("embed",), ("heads",), ("head_dim",)] if nd == 3 else [()] * nd
            if name in ("wk", "wv"):
                return [("embed",), ("kv_heads",), ("head_dim",)]
            if name == "wo":
                return [("heads",), ("head_dim",), ("embed",)]
        if parent in ("ffn", "residual"):
            if name in ("wi", "wg"):
                return [("embed",), ("mlp",)]
            if name == "wo":
                return [("mlp",), ("embed",)]
        if parent == "moe":
            if name == "router":
                return [("embed",), ()]
            if name in ("wi", "wg"):
                return [("expert",), ("embed",), ("expert_mlp", "mlp")]
            if name == "wo":
                return [("expert",), ("expert_mlp", "mlp"), ("embed",)]
        if name == "embed":
            return [("vocab",), ("embed",)]
        if name == "unembed":
            return [("embed",), ("vocab",)]
        # ssm / lru mixer params, norms, scalars: replicated
        return [()] * nd

    dim_options = opts()
    if len(dim_options) != nd:
        dim_options = [()] * nd
    taken: set = set()
    core_spec = []
    # attention fallback: if 'heads' can't shard, try 'head_dim' on that dim
    fallback = {"heads": ("head_dim",), "kv_heads": ("head_dim",)}
    for d, options in zip(core, dim_options):
        names = list(options)
        for o in options:
            names.extend(fallback.get(o, ()))
        # 'embed' is replicated by default rules; including it is harmless
        core_spec.append(_pick(mesh, rules, names, d, taken))
    if stacked:
        return P(None, *core_spec)
    return P(*core_spec)


def _extend_for_train(spec: P, shape: Tuple[int, ...], mesh, stacked: bool = False) -> P:
    """ZeRO-3/FSDP extension: additionally shard parameters (and optimizer
    moments) over the 'data' (and 'pod') axes on the first divisible free
    dim.  The paper trains with ZeRO [23]; under GSPMD + scan-over-layers the
    per-layer all-gather this induces is naturally scheduled layer-by-layer.
    Serving keeps params replicated over 'data' instead — that is the paper's
    aggregate-memory-bandwidth inference layout."""
    sizes = _axis_sizes(mesh)
    used = set()
    for s in spec:
        if s is None:
            continue
        for a in s if isinstance(s, tuple) else (s,):
            used.add(a)
    out = list(spec)
    for extra in ("data", "pod"):
        if extra in used or extra not in sizes or sizes[extra] == 1:
            continue
        for i, (s, d) in enumerate(zip(out, shape)):
            if stacked and i == 0:
                continue  # never shard the scan (layers) dim
            cur = 1
            if s is not None:
                for a in s if isinstance(s, tuple) else (s,):
                    cur *= sizes[a]
            if d % (cur * sizes[extra]) == 0 and d // cur >= sizes[extra]:
                if s is None:
                    out[i] = extra
                else:
                    out[i] = tuple(s if isinstance(s, tuple) else (s,)) + (extra,)
                used.add(extra)
                break
    return P(*out)


def param_pspecs(mesh, tree, *, mode: str = "serve") -> object:
    """Build a pytree of PartitionSpec matching ``tree`` (params or shapes).

    mode='serve': DESIGN.md §4 layout (TP over 'model', EP+slicing for
    experts, non-expert params replicated over 'data' for aggregate
    bandwidth).  mode='train': same + ZeRO-3-style extension over
    'data'/'pod' so model+optimizer state scales with the full chip count."""
    rules = get_rules()
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        parents = set(keys[:-1])
        if "attn" in parents:
            parent = "attn"
        elif "cross" in parents:
            parent = "cross"
        elif "moe" in parents and "residual" not in parents:
            parent = "moe"
        elif "residual" in parents:
            parent = "residual"
        elif "ffn" in parents:
            parent = "ffn"
        elif "ssm" in parents or "lru" in parents:
            parent = "mixer"
        else:
            parent = ""
        stacked = "segments" in parents
        shape = tuple(leaf.shape)
        spec = _leaf_spec(mesh, rules, parent, name, shape, stacked)
        if mode == "train" and len(shape) >= 2:
            spec = _extend_for_train(spec, shape, mesh, stacked)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspec(mesh, ndim: int, *, batch_divisible: bool = True) -> P:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    lead = tuple(axes) if (axes and batch_divisible) else None
    if isinstance(lead, tuple) and len(lead) == 1:
        lead = lead[0]
    return P(lead, *([None] * (ndim - 1)))


def cache_pspecs(mesh, tree, batch: int) -> object:
    """KV/state caches: batch over (pod,data) when divisible; kv heads over
    'model' when divisible (dim 2 of k/v); everything else replicated."""
    rules = get_rules()
    sizes = _axis_sizes(mesh)
    dp = 1
    for a in ("pod", "data"):
        dp *= sizes.get(a, 1)
    batch_ok = batch % dp == 0

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        shape = tuple(leaf.shape)
        stacked = "seg0" in "".join(keys) or any(k.startswith("seg") for k in keys)
        # layout: [layers?, B, ...]
        off = 1 if any(k.startswith("pos") and k[3:].isdigit() for k in keys) else 0
        spec = [None] * len(shape)
        bdim = 1 if off else 0
        if batch_ok and len(shape) > bdim and shape[bdim] == batch:
            axes = tuple(a for a in ("pod", "data") if a in sizes)
            spec[bdim] = axes if len(axes) > 1 else axes[0]
        if name in ("k", "v") and len(shape) >= bdim + 4:
            kvh = shape[bdim + 2]
            seq = shape[bdim + 1]
            if "model" in sizes and sizes["model"] > 1 and kvh % sizes["model"] == 0:
                spec[bdim + 2] = "model"
            elif "model" in sizes and sizes["model"] > 1 and seq % sizes["model"] == 0:
                # GQA archs with few KV heads (llama4 kv=8 < model=16): shard
                # the cache *sequence* dim instead — GSPMD partitions the
                # attention softmax reduction (flash-decode-style).
                spec[bdim + 1] = "model"
        specs.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, specs)
