"""Sharding rules: logical-axis → mesh-axis mapping and helpers.

The production mesh is ``(pod=2,) data=16, model=16`` (launch/mesh.py).  The
paper-faithful DS-MoE scheme (DESIGN.md §4):

  * batch                 -> ('pod', 'data')
  * attention heads, d_ff -> 'model'            (Megatron tensor-slicing)
  * expert dim E          -> 'data'             (expert parallelism, EP=16)
  * expert d_ff           -> 'model'            (paper's *expert-slicing*)
  * vocab                 -> 'model'
  * everything else       -> replicated

GQA kv-heads and odd dims (glm4 kv=2, internvl2 H=14) are sharded only when
divisible by the mesh axis — ``maybe_shard`` implements that rule.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _axis_sizes() -> dict:
    mesh = get_mesh()
    if mesh is None:
        return {}
    return dict(zip(mesh.axis_names, mesh.devices.shape))


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh for ``shard_hint``/``spec`` helpers (and as the jax
    ambient mesh for shard_map)."""
    prev = getattr(_state, "mesh", None)
    prev_rules = getattr(_state, "rules", None)
    _state.mesh = mesh
    _state.rules = rules or DEFAULT_RULES
    try:
        yield mesh
    finally:
        _state.mesh = prev
        _state.rules = prev_rules


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def get_rules() -> dict:
    return getattr(_state, "rules", None) or DEFAULT_RULES


# Logical axis names used throughout model code.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "data",
    "expert_mlp": "model",  # expert-slicing (paper §5.2)
    # context-parallel fallback: when an arch's head count doesn't divide the
    # 'model' axis (llama4: 40 heads, internvl2: 14), attention would run
    # fully replicated across TP ranks; sharding the query sequence instead
    # distributes it (EXPERIMENTS.md §Perf, llama4-prefill iteration).
    "q_seq": "model",
    "state": None,
    "layers": None,  # stacked-scan leading axis
}

# Alternative rule-sets used by perf experiments (see EXPERIMENTS.md §Perf).
RULESETS = {
    "default": DEFAULT_RULES,
    # Naive baseline: experts spread over *all* chips (flat EP=256) — the
    # paper's "PyTorch baseline" analogue where the a2a spans p devices.
    "flat_ep": {**DEFAULT_RULES, "expert": ("data", "model"), "expert_mlp": None},
    # Cross-pod expert parallelism with the paper's hierarchical a2a (Fig. 8):
    # experts over (pod, data) = EP 32, intra-pod + inter-pod two-stage a2a.
    "ep_pod": {**DEFAULT_RULES, "expert": ("pod", "data")},
    # Sequence-parallel long decode: KV cache sequence dim over 'data'.
    "seqpar_kv": {**DEFAULT_RULES, "kv_seq": "data"},
}


def _filter_axes(mesh_axes, dim_size: int, taken: set):
    """Return mesh axes (possibly a sub-tuple) that evenly divide dim_size."""
    if mesh_axes is None:
        return None
    sizes = _axis_sizes()
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    picked = []
    prod = 1
    for ax in mesh_axes:
        if ax in taken or ax not in sizes:
            continue
        if dim_size % (prod * sizes[ax]) == 0:
            picked.append(ax)
            prod *= sizes[ax]
    if not picked:
        return None
    return tuple(picked) if len(picked) > 1 else picked[0]


def spec(*logical_axes, shape=None) -> P:
    """Build a PartitionSpec from logical axis names, respecting divisibility
    when ``shape`` is given."""
    rules = get_rules()
    out = []
    taken: set = set()
    for i, name in enumerate(logical_axes):
        axes = rules.get(name) if name is not None else None
        if shape is not None:
            axes = _filter_axes(axes, shape[i], taken)
        if axes is not None:
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                taken.add(a)
        out.append(axes)
    return P(*out)


def shard_hint(x: jax.Array, *logical_axes) -> jax.Array:
    """``with_sharding_constraint`` if a mesh is active, identity otherwise."""
    mesh = get_mesh()
    if mesh is None:
        return x
    s = spec(*logical_axes, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))


def named_sharding(*logical_axes, shape=None) -> Optional[NamedSharding]:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(*logical_axes, shape=shape))
