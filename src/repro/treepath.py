"""Pytree key-path stringification shared by the checkpoint manifest and the
quantization policy matcher (one definition so manifest keys and policy paths
can never diverge for the same tree)."""
from __future__ import annotations

from typing import List


def path_entry(p) -> str:
    """Stable string for one key-path entry: DictKey -> key, SequenceKey ->
    idx, GetAttrKey (e.g. QuantizedArray's .q/.scale children) -> name."""
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def path_names(path) -> List[str]:
    return [path_entry(p) for p in path]
