"""Retrace watchdog: per-tick jit compile accounting for the serving engines.

Continuous batching is only viable under XLA because the decode tick is a
fixed-shape jitted call that compiles ONCE — any shape (or static-arg) drift
silently turns a ~ms tick into a ~s compile.  The fused-tick ROADMAP item
asks for exactly this instrument: compile-count before/after across a
scheduler run, and a warning the moment a *steady-state* tick recompiles.

Implementation: every jitted callable JAX returns carries a per-function
trace-cache whose size ``_cache_size()`` reports (jax 0.4.x and newer; the
accessor is probed defensively so an API change degrades to "watchdog
inactive", never an engine failure).  The watchdog samples the sizes of all
registered functions each tick and reports the delta as that tick's compile
count.  Warmup compiles (first decode, each distinct prefill chunk length)
are expected; after ``steady_after`` consecutive zero-compile ticks the
engine is declared steady, and any later compile fires ``warn_fn`` once per
offending tick and increments ``steady_retraces``.
"""
from __future__ import annotations

import warnings
from typing import Callable, Dict, Optional


def jit_cache_size(fn) -> Optional[int]:
    """Trace-cache entry count of a jitted callable, or None when the
    running jax does not expose one (watchdog degrades to inactive)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        n = probe() if callable(probe) else probe
    except Exception:
        return None
    return int(n) if isinstance(n, int) else None


class RetraceWatchdog:
    """Tracks compile-count deltas across engine ticks.

    Usage: ``register`` each jitted function at engine construction, call
    ``tick()`` once per scheduler step — it returns the number of fresh
    compilations since the previous call and maintains the steady-state
    accounting."""

    def __init__(self, steady_after: int = 3,
                 warn_fn: Callable[[str], None] = None):
        self.steady_after = steady_after
        self.warn_fn = warn_fn if warn_fn is not None else (
            lambda msg: warnings.warn(msg, RuntimeWarning, stacklevel=3))
        self._fns: Dict[str, object] = {}
        self._aux: set = set()  # names exempt from steady-state warnings
        self._last: Dict[str, int] = {}
        self.total_compiles = 0  # lifetime compiles seen across all fns
        self.steady_retraces = 0  # compiles AFTER steady state was reached
        self._zero_streak = 0
        self.steady = False
        self.active = True  # False if no registered fn exposes a cache size

    def register(self, name: str, fn, aux: bool = False) -> None:
        """``aux=True`` marks a function whose compiles COUNT but never fire
        the steady-state warning: admission prefills compile once per novel
        chunk/prompt length and page-reset/copy helpers compile on their
        first use, which can legitimately happen long after the decode step
        went steady.  Only non-aux functions (the fixed-shape decode tick)
        carry the never-retrace-after-warmup contract."""
        if fn is None:
            return
        self._fns[name] = fn
        if aux:
            self._aux.add(name)
        size = jit_cache_size(fn)
        self._last[name] = 0 if size is None else size

    def _sizes(self) -> Dict[str, int]:
        out = {}
        for name, fn in self._fns.items():
            size = jit_cache_size(fn)
            if size is not None:
                out[name] = size
        return out

    def tick(self) -> int:
        """Compiles since the last tick (0 when inactive)."""
        sizes = self._sizes()
        if not sizes and self._fns:
            self.active = False
            return 0
        fresh = 0
        primary_fresh = 0
        culprits = []
        for name, size in sizes.items():
            prev = self._last.get(name, 0)
            d = size - prev
            if d > 0:
                fresh += d
                # a primary fn's FIRST-ever compile is warmup no matter how
                # late it lands (e.g. every slot spends the early ticks in
                # chunked prefill, so decode first compiles after the
                # zero-compile streak already declared the engine steady);
                # the contract is about RE-tracing, prev > 0
                if name not in self._aux and prev > 0:
                    primary_fresh += d
                    culprits.append(f"{name}(+{d})")
            self._last[name] = size
        self.total_compiles += fresh
        if primary_fresh == 0:
            self._zero_streak += 1
            if self._zero_streak >= self.steady_after:
                self.steady = True
        else:
            self._zero_streak = 0
            if self.steady:
                self.steady_retraces += primary_fresh
                self.warn_fn(
                    "steady-state engine tick recompiled: "
                    + ", ".join(culprits)
                    + " — a fixed-shape decode tick should never retrace "
                    "(shape or static-arg drift?)"
                )
        return fresh

    def registry(self) -> Dict[str, bool]:
        """name -> is-primary for every registered jitted function.

        This is the single source of truth for "which functions carry the
        steady-state never-retrace contract": the static contract checker
        (``repro.analysis.contracts``) reads the same classification the
        runtime watchdog enforces, so the two halves of the instrument can
        never disagree about which function must be a singleton."""
        return {name: name not in self._aux for name in self._fns}

    def snapshot(self) -> dict:
        return {
            "active": self.active,
            "total_compiles": self.total_compiles,
            "steady": self.steady,
            "steady_retraces": self.steady_retraces,
            "per_fn": dict(self._last),
        }
