"""Observability layer: metrics (counters / gauges / log-bucket histograms),
request-lifecycle tracing (Chrome/Perfetto trace_event JSON), and a retrace
watchdog over the engines' jitted functions.  Dependency-free; see
docs/OBSERVABILITY.md for the metric catalog and span taxonomy.

``Obs`` is the bundle the engines, the trainer, and launch/serve.py accept:

    obs = Obs(trace=True, routing=True)      # everything on
    eng = ContinuousEngine(cfg, params, obs=obs, ...)
    ...
    print(obs.metrics.render())
    obs.tracer.export("trace.json")          # load in ui.perfetto.dev

Engines construct a default ``Obs()`` when none is injected: metrics stay on
(they are the source of per-tick telemetry and cost ~µs/tick), the tracer is
disabled (no-op fast path), and per-tick routing-stats collection is off
(it changes the decode step's jitted signature, so it is an explicit
opt-in).  ``Obs.disabled()`` turns the metrics off too — the benchmark
baseline for the overhead guard."""
from __future__ import annotations

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.retrace import RetraceWatchdog, jit_cache_size
from repro.obs.trace import Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "RetraceWatchdog", "jit_cache_size", "Tracer", "Obs",
]


class Obs:
    """Bundle of the three instruments plus collection knobs.

    ``routing=True`` makes the engines' decode step (and the trainer's step
    when asked) return jit-computed per-layer ``RoutingStats`` — per-expert
    token counts, dropped-token fraction, gate entropy, f·P imbalance —
    aggregated host-side each tick/step (paper §3/§5: expert load balance is
    THE MoE-specific signal)."""

    def __init__(self, metrics: MetricsRegistry = None, tracer: Tracer = None,
                 watchdog: RetraceWatchdog = None, routing: bool = False,
                 trace: bool = False):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=trace)
        self.watchdog = watchdog if watchdog is not None else RetraceWatchdog()
        self.routing = routing

    @classmethod
    def disabled(cls) -> "Obs":
        """Everything off — registry included.  Benchmark baseline."""
        return cls(metrics=MetricsRegistry(enabled=False),
                   tracer=Tracer(enabled=False),
                   watchdog=_InertWatchdog(), routing=False)


class _InertWatchdog(RetraceWatchdog):
    """Watchdog that never samples (Obs.disabled baseline)."""

    def register(self, name, fn, aux=False):  # noqa: D102
        pass

    def tick(self) -> int:  # noqa: D102
        return 0
