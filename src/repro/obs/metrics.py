"""Counters, gauges, and fixed-bucket histograms for serving/training
telemetry ("Who Says Elephants Can't Run": production MoE serving stands or
falls on what you can measure — latency percentiles, expert load, cost per
token).

Design constraints, in order:

  * **dependency-free and allocation-light** — a ``Histogram.observe`` is a
    ``bisect`` into precomputed bucket bounds plus three float updates, so
    per-token SLO accounting (TTFT, TPOT, queue-wait) costs microseconds and
    never stores samples;
  * **percentiles without sample storage** — buckets are log-spaced, so
    p50/p95/p99 come from cumulative-count bucket interpolation.  The error
    is bounded by the bucket's log width (``(hi/lo)^(1/n)`` per bucket,
    ~±4% at the defaults), which tests/test_obs.py pins down;
  * **one source of truth** — everything the CLI prints and everything
    ``--metrics-out`` writes comes from the same ``snapshot()`` dict, so the
    two can never disagree (the failure mode of the old ad-hoc prints in
    launch/serve.py).
"""
from __future__ import annotations

import json
import math
import time
from bisect import bisect_right
from typing import Dict, List, Optional


class Counter:
    """Monotonic counter.  ``inc`` accepts any non-negative increment."""

    __slots__ = ("name", "unit", "value")

    def __init__(self, name: str, unit: str = ""):
        self.name = name
        self.unit = unit
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "unit", "value")

    def __init__(self, name: str, unit: str = ""):
        self.name = name
        self.unit = unit
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = None

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed log-spaced-bucket histogram: percentiles via bucket
    interpolation, no sample storage.

    Buckets: ``n_buckets`` geometric intervals spanning ``[lo, hi)`` plus an
    underflow bucket (``< lo``, includes zero/negative) and an overflow
    bucket (``>= hi``).  A percentile inside ``[lo, hi)`` is log-linearly
    interpolated within its bucket, so the worst-case relative error is one
    bucket's geometric width; underflow resolves to ``min_seen..lo`` and
    overflow to ``hi..max_seen`` (linear), keeping estimates finite and
    inside the observed range.
    """

    __slots__ = ("name", "unit", "lo", "hi", "bounds", "counts", "count",
                 "total", "min_seen", "max_seen")

    def __init__(self, name: str, unit: str = "s", lo: float = 1e-6,
                 hi: float = 100.0, n_buckets: int = 64):
        assert lo > 0 and hi > lo and n_buckets >= 1
        self.name = name
        self.unit = unit
        self.lo = lo
        self.hi = hi
        ratio = (hi / lo) ** (1.0 / n_buckets)
        # bounds[i] = upper edge of bucket i (i in 0..n_buckets-1 regular);
        # index layout: [underflow] + n_buckets regular + [overflow]
        self.bounds: List[float] = [lo * ratio ** (i + 1) for i in range(n_buckets)]
        self.counts: List[int] = [0] * (n_buckets + 2)
        self.count = 0
        self.total = 0.0
        self.min_seen = math.inf
        self.max_seen = -math.inf

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min_seen:
            self.min_seen = v
        if v > self.max_seen:
            self.max_seen = v
        if v < self.lo:
            self.counts[0] += 1
        elif v >= self.hi:
            self.counts[-1] += 1
        else:
            self.counts[1 + bisect_right(self.bounds, v)] += 1

    def reset(self) -> None:
        self.counts = [0] * len(self.counts)
        self.count = 0
        self.total = 0.0
        self.min_seen = math.inf
        self.max_seen = -math.inf

    # -- percentile estimation ------------------------------------------
    def _bucket_edges(self, idx: int):
        """(lower, upper) value edges of bucket ``idx`` in counts[] space."""
        if idx == 0:  # underflow: min_seen .. lo
            return min(self.min_seen, self.lo), self.lo
        if idx == len(self.counts) - 1:  # overflow: hi .. max_seen
            return self.hi, max(self.max_seen, self.hi)
        lower = self.lo if idx == 1 else self.bounds[idx - 2]
        return lower, self.bounds[idx - 1]

    def percentile(self, q: float) -> float:
        """Estimate the ``q``-quantile (``q`` in [0, 1]) by cumulative-count
        bucket interpolation.  Returns nan when empty."""
        if self.count == 0:
            return math.nan
        if self.count == 1:
            return self.min_seen
        target = q * self.count
        acc = 0
        for idx, c in enumerate(self.counts):
            if c == 0:
                continue
            if acc + c >= target:
                frac = (target - acc) / c
                frac = min(max(frac, 0.0), 1.0)
                lower, upper = self._bucket_edges(idx)
                if idx in (0, len(self.counts) - 1) or lower <= 0:
                    est = lower + (upper - lower) * frac  # linear at the tails
                else:
                    est = lower * (upper / lower) ** frac  # log-linear inside
                # clamp into the observed range — interpolation must never
                # manufacture values outside [min_seen, max_seen]
                return min(max(est, self.min_seen), self.max_seen)
            acc += c
        return self.max_seen

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def snapshot(self) -> dict:
        if self.count == 0:
            return {"count": 0, "unit": self.unit}
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean,
            "min": self.min_seen,
            "max": self.max_seen,
            "p50": self.percentile(0.50),
            "p90": self.percentile(0.90),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
            "unit": self.unit,
        }


class MetricsRegistry:
    """Get-or-create registry of named metrics with one ``snapshot()``.

    ``enabled=False`` turns every get-or-create into a shared no-op metric
    (observes/incs go nowhere) — the benchmark baseline for the <1%-overhead
    guard on the serving tick."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str, unit: str = "") -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = Counter(name, unit)
            if self.enabled:
                self._counters[name] = c
        return c

    def gauge(self, name: str, unit: str = "") -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = Gauge(name, unit)
            if self.enabled:
                self._gauges[name] = g
        return g

    def histogram(self, name: str, unit: str = "s", lo: float = 1e-6,
                  hi: float = 100.0, n_buckets: int = 64) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = Histogram(name, unit, lo, hi, n_buckets)
            if self.enabled:
                self._histograms[name] = h
        return h

    def reset_all(self) -> None:
        """Zero every registered metric IN PLACE (callers hold direct
        references to the metric objects, so replacing them would silently
        disconnect the telemetry source).  Used to drop warmup/compile
        samples before a measured run."""
        for group in (self._counters, self._gauges, self._histograms):
            for m in group.values():
                m.reset()

    # -- export ---------------------------------------------------------
    def snapshot(self) -> dict:
        """{"counters": {name: value}, "gauges": {...}, "histograms":
        {name: {count, sum, min, max, p50, p90, p95, p99, unit}}}."""
        return {
            "counters": {n: c.snapshot() for n, c in sorted(self._counters.items())},
            "gauges": {n: g.snapshot() for n, g in sorted(self._gauges.items())
                       if g.value is not None},
            "histograms": {n: h.snapshot() for n, h in sorted(self._histograms.items())},
        }

    def write_jsonl(self, path: str, extra: Optional[dict] = None) -> None:
        """Append one JSON line: {"ts": unix_s, **extra, **snapshot()}."""
        row = {"ts": time.time()}
        if extra:
            row.update(extra)
        row.update(self.snapshot())
        with open(path, "a") as f:
            f.write(json.dumps(row) + "\n")

    def render(self, prefix: str = "") -> str:
        """Human-readable render of the SAME snapshot the JSON export writes
        (counters one block, gauges one block, histograms one line each with
        count/mean/p50/p95/p99)."""
        snap = self.snapshot()
        lines: List[str] = []
        if snap["counters"]:
            pairs = [f"{n}={v:g}" if isinstance(v, float) else f"{n}={v}"
                     for n, v in snap["counters"].items()]
            lines.append(prefix + "counters: " + " ".join(pairs))
        if snap["gauges"]:
            lines.append(prefix + "gauges:   " + " ".join(
                f"{n}={v:.4g}" for n, v in snap["gauges"].items()))
        for n, h in snap["histograms"].items():
            if not h["count"]:
                continue
            u = h["unit"]
            lines.append(
                prefix + f"{n}: n={h['count']} mean={h['mean']:.4g}{u} "
                f"p50={h['p50']:.4g}{u} p95={h['p95']:.4g}{u} "
                f"p99={h['p99']:.4g}{u} max={h['max']:.4g}{u}"
            )
        return "\n".join(lines)
