"""Request-lifecycle tracer: spans and instants exported as Chrome/Perfetto
``trace_event`` JSON.

The serving scheduler emits one *track* per decode slot (what physical
resource was doing when), one per request (queued → admitted → prefill
chunk(s) → decode → complete, plus preemption / CoW / prefix-hit instants),
and one for the engine itself (tick spans, retrace warnings).  Tracks map
onto Chrome's process/thread model: a track *group* ("slot", "request",
"engine") becomes a pid, the id within the group becomes a tid, and metadata
events name both so Perfetto renders labeled swimlanes.

Open ``chrome://tracing`` or https://ui.perfetto.dev and load the exported
file (``Tracer.export`` / ``serve.py --trace-out``).

Overhead contract: when ``enabled=False`` every method returns after a
single attribute test — engines additionally hoist the check by holding
``tracer if tracer.enabled else None`` — so tracing compiled into the
serving hot path costs <1% of tick latency when off (asserted by the
benchmarks ``obs`` section).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

Track = Tuple[str, int]  # (group, id) -> (pid, tid)


class Tracer:
    """Span/event recorder.  All methods no-op when ``enabled=False``.

    Spans on one track must nest (Chrome's B/E model is a per-thread stack);
    ``end`` closes the innermost open span.  ``ts`` values are seconds from
    an arbitrary epoch shared with ``time.perf_counter`` so callers can pass
    timestamps they already took for SLO accounting."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._events: List[dict] = []
        self._open: Dict[Track, List[dict]] = {}  # per-track span stacks
        self._groups: Dict[str, int] = {}  # group name -> pid
        self._named: set = set()  # (pid, tid) already carrying metadata
        self._t0 = time.perf_counter()

    # -- internals ------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter()

    def _us(self, ts: Optional[float]) -> float:
        return ((self._now() if ts is None else ts) - self._t0) * 1e6

    def _ids(self, track: Track) -> Tuple[int, int]:
        group, tid = track
        pid = self._groups.get(group)
        if pid is None:
            pid = len(self._groups) + 1
            self._groups[group] = pid
            self._events.append({"ph": "M", "pid": pid, "tid": 0,
                                 "name": "process_name",
                                 "args": {"name": group}})
        if (pid, tid) not in self._named:
            self._named.add((pid, tid))
            self._events.append({"ph": "M", "pid": pid, "tid": tid,
                                 "name": "thread_name",
                                 "args": {"name": f"{group} {tid}"}})
        return pid, tid

    # -- spans ----------------------------------------------------------
    def begin(self, track: Track, name: str, ts: Optional[float] = None,
              args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        pid, tid = self._ids(track)
        ev = {"ph": "B", "pid": pid, "tid": tid, "ts": self._us(ts),
              "name": name, "cat": track[0]}
        if args:
            ev["args"] = args
        self._events.append(ev)
        self._open.setdefault(track, []).append(ev)

    def end(self, track: Track, ts: Optional[float] = None,
            args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        stack = self._open.get(track)
        if not stack:
            return  # tolerate stray ends — export stays well-formed
        b = stack.pop()
        pid, tid = self._ids(track)
        ev = {"ph": "E", "pid": pid, "tid": tid,
              "ts": max(self._us(ts), b["ts"]), "name": b["name"],
              "cat": track[0]}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def span(self, track: Track, name: str, args: Optional[dict] = None):
        """``with tracer.span(("engine", 0), "tick"): ...``"""
        return _Span(self, track, name, args)

    def instant(self, track: Track, name: str, ts: Optional[float] = None,
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        pid, tid = self._ids(track)
        ev = {"ph": "i", "pid": pid, "tid": tid, "ts": self._us(ts),
              "name": name, "s": "t", "cat": track[0]}
        if args:
            ev["args"] = args
        self._events.append(ev)

    # -- export ---------------------------------------------------------
    @property
    def n_events(self) -> int:
        return len(self._events)

    def trace_events(self, close_open: bool = True) -> List[dict]:
        """The event list, with any still-open spans closed at "now" so the
        JSON is always loadable mid-run."""
        out = list(self._events)
        if close_open:
            now = self._us(None)
            for track, stack in self._open.items():
                pid, tid = self._groups[track[0]], track[1]
                for b in reversed(stack):
                    out.append({"ph": "E", "pid": pid, "tid": tid,
                                "ts": now, "name": b["name"], "cat": track[0]})
        return out

    def export(self, path: str) -> int:
        """Write Chrome trace JSON; returns the number of events written."""
        evs = self.trace_events()
        with open(path, "w") as f:
            json.dump({"traceEvents": evs, "displayTimeUnit": "ms"}, f)
        return len(evs)


class _Span:
    __slots__ = ("_tr", "_track", "_name", "_args")

    def __init__(self, tr: Tracer, track: Track, name: str, args):
        self._tr = tr
        self._track = track
        self._name = name
        self._args = args

    def __enter__(self):
        self._tr.begin(self._track, self._name, args=self._args)
        return self

    def __exit__(self, *exc):
        self._tr.end(self._track)
        return False
