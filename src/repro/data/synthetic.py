"""Synthetic-but-learnable LM data: a fixed random first-order Markov chain
over the vocabulary with Zipfian marginals.  Deterministic given seed;
entropy is well below uniform, so models visibly learn (loss drops toward
the chain's conditional entropy) — enough to reproduce the paper's
MoE-beats-dense-at-equal-FLOPs *convergence* comparison qualitatively
without shipping a corpus."""
from __future__ import annotations

import numpy as np


class MarkovLM:
    def __init__(self, vocab_size: int, seed: int = 0, branching: int = 8, skew: float = 1.2):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.branching = branching
        # each state transitions to `branching` successors with Zipf weights
        self.succ = rng.integers(0, vocab_size, size=(vocab_size, branching))
        w = 1.0 / np.arange(1, branching + 1) ** skew
        self.w = w / w.sum()

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len), np.int32)
        state = rng.integers(0, self.vocab, size=batch)
        for t in range(seq_len):
            out[:, t] = state
            choice = rng.choice(self.branching, size=batch, p=self.w)
            state = self.succ[state, choice]
        return out

    def conditional_entropy(self) -> float:
        """Entropy (nats) of the next-token distribution (loss floor)."""
        # ignores successor collisions; close enough for reporting
        return float(-(self.w * np.log(self.w)).sum())


def batches(vocab_size: int, batch: int, seq_len: int, *, seed: int = 0, start_step: int = 0):
    """Infinite deterministic stream of (tokens, labels) numpy batches."""
    lm = MarkovLM(vocab_size, seed)
    step = start_step
    while True:
        rng = np.random.default_rng((seed + 1) * 1_000_003 + step)
        toks = lm.sample(rng, batch, seq_len + 1)
        yield toks[:, :-1], toks[:, 1:]
        step += 1
