"""Data pipeline: host-side batch production + device placement with the
global-batch sharding (batch over ('pod','data')).  Single-process here, but
written against ``jax.make_array_from_callback`` so a multi-host launcher
feeds per-host shards identically."""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import batches
from repro.parallel.sharding import get_mesh, named_sharding


def device_put_batch(tokens: np.ndarray, labels: np.ndarray):
    mesh = get_mesh()
    if mesh is None:
        return jnp.asarray(tokens), jnp.asarray(labels)
    sh = named_sharding("batch", "seq", shape=tokens.shape)
    mk = lambda arr: jax.make_array_from_callback(arr.shape, sh, lambda idx: arr[idx])
    return mk(tokens), mk(labels)


def data_stream(
    vocab_size: int,
    global_batch: int,
    seq_len: int,
    *,
    seed: int = 0,
    start_step: int = 0,
) -> Iterator[Tuple[jax.Array, jax.Array]]:
    for toks, labels in batches(vocab_size, global_batch, seq_len, seed=seed, start_step=start_step):
        yield device_put_batch(toks, labels)
