"""Flash-attention Pallas kernel (TPU target, validated in interpret mode).

This is the kernel that justifies the roofline accounting's score-tensor
exclusion (EXPERIMENTS.md §Roofline): the [Bq, Bk] logit/softmax tiles live
entirely in VMEM scratch; HBM sees only Q/K/V streaming (K/V re-read once
per query block — exactly what the analyzer counts via dot operands) and a
single O write.

Grid (batch·heads, q-blocks, k-blocks), k innermost (sequential on TPU) so
the online-softmax running max / normalizer / accumulator carry across k
tiles in VMEM scratch; the output tile is written once on the last k step.
Block shapes default to 128/256 — MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [Bq, dh]
    k = k_ref[0].astype(jnp.float32)  # [Bk, dh]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [Bq, Bk]

    if causal:
        iq = pl.program_id(1)
        bq, bk = q.shape[0], k.shape[0]
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])  # [Bq, Bk]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None])[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "interpret", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,  # [BH, S, dh]
    k: jax.Array,  # [BH, T, dh]
    v: jax.Array,  # [BH, T, dh]
    *,
    scale: float,
    causal: bool = True,
    interpret: bool = True,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
) -> jax.Array:
    BH, S, dh = q.shape
    T = k.shape[1]
    def _fit(block, dim):
        b = min(block, dim)
        while dim % b:
            b //= 2
        return max(b, 1)

    bq = _fit(block_q, S)
    bk = _fit(block_k, T)
    nq, nk = S // bq, T // bk

    kern = functools.partial(_flash_kernel, scale=scale, causal=causal, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),  # running max
            pltpu.VMEM((bq,), jnp.float32),  # running normalizer
            pltpu.VMEM((bq, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q, k, v, *, scale: float, causal: bool = True):
    """Pure-jnp oracle."""
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        S, T = s.shape[-2:]
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p.astype(v.dtype), v).astype(q.dtype)
