"""Dropless grouped expert-MLP Pallas kernel (MegaBlocks-style ragged walk).

The capacity kernels (``expert_mlp.py`` / ``expert_mlp_quant.py``) iterate a
dense ``[E, C, D]`` buffer — every expert pays for ``C = expert_capacity``
rows whether routed or not.  Here the dispatch layer
(``core/dispatch_grouped.py``) has already *sorted* the tokens by expert into
one flat ``[Ct, D]`` buffer of tile-padded per-expert groups, so the grid
walks token tiles, not (expert, capacity-slot) pairs:

  grid (t, f): token tile ``t`` belongs entirely to expert ``te[t]`` — the
  scalar-prefetched tile->expert map indexes the weight BlockSpecs directly,
  so each tile streams exactly its own expert's ``[D, BF]`` / ``[BF, D]``
  weight slices from HBM.  SwiGLU + down-projection accumulate across the
  innermost ``f`` axis in VMEM, same as the capacity kernel.

Ragged group boundaries therefore cost *zero* control flow in the kernel:
the raggedness lives in ``te`` (data) and in the zero rows padding each
group to the tile — at most ``tile - 1`` wasted rows per expert, versus
``C - count_e`` per expert for the capacity path.

Quantized variants dequantize int8 tiles in VMEM (per-output-channel f32
scales ride in ``[1, BF]`` / ``[1, D]`` blocks), and int4 additionally
unpacks two nibbles per stored byte along the contraction axis in-register —
the grouped path is where int4 weights first get a true dequant-in-kernel
execution (the capacity kernel int4 path is einsum-ref only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.expert_mlp import BLOCK_F
from repro.quant.qarrays import QuantizedArray

# ---------------------------------------------------------------------------
# fp kernel
# ---------------------------------------------------------------------------


def _grouped_mlp_kernel(te_ref, x_ref, wi_ref, wg_ref, wo_ref, o_ref):
    del te_ref  # consumed by the index maps
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [BT, D] — one token tile, all rows share expert te[t]
    h = jnp.dot(x, wi_ref[0], preferred_element_type=jnp.float32)  # [BT, BF]
    g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    act = (jax.nn.silu(g) * h).astype(x.dtype)
    o_ref[...] += jnp.dot(act, wo_ref[0], preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_f"))
def grouped_mlp_kernel(
    xg: jax.Array,  # [Ct, D] — tile-padded, expert-sorted token buffer
    te: jax.Array,  # [Ct / BT] int32 — tile -> expert id (scalar-prefetched)
    wi: jax.Array,  # [E, D, F]
    wg: jax.Array,  # [E, D, F]
    wo: jax.Array,  # [E, F, D]
    *,
    interpret: bool = True,
    block_f: int = BLOCK_F,
) -> jax.Array:
    Ct, D = xg.shape
    nt = te.shape[0]
    F = wi.shape[-1]
    bt = Ct // nt  # token tile == the dispatch layout's tile
    bf = min(block_f, F)
    assert Ct % nt == 0 and F % bf == 0, (Ct, nt, F, bf)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, F // bf),
        in_specs=[
            pl.BlockSpec((bt, D), lambda t, f, te: (t, 0)),
            pl.BlockSpec((1, D, bf), lambda t, f, te: (te[t], 0, f)),
            pl.BlockSpec((1, D, bf), lambda t, f, te: (te[t], 0, f)),
            pl.BlockSpec((1, bf, D), lambda t, f, te: (te[t], f, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda t, f, te: (t, 0)),
    )
    out = pl.pallas_call(
        _grouped_mlp_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Ct, D), jnp.float32),
        interpret=interpret,
    )(te, xg, wi, wg, wo)
    return out.astype(xg.dtype)


# ---------------------------------------------------------------------------
# quantized kernel (int8 / int4, dequant in VMEM)
# ---------------------------------------------------------------------------


def _widen(tile: jax.Array, bits: int) -> jax.Array:
    """int8 tile -> f32; int4 tile additionally unpacks 2 nibbles/byte along
    axis 0 (the contraction axis — qarrays packs along ``reduce_axes[0]``),
    matching ``qarrays._unpack_int4`` bit-for-bit."""
    if bits == 8:
        return tile.astype(jnp.float32)
    qm = tile.astype(jnp.int32) & 0xFF
    lo = qm & 0xF
    hi = (qm >> 4) & 0xF
    lo = lo - 16 * (lo > 7)
    hi = hi - 16 * (hi > 7)
    n, m = tile.shape
    return jnp.stack([lo, hi], axis=1).reshape(n * 2, m).astype(jnp.float32)


def _grouped_mlp_quant_kernel(
    te_ref, x_ref, wi_ref, wis_ref, wg_ref, wgs_ref, wo_ref, wos_ref, o_ref, *, bits
):
    del te_ref
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [BT, D]
    wi = _widen(wi_ref[0], bits) * wis_ref[0]  # [D, BF] * [1, BF]
    wg = _widen(wg_ref[0], bits) * wgs_ref[0]
    h = jnp.dot(x, wi.astype(x.dtype), preferred_element_type=jnp.float32)
    g = jnp.dot(x, wg.astype(x.dtype), preferred_element_type=jnp.float32)
    act = (jax.nn.silu(g) * h).astype(x.dtype)
    wo = _widen(wo_ref[0], bits) * wos_ref[0]  # [BF, D] * [1, D]
    o_ref[...] += jnp.dot(act, wo.astype(x.dtype), preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bits", "interpret", "block_f"))
def grouped_mlp_quant_kernel(
    xg: jax.Array,  # [Ct, D]
    te: jax.Array,  # [Ct / BT] int32
    wi_q: jax.Array,  # [E, D(/2), F] int8 (contraction axis packed when int4)
    wi_s: jax.Array,  # [E, 1, F] f32
    wg_q: jax.Array,
    wg_s: jax.Array,
    wo_q: jax.Array,  # [E, F(/2), D] int8
    wo_s: jax.Array,  # [E, 1, D] f32
    *,
    bits: int,
    interpret: bool = True,
    block_f: int = BLOCK_F,
) -> jax.Array:
    Ct, D = xg.shape
    nt = te.shape[0]
    F = wi_q.shape[-1]
    bt = Ct // nt
    bf = min(block_f, F)
    assert Ct % nt == 0 and F % bf == 0, (Ct, nt, F, bf)
    pack = 2 if bits == 4 else 1
    assert D % pack == 0 and bf % pack == 0, (D, bf, pack)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, F // bf),
        in_specs=[
            pl.BlockSpec((bt, D), lambda t, f, te: (t, 0)),
            pl.BlockSpec((1, D // pack, bf), lambda t, f, te: (te[t], 0, f)),
            pl.BlockSpec((1, 1, bf), lambda t, f, te: (te[t], 0, f)),
            pl.BlockSpec((1, D // pack, bf), lambda t, f, te: (te[t], 0, f)),
            pl.BlockSpec((1, 1, bf), lambda t, f, te: (te[t], 0, f)),
            # wo is packed along F: block index f over packed rows of size
            # bf/pack covers exactly the unpacked slice [f*bf, (f+1)*bf)
            pl.BlockSpec((1, bf // pack, D), lambda t, f, te: (te[t], f, 0)),
            pl.BlockSpec((1, 1, D), lambda t, f, te: (te[t], 0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda t, f, te: (t, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_grouped_mlp_quant_kernel, bits=bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Ct, D), jnp.float32),
        interpret=interpret,
    )(te, xg, wi_q, wi_s, wg_q, wg_s, wo_q, wo_s)
    return out.astype(xg.dtype)


def _check_grouped_quant_compat(wi, wg, wo, *, block_f: int = BLOCK_F) -> bool:
    """Kernel path: SwiGLU QuantizedArray triples with per-output-channel
    scales (group_size == 0) at 8 or 4 bits.  Unlike the capacity kernel,
    int4 IS supported (nibble unpack in VMEM); group-wise scales still take
    the dequant-ref path.  Token-tile divisibility is guaranteed by the
    dispatch layout (Ct is a tile multiple by construction); only the f
    axis needs checking, plus even tiles for nibble packing."""
    qs = (wi, wg, wo)
    if wg is None or not all(isinstance(q, QuantizedArray) for q in qs):
        return False
    if not all(q.bits in (8, 4) and q.group_size == 0 for q in qs):
        return False
    bits = wi.bits
    if any(q.bits != bits for q in qs):
        return False
    F = wi.shape[-1]
    D = wo.shape[-1]
    bf = min(block_f, F)
    if F % bf:
        return False
    pack = 2 if bits == 4 else 1
    return D % pack == 0 and bf % pack == 0


def grouped_mlp_quant(
    xg: jax.Array,
    te: jax.Array,
    wi: QuantizedArray,
    wg: QuantizedArray,
    wo: QuantizedArray,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Kernel entry from QuantizedArray leaves (int8/int4 per-channel)."""
    if not _check_grouped_quant_compat(wi, wg, wo):
        raise ValueError(
            "grouped_mlp_quant kernel needs int8/int4 per-output-channel "
            "QuantizedArrays (group_size=0) and a block-divisible d_ff; got "
            f"bits={getattr(wi, 'bits', None)}, "
            f"group_size={getattr(wi, 'group_size', None)}, F={wi.shape[-1]}"
        )
    return grouped_mlp_quant_kernel(
        xg, te, wi.q, wi.scale, wg.q, wg.scale, wo.q, wo.scale,
        bits=wi.bits, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# references (pure-jnp oracles + CPU execution path)
# ---------------------------------------------------------------------------


def grouped_mlp_ref(
    xg: jax.Array,  # [Ct, D]
    te: jax.Array,  # [Ct / tile] int32
    wi: jax.Array,
    wg: jax.Array | None,
    wo: jax.Array,
    act: str = "swiglu",
) -> jax.Array:
    """Gather-einsum oracle: gather each tile's expert weights, batched GEMM
    over tiles.  Supports all acts (the Pallas kernel is SwiGLU-only, like
    the capacity kernels)."""
    Ct, D = xg.shape
    nt = te.shape[0]
    xt = xg.reshape(nt, Ct // nt, D)
    h = jnp.einsum("tcd,tdf->tcf", xt, wi[te], preferred_element_type=jnp.float32)
    if act == "swiglu":
        g = jnp.einsum("tcd,tdf->tcf", xt, wg[te], preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    y = jnp.einsum("tcf,tfd->tcd", h.astype(xg.dtype), wo[te],
                   preferred_element_type=jnp.float32)
    return y.reshape(Ct, D).astype(xg.dtype)


def grouped_mlp_quant_ref(
    xg: jax.Array,
    te: jax.Array,
    wi: QuantizedArray,
    wg: QuantizedArray | None,
    wo: QuantizedArray,
    act: str = "swiglu",
) -> jax.Array:
    """Dequantize whole weights into the fp oracle (correctness reference for
    the quant kernel, and the default CPU execution path in core/moe.py)."""
    return grouped_mlp_ref(
        xg, te, wi.dequantize(), wg.dequantize() if wg is not None else None,
        wo.dequantize(), act,
    )
