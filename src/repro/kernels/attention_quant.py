"""Decode attention over an int8-quantized KV cache (TPU Pallas, validated
in interpret mode) — the kernel half of the quantized-KV serving path.

Decode is memory-bandwidth bound (DeepSpeed-MoE §5): each step streams the
whole K/V history from HBM to score one query token.  Here the cache lives
in HBM as int8 values + f32 per-(timestep, head) scales (quant/kv.py), and
each K/V tile is widened and rescaled *in VMEM* right before its dot — HBM
only ever carries 1-byte cache entries, which is the ~4x decode-traffic
reduction that buys batch-size headroom at long context.

Grid: (batch, kv-head, k-tiles); the k-tile axis is innermost (sequential on
TPU) so the online-softmax running max / normalizer / accumulator live in
VMEM scratch across tiles, flash-attention style.  GQA is handled by loading
the G = H/H_kv query rows of a kv-head as one [G, dh] tile.  Masking
(ring-slot validity, causality, sliding window) is computed in-kernel from
the cache's absolute-position array, so ring-buffer caches work unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_T = 128
NEG_INF = -1e30


def _soft_cap(s, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(s / cap) * cap
    return s


def _decode_quant_kernel(
    q_ref, kq_ref, ks_ref, vq_ref, vs_ref, kpos_ref, qpos_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *, scale, causal, window, softcap, nk, bt,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G, dh = q_ref.shape[-2], q_ref.shape[-1]
    q = q_ref[...].reshape(G, dh).astype(jnp.float32)  # [G, dh]
    # Dequantize the K tile in VMEM: int8 values * per-(timestep, head) scale.
    k = kq_ref[...].reshape(bt, dh).astype(jnp.float32) * ks_ref[...].reshape(bt, 1)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, bt]
    s = _soft_cap(s, softcap)

    kp = kpos_ref[...].reshape(1, bt)  # absolute positions, -1 = empty slot
    qp = qpos_ref[0, 0]
    valid = kp >= 0
    if causal:
        valid = valid & (kp <= qp)
    if window > 0:
        valid = valid & (qp - kp < window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])  # [G, bt]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    v = vq_ref[...].reshape(bt, dh).astype(jnp.float32) * vs_ref[...].reshape(bt, 1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def _fit(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b //= 2
    return max(b, 1)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "interpret", "block_t"),
)
def decode_attention_quant(
    q: jax.Array,      # [B, Hkv, G, dh] — one decode token, grouped per kv-head
    kq: jax.Array,     # [B, T, Hkv, dh] int8
    ks: jax.Array,     # [B, T, Hkv, 1]  f32
    vq: jax.Array,     # [B, T, Hkv, dh] int8
    vs: jax.Array,     # [B, T, Hkv, 1]  f32
    kpos: jax.Array,   # [B, T] int32 — absolute position per slot, -1 empty
    qpos: jax.Array,   # [B, 1] int32 — the query token's absolute position
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = True,
    block_t: int = BLOCK_T,
) -> jax.Array:
    """Returns [B, Hkv, G, dh] attention output in q.dtype."""
    B, Hkv, G, dh = q.shape
    T = kq.shape[1]
    bt = _fit(block_t, T)
    nk = T // bt

    kern = functools.partial(
        _decode_quant_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap, nk=nk, bt=bt,
    )
    return pl.pallas_call(
        kern,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, bt, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt, 1, 1), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt, 1, 1), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt), lambda b, h, t: (b, t)),
            pl.BlockSpec((1, 1), lambda b, h, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),      # running max
            pltpu.VMEM((G,), jnp.float32),      # running normalizer
            pltpu.VMEM((G, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, kq, ks, vq, vs, kpos, qpos)


def decode_attention_quant_ref(
    q, kq, ks, vq, vs, kpos, qpos, *, scale, causal=True, window=0, softcap=0.0
):
    """Pure-jnp oracle: dequantize the whole cache, masked f32 softmax."""
    B, Hkv, G, dh = q.shape
    k = kq.astype(jnp.float32) * ks  # [B, T, Hkv, dh]
    v = vq.astype(jnp.float32) * vs
    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32), k) * scale
    s = _soft_cap(s, softcap)
    kp = kpos[:, None, None, :]  # [B, 1, 1, T]
    qp = qpos[:, :, None, None].astype(jnp.int32)  # [B, 1, 1, 1]
    valid = kp >= 0
    if causal:
        valid = valid & (kp <= qp)
    if window > 0:
        valid = valid & (qp - kp < window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v)
    return out.astype(q.dtype)
