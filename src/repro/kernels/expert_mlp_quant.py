"""Grouped expert-MLP Pallas kernel over int8 weights, dequantized *in VMEM*
(MoQ serving path — DeepSpeed-MoE §4 compression meeting the §5.4 kernels).

Same grid/BlockSpec structure as ``kernels/expert_mlp.py``: per grid step
(e, c, f) a [BC, D] token tile of expert e meets int8 tiles of that expert's
up/gate/down projections plus their per-output-channel f32 scales.  Each
weight tile is widened and rescaled right before its MXU dot, so HBM only
ever holds (and the grid only ever streams) 1-byte weights — the bytes/step
reduction that sets decode latency in the paper's memory-bound inference
analysis.  Scales ride in tiny [1, BF] / [1, D] blocks alongside each tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.expert_mlp import BLOCK_C, BLOCK_F
from repro.quant.qarrays import QuantizedArray


def _expert_mlp_quant_kernel(x_ref, wi_ref, wis_ref, wg_ref, wgs_ref, wo_ref, wos_ref, o_ref):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]  # [BC, D]
    # Dequantize int8 tiles in VMEM right before the MXU dots: widen to f32,
    # broadcast the per-output-channel scale across the contraction dim.
    wi = wi_ref[0].astype(jnp.float32) * wis_ref[0]  # [D, BF] * [1, BF]
    wg = wg_ref[0].astype(jnp.float32) * wgs_ref[0]
    h = jnp.dot(x, wi.astype(x.dtype), preferred_element_type=jnp.float32)  # [BC, BF]
    g = jnp.dot(x, wg.astype(x.dtype), preferred_element_type=jnp.float32)
    act = (jax.nn.silu(g) * h).astype(x.dtype)
    wo = wo_ref[0].astype(jnp.float32) * wos_ref[0]  # [BF, D] * [1, D]
    o_ref[...] += jnp.dot(act, wo.astype(x.dtype), preferred_element_type=jnp.float32)[None].astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret", "block_c", "block_f"))
def expert_mlp_quant_kernel(
    xe: jax.Array,  # [E, C, D]
    wi_q: jax.Array,  # [E, D, F] int8
    wi_s: jax.Array,  # [E, 1, F] f32
    wg_q: jax.Array,  # [E, D, F] int8
    wg_s: jax.Array,  # [E, 1, F] f32
    wo_q: jax.Array,  # [E, F, D] int8
    wo_s: jax.Array,  # [E, 1, D] f32
    *,
    interpret: bool = True,
    block_c: int = BLOCK_C,
    block_f: int = BLOCK_F,
) -> jax.Array:
    E, C, D = xe.shape
    F = wi_q.shape[-1]
    bc = min(block_c, C)
    bf = min(block_f, F)
    assert C % bc == 0 and F % bf == 0, (C, bc, F, bf)
    grid = (E, C // bc, F // bf)

    out = pl.pallas_call(
        _expert_mlp_quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, D), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, D, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, 1, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, D, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, 1, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, bf, D), lambda e, c, f: (e, f, 0)),
            pl.BlockSpec((1, 1, D), lambda e, c, f: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, D), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), jnp.float32),
        interpret=interpret,
    )(xe, wi_q, wi_s, wg_q, wg_s, wo_q, wo_s)
    return out.astype(xe.dtype)


def _check_kernel_compat(xe, wi, wg, wo, *, block_c: int = BLOCK_C, block_f: int = BLOCK_F) -> bool:
    """Kernel path handles the plain int8 per-output-channel layout only
    (int4 / group-wise take the einsum reference path), and only shapes the
    grid tiles divide: capacity C and d_ff F must be multiples of the block
    sizes once they exceed them (expert_capacity pads to 8, not 128)."""
    qs = (wi, wg, wo)
    if wg is None or not all(isinstance(q, QuantizedArray) for q in qs):
        return False
    if not all(q.bits == 8 and q.group_size == 0 for q in qs):
        return False
    C = xe.shape[1]
    F = wi.shape[-1]
    return C % min(block_c, C) == 0 and F % min(block_f, F) == 0


def expert_mlp_quant(
    xe: jax.Array,
    wi: QuantizedArray,
    wg: QuantizedArray,
    wo: QuantizedArray,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Kernel entry from QuantizedArray leaves (int8 per-channel layout)."""
    if not _check_kernel_compat(xe, wi, wg, wo):
        raise ValueError(
            "expert_mlp_quant kernel needs int8 per-output-channel QuantizedArrays "
            "and block-divisible shapes (C mult of 128, F mult of 256 once larger); "
            f"got C={xe.shape[1]}, F={wi.shape[-1]}"
        )
    return expert_mlp_quant_kernel(
        xe, wi.q, wi.scale, wg.q, wg.scale, wo.q, wo.scale, interpret=interpret
    )


def expert_mlp_quant_ref(
    xe: jax.Array, wi: QuantizedArray, wg: QuantizedArray, wo: QuantizedArray
) -> jax.Array:
    """Einsum reference path: dequantize whole weights into the fp oracle
    ``kernels/ref.py::expert_mlp_ref`` (correctness reference for the kernel,
    and the default CPU execution path in core/moe.py)."""
    from repro.kernels.ref import expert_mlp_ref

    return expert_mlp_ref(xe, wi.dequantize(), wg.dequantize(), wo.dequantize())
