"""Chunked prefill attention over a paged KV pool (TPU Pallas, validated in
interpret mode): a page-aligned *chunk* of prompt queries attends causally to

  1. every page the sequence has already written — earlier prefill chunks
     plus any prefix pages SHARED from other sequences — gathered through the
     scalar-prefetched block table, exactly like the decode kernel
     (kernels/attention_paged.py), and
  2. the chunk's own in-flight K/V, still full precision, with the causal
     mask applied inside the chunk.

This is the kernel that removes the temp-contiguous-then-scatter admission
path: the scheduler maps the prompt's pages up front, each chunk's K/V is
written straight into its destination pages after this kernel reads the
*pre-write* pool, and a prefix-sharing admission starts its first chunk at
``shared_len`` — the shared pages are read in place, never recomputed, so
sharing saves the prefill FLOPs as well as the pages.

Composes with the int8 KV cache the same way decode does: quantized pages
are widened and rescaled by their per-(timestep, head) f32 scales in VMEM
right before the dot.  The chunk's own K/V arrives unquantized (it has not
been written yet), so intra-chunk attention is always full precision.

Grid is (kv-head, table entry + 1): the page axis is innermost (sequential
on TPU) with the online-softmax running max / normalizer / accumulator in
VMEM scratch, flash-attention style; the extra final step processes the
in-flight chunk tile.  Unlike decode, a prefill chunk routinely sees *fully
masked* tiles before any valid key (the pool is empty on the first chunk of
an unshared admission), so the probability tile is explicitly zeroed where
masked — ``exp(NEG_INF - NEG_INF) == 1`` would otherwise pollute the
normalizer while the running max is still at its initial value.

Invariants the wrapper relies on (enforced by tests/test_chunked.py):

  * ``table`` is pre-clamped (-1 -> trash page, whose ``pos`` is pinned -1);
  * pages of not-yet-written positions carry ``pos == -1`` (freshly
    allocated or recycled via ``paged_reset_pages``), so causal masking
    falls out of the pool's position array with no extra bookkeeping;
  * pool keys at positions >= the chunk start are masked in-kernel: they can
    only be shared-prefix pages being *recomputed* (archs whose window-ring
    or SSM/LRU per-slot state forces the prefix compute) — those positions
    are in flight in the chunk tile, and each key is counted exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _soft_cap(s, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(s / cap) * cap
    return s


def _prefill_kernel(table_ref, *refs, scale, causal, window, softcap, nt, ps, quantized):
    """Grid (Hkv, nt + 1); steps 0..nt-1 stream pool pages via the prefetched
    table, step nt processes the chunk's in-flight K/V and finalizes."""
    if quantized:
        (q_ref, qpos_ref, kq_ref, ks_ref, vq_ref, vs_ref, kpos_ref,
         ck_ref, cv_ref, o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, qpos_ref, kq_ref, vq_ref, kpos_ref,
         ck_ref, cv_ref, o_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    C, G, dh = q_ref.shape[0], q_ref.shape[-2], q_ref.shape[-1]
    q = q_ref[...].reshape(C * G, dh).astype(jnp.float32)
    # per-query positions, expanded over the G grouped heads (c-major rows)
    qp = jnp.broadcast_to(
        qpos_ref[...].reshape(C, 1, 1), (C, G, 1)
    ).reshape(C * G, 1).astype(jnp.int32)

    def update(k, v, kp):
        """Online-softmax update with one key tile.  k/v: [T, dh] f32;
        kp: [1, T] absolute positions (-1 = empty)."""
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [CG, T]
        s = _soft_cap(s, softcap)
        valid = kp >= 0
        if causal:
            valid = valid & (kp <= qp)
        if window > 0:
            valid = valid & (qp - kp < window)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        # Zero masked entries explicitly: while no valid key has been seen
        # the running max is still NEG_INF and exp(NEG_INF - NEG_INF) == 1
        # would count every masked key into the normalizer.
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(it < nt)
    def _page_tile():
        k = kq_ref[...].reshape(ps, dh).astype(jnp.float32)
        v = vq_ref[...].reshape(ps, dh).astype(jnp.float32)
        if quantized:
            k = k * ks_ref[...].reshape(ps, 1)  # dequantize the page in VMEM
            v = v * vs_ref[...].reshape(ps, 1)
        # pool history is STRICTLY pre-chunk: when a shared-prefix admission
        # recomputes the prefix (rebuilding window-ring/SSM state), those
        # positions are live in shared pages AND in flight — mask the pool
        # copy so each key is counted exactly once
        kp = kpos_ref[...].reshape(1, ps)
        kp = jnp.where(kp >= qpos_ref[0, 0], -1, kp)
        update(k, v, kp)

    @pl.when(it == nt)
    def _chunk_tile_and_finalize():
        k = ck_ref[...].reshape(C, dh).astype(jnp.float32)
        v = cv_ref[...].reshape(C, dh).astype(jnp.float32)
        # the chunk's keys sit at the query positions themselves
        update(k, v, qpos_ref[...].reshape(1, C).astype(jnp.int32))
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "interpret"),
)
def paged_prefill_attention(
    q: jax.Array,      # [C, Hkv, G, dh] — one chunk of prompt queries
    kq: jax.Array,     # [Pt, ps, Hkv, dh] page pool (int8 if quantized, else fp)
    ks,                # [Pt, ps, Hkv, 1] f32 scales, or None (fp pool)
    vq: jax.Array,     # [Pt, ps, Hkv, dh]
    vs,                # [Pt, ps, Hkv, 1] or None
    kpos: jax.Array,   # [Pt, ps] int32 — absolute position per pool entry, -1 empty
    table: jax.Array,  # [nt] int32 — the slot's page ids; pre-clamped: -1 -> Pt-1
    qpos: jax.Array,   # [C] int32 — the chunk tokens' absolute positions
    ck: jax.Array,     # [C, Hkv, dh] — the chunk's in-flight (fp) keys
    cv: jax.Array,     # [C, Hkv, dh] — the chunk's in-flight (fp) values
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    """Returns [C, Hkv, G, dh] attention output in q.dtype."""
    C, Hkv, G, dh = q.shape
    Pt, ps = kq.shape[0], kq.shape[1]
    nt = table.shape[0]
    quantized = ks is not None
    # pad the prefetched table with one trash entry so the chunk step's page
    # index maps stay in range (their DMA result is unused)
    tbl = jnp.concatenate(
        [table.astype(jnp.int32), jnp.full((1,), Pt - 1, jnp.int32)]
    )
    qpos2 = qpos.reshape(C, 1).astype(jnp.int32)

    kern = functools.partial(
        _prefill_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        nt=nt, ps=ps, quantized=quantized,
    )
    page = lambda h, t, tref: (tref[t], 0, h, 0)
    in_specs = [
        pl.BlockSpec((C, 1, G, dh), lambda h, t, tref: (0, h, 0, 0)),   # q
        pl.BlockSpec((C, 1), lambda h, t, tref: (0, 0)),                # qpos
        pl.BlockSpec((1, ps, 1, dh), page),                             # k page
    ]
    args = [q, qpos2, kq]
    if quantized:
        in_specs.append(pl.BlockSpec((1, ps, 1, 1), page))              # k scales
        args.append(ks)
    in_specs.append(pl.BlockSpec((1, ps, 1, dh), page))                 # v page
    args.append(vq)
    if quantized:
        in_specs.append(pl.BlockSpec((1, ps, 1, 1), page))              # v scales
        args.append(vs)
    in_specs.append(pl.BlockSpec((1, ps), lambda h, t, tref: (tref[t], 0)))  # pos
    args.append(kpos)
    in_specs.append(pl.BlockSpec((C, 1, dh), lambda h, t, tref: (0, h, 0)))  # ck
    args.append(ck)
    in_specs.append(pl.BlockSpec((C, 1, dh), lambda h, t, tref: (0, h, 0)))  # cv
    args.append(cv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Hkv, nt + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((C, 1, G, dh), lambda h, t, tref: (0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * G,), jnp.float32),      # running max
            pltpu.VMEM((C * G,), jnp.float32),      # running normalizer
            pltpu.VMEM((C * G, dh), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(tbl, *args)


def paged_prefill_attention_ref(
    q, kq, ks, vq, vs, kpos, table, qpos, ck, cv,
    *, scale, causal=True, window=0, softcap=0.0,
):
    """Pure-jnp oracle: gather the mapped pages into a contiguous history,
    append the chunk's in-flight K/V, masked f32 softmax over the union."""
    C, Hkv, G, dh = q.shape
    ps = kq.shape[1]

    def gather(pool):  # [Pt, ps, ...] -> [nt*ps, ...]
        g = pool[table]  # table pre-clamped: -1 -> trash page
        return g.reshape((table.shape[0] * ps,) + g.shape[2:])

    k = gather(kq).astype(jnp.float32)
    v = gather(vq).astype(jnp.float32)
    if ks is not None:
        k = k * gather(ks)
        v = v * gather(vs)
    k = jnp.concatenate([k, ck.astype(jnp.float32)], axis=0)  # [T, Hkv, dh]
    v = jnp.concatenate([v, cv.astype(jnp.float32)], axis=0)
    hist = gather(kpos)
    hist = jnp.where(hist >= qpos[0], -1, hist)  # pool = strictly pre-chunk
    kp = jnp.concatenate([hist, qpos.astype(jnp.int32)])  # [T]

    s = jnp.einsum("chgd,thd->hgct", q.astype(jnp.float32), k) * scale
    s = _soft_cap(s, softcap)
    qp = qpos.astype(jnp.int32)[:, None]  # [C, 1]
    valid = kp[None, :] >= 0
    if causal:
        valid = valid & (kp[None, :] <= qp)
    if window > 0:
        valid = valid & (qp - kp[None, :] < window)
    s = jnp.where(valid[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hgct,thd->chgd", p, v)
    return out.astype(q.dtype)
