"""Grouped expert-MLP Pallas kernel (the dense [E, C, D] expert GEMMs that
the mapping-table dispatch feeds — DeepSpeed-MoE §5.4 "optimized transformer
and MoE related kernels", adapted to the TPU MXU).

Per grid step (e, c, f): a [BC, D] token tile of expert e meets a [D, BF]
slice of that expert's up/gate projections; the SwiGLU'd [BC, BF] tile is
immediately multiplied by the [BF, D] down-projection slice and accumulated
into the [BC, D] output tile in VMEM (revisited across the innermost f axis,
so the intermediate [C, F] activation never exists in HBM).  Block shapes
are multiples of 128 to keep the MXU systolic array full.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_C = 128
BLOCK_F = 256


def _expert_mlp_kernel(x_ref, wi_ref, wg_ref, wo_ref, o_ref):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]  # [BC, D]
    h = jnp.dot(x, wi_ref[0], preferred_element_type=jnp.float32)  # [BC, BF]
    g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    act = (jax.nn.silu(g) * h).astype(x.dtype)
    o_ref[...] += jnp.dot(act, wo_ref[0], preferred_element_type=jnp.float32)[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_c", "block_f"))
def expert_mlp_kernel(
    xe: jax.Array,  # [E, C, D]
    wi: jax.Array,  # [E, D, F]
    wg: jax.Array,  # [E, D, F]
    wo: jax.Array,  # [E, F, D]
    *,
    interpret: bool = True,
    block_c: int = BLOCK_C,
    block_f: int = BLOCK_F,
) -> jax.Array:
    E, C, D = xe.shape
    F = wi.shape[-1]
    bc = min(block_c, C)
    bf = min(block_f, F)
    assert C % bc == 0 and F % bf == 0, (C, bc, F, bf)
    grid = (E, C // bc, F // bf)

    out = pl.pallas_call(
        _expert_mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, D), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, D, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, D, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, bf, D), lambda e, c, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, D), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), jnp.float32),
        interpret=interpret,
    )(xe, wi, wg, wo)
    return out.astype(xe.dtype)
