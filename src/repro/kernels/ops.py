"""jit'd public wrappers around the Pallas kernels.

On TPU the kernels compile natively; on CPU (this container) they execute in
``interpret=True`` mode, which runs the kernel body in Python for
correctness validation against ref.py.  ``use_pallas_gating()`` returns a
Gating namedtuple so the kernel drops into core/moe.py transparently.
"""
from __future__ import annotations

import jax

from repro.core.gating import Gating
from repro.kernels.expert_mlp import expert_mlp_kernel
from repro.kernels.expert_mlp_quant import expert_mlp_quant
from repro.kernels.moe_gating import gating_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_gating(logits: jax.Array, top_k: int, capacity: int, *, normalize: bool = True) -> Gating:
    eidx, w, pos, keep, probs = gating_kernel(
        logits, top_k, capacity, normalize=normalize, interpret=_interpret()
    )
    return Gating(eidx, w, pos, keep, probs)


def fused_expert_mlp(xe, wi, wg, wo):
    return expert_mlp_kernel(xe, wi, wg, wo, interpret=_interpret())


def fused_expert_mlp_quant(xe, wi, wg, wo):
    """wi/wg/wo: int8 per-output-channel QuantizedArrays — tiles dequantized
    in VMEM right before each MXU dot (kernels/expert_mlp_quant.py)."""
    return expert_mlp_quant(xe, wi, wg, wo, interpret=_interpret())


def fused_expert_mlp_grouped(xg, te, wi, wg, wo):
    """Dropless grouped expert MLP: ``xg`` [Ct, D] expert-sorted tile-padded
    tokens, ``te`` the scalar-prefetched tile->expert map
    (kernels/expert_mlp_grouped.py)."""
    from repro.kernels.expert_mlp_grouped import grouped_mlp_kernel

    return grouped_mlp_kernel(xg, te, wi, wg, wo, interpret=_interpret())


def fused_expert_mlp_grouped_quant(xg, te, wi, wg, wo):
    """Dropless grouped expert MLP over int8/int4 QuantizedArrays — tiles
    dequantized (int4: nibble-unpacked) in VMEM before each MXU dot."""
    from repro.kernels.expert_mlp_grouped import grouped_mlp_quant

    return grouped_mlp_quant(xg, te, wi, wg, wo, interpret=_interpret())


def fused_decode_attention_quant(q, kq, ks, vq, vs, kpos, qpos, *, scale, causal, window, softcap):
    """Decode attention over an int8 KV cache — K/V tiles dequantized in
    VMEM right before the attention dots (kernels/attention_quant.py).
    Compiles natively on TPU; interpret mode elsewhere."""
    from repro.kernels.attention_quant import decode_attention_quant

    return decode_attention_quant(
        q, kq, ks, vq, vs, kpos, qpos,
        scale=scale, causal=causal, window=window, softcap=softcap,
        interpret=_interpret(),
    )


def fused_decode_attention_paged(q, kq, ks, vq, vs, kpos, table, qpos, *, scale, causal, window, softcap):
    """Decode attention over a paged KV pool: pages gathered via the
    scalar-prefetched block table inside the kernel, int8 pages dequantized
    in VMEM when ``ks``/``vs`` scales are given (kernels/attention_paged.py).
    ``table`` must be pre-clamped (-1 entries -> trash page)."""
    from repro.kernels.attention_paged import paged_decode_attention

    return paged_decode_attention(
        q, kq, ks, vq, vs, kpos, table, qpos,
        scale=scale, causal=causal, window=window, softcap=softcap,
        interpret=_interpret(),
    )


def fused_prefill_attention_paged(q, kq, ks, vq, vs, kpos, table, qpos, ck, cv,
                                  *, scale, causal, window, softcap):
    """Chunked-prefill attention over a paged KV pool: one chunk of prompt
    queries attends to the sequence's already-written pages (earlier chunks,
    shared prefix pages) via the scalar-prefetched block table PLUS its own
    in-flight fp K/V (kernels/attention_prefill_paged.py).  ``table`` must be
    pre-clamped (-1 entries -> trash page); the pool must be pre-write (the
    chunk's own positions still carry ``pos == -1``)."""
    from repro.kernels.attention_prefill_paged import paged_prefill_attention

    return paged_prefill_attention(
        q, kq, ks, vq, vs, kpos, table, qpos, ck, cv,
        scale=scale, causal=causal, window=window, softcap=softcap,
        interpret=_interpret(),
    )
