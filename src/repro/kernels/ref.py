"""Pure-jnp oracles for the Pallas kernels (the `ref` side of every
kernel-vs-reference allclose test)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gating import Gating, top_k_gating


def gating_ref(logits: jax.Array, top_k: int, capacity: int) -> Gating:
    """Oracle for kernels/moe_gating.py — the cumsum formulation with k-major
    priority (identical to core/gating.py)."""
    return top_k_gating(logits, top_k, capacity, method="cumsum")


def expert_mlp_ref(xe: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    """Oracle for kernels/expert_mlp.py: per-expert SwiGLU grouped GEMM.
    xe: [E, C, D]; wi/wg: [E, D, F]; wo: [E, F, D] -> [E, C, D]."""
    h = jnp.einsum("ecd,edf->ecf", xe, wi, preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", xe, wg, preferred_element_type=jnp.float32)
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h.astype(xe.dtype), wo, preferred_element_type=jnp.float32).astype(
        xe.dtype
    )
