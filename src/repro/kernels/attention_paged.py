"""Paged decode attention (TPU Pallas, validated in interpret mode): one
query token attends over K/V *pages* gathered through a block table.

The KV cache lives in HBM as a shared page pool ``[n_pages+1, page_size,
H_kv, dh]`` (last page = trash, never mapped); each sequence's history is the
pages named by its block-table row.  The grid is (batch, kv-head, table
entry) with the table **scalar-prefetched** so the BlockSpec index map can
pick each K/V page data-dependently — the DMA engine streams exactly the
pages a sequence owns, nothing else, and the kernel never materializes a
gathered contiguous copy of the cache.  The page axis is innermost
(sequential on TPU), so the online-softmax running max / normalizer /
accumulator live in VMEM scratch across pages, flash-attention style.

Composes with the int8 KV cache (kernels/attention_quant.py): when the pool
is quantized, each page's int8 K/V tile is widened and rescaled by its
per-(timestep, head) f32 scales *in VMEM* right before the dot — pages then
cost ~1 byte/entry of HBM traffic on top of the fragmentation win.

Masking (unmapped-page validity, causality, sliding window) is computed
in-kernel from the pool's absolute-position array: the trash page is pinned
at ``pos == -1`` so -1 table entries (pre-clamped to the trash page by the
wrapper) contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _soft_cap(s, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(s / cap) * cap
    return s


def _paged_kernel(table_ref, *refs, scale, causal, window, softcap, nt, ps, quantized):
    """Grid (B, Hkv, nt); refs layout depends on ``quantized`` (scales
    present or not).  Scratch: running max / normalizer / accumulator."""
    if quantized:
        (q_ref, qpos_ref, kq_ref, ks_ref, vq_ref, vs_ref, kpos_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, qpos_ref, kq_ref, vq_ref, kpos_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G, dh = q_ref.shape[-2], q_ref.shape[-1]
    q = q_ref[...].reshape(G, dh).astype(jnp.float32)
    k = kq_ref[...].reshape(ps, dh).astype(jnp.float32)
    if quantized:
        k = k * ks_ref[...].reshape(ps, 1)  # dequantize the page in VMEM
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, ps]
    s = _soft_cap(s, softcap)

    kp = kpos_ref[...].reshape(1, ps)  # absolute positions, -1 = empty
    qp = qpos_ref[0, 0]
    valid = kp >= 0
    if causal:
        valid = valid & (kp <= qp)
    if window > 0:
        valid = valid & (qp - kp < window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    # Zero masked entries explicitly: on a fully-masked tile seen before any
    # valid key the running max is still NEG_INF, and exp(NEG_INF - NEG_INF)
    # == 1 would count every masked key into the normalizer.
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)  # [G, ps]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    v = vq_ref[...].reshape(ps, dh).astype(jnp.float32)
    if quantized:
        v = v * vs_ref[...].reshape(ps, 1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(it == nt - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "interpret"),
)
def paged_decode_attention(
    q: jax.Array,      # [B, Hkv, G, dh] — one decode token, grouped per kv-head
    kq: jax.Array,     # [Pt, ps, Hkv, dh] page pool (int8 if quantized, else fp)
    ks,                # [Pt, ps, Hkv, 1] f32 scales, or None (fp pool)
    vq: jax.Array,     # [Pt, ps, Hkv, dh]
    vs,                # [Pt, ps, Hkv, 1] or None
    kpos: jax.Array,   # [Pt, ps] int32 — absolute position per pool entry, -1 empty
    table: jax.Array,  # [B, nt] int32 — page ids; MUST be pre-clamped: -1 -> Pt-1
    qpos: jax.Array,   # [B, 1] int32 — the query token's absolute position
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    """Returns [B, Hkv, G, dh] attention output in q.dtype.

    Caller contract (``tests/test_paged.py::TestPagedKernel`` checks the
    masking consequences against the einsum ref):

      * ``table`` is pre-clamped — -1 (unmapped) entries replaced by the
        trash page id ``Pt - 1``, whose ``kpos`` row is pinned at -1 so it
        contributes nothing;
      * ``kpos`` is -1 for every never/no-longer-valid pool entry (freshly
        allocated and recycled pages are invalidated by
        ``models.model.paged_reset_pages`` — a stale position <= the query's
        would otherwise unmask the previous occupant's K/V);
      * fully masked tiles are explicitly zeroed out of the normalizer, so
        trash-only rows (inactive slots) return garbage-but-finite output
        that the scheduler discards."""
    B, Hkv, G, dh = q.shape
    ps = kq.shape[1]
    nt = table.shape[1]
    quantized = ks is not None

    kern = functools.partial(
        _paged_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        nt=nt, ps=ps, quantized=quantized,
    )
    # index maps get the prefetched table ref appended; each (b, ·, t) step
    # DMAs page table[b, t] of the pool straight into VMEM
    page = lambda b, h, t, tref: (tref[b, t], 0, h, 0)
    in_specs = [
        pl.BlockSpec((1, 1, G, dh), lambda b, h, t, tref: (b, h, 0, 0)),   # q
        pl.BlockSpec((1, 1), lambda b, h, t, tref: (b, 0)),                # qpos
        pl.BlockSpec((1, ps, 1, dh), page),                                # k page
    ]
    args = [q, qpos, kq]
    if quantized:
        in_specs.append(pl.BlockSpec((1, ps, 1, 1), page))                 # k scales
        args.append(ks)
    in_specs.append(pl.BlockSpec((1, ps, 1, dh), page))                    # v page
    args.append(vq)
    if quantized:
        in_specs.append(pl.BlockSpec((1, ps, 1, 1), page))                 # v scales
        args.append(vs)
    in_specs.append(pl.BlockSpec((1, ps), lambda b, h, t, tref: (tref[b, t], 0)))  # pos

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, t, tref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),      # running max
            pltpu.VMEM((G,), jnp.float32),      # running normalizer
            pltpu.VMEM((G, dh), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(table, *args, kpos)


def paged_decode_attention_ref(
    q, kq, ks, vq, vs, kpos, table, qpos, *, scale, causal=True, window=0, softcap=0.0
):
    """Pure-jnp oracle: gather the mapped pages into a contiguous [B, T]
    view (T = nt * ps), dequantize if needed, masked f32 softmax."""
    B, Hkv, G, dh = q.shape
    ps = kq.shape[1]

    def gather(pool):  # [Pt, ps, ...] -> [B, nt*ps, ...]
        g = pool[table]  # table pre-clamped: -1 -> trash page
        return g.reshape((B, table.shape[1] * ps) + g.shape[3:])

    k = gather(kq).astype(jnp.float32)
    v = gather(vq).astype(jnp.float32)
    if ks is not None:
        k = k * gather(ks)
        v = v * gather(vs)
    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32), k) * scale
    s = _soft_cap(s, softcap)
    kp = gather(kpos)[:, None, None, :]  # [B, 1, 1, T]
    qp = qpos[:, :, None, None].astype(jnp.int32)
    valid = kp >= 0
    if causal:
        valid = valid & (kp <= qp)
    if window > 0:
        valid = valid & (qp - kp < window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v)
    return out.astype(q.dtype)
