"""Fused MoE gating kernel (DeepSpeed-MoE §5.4, TPU-native).

The paper fuses the gating function — top-k selection, the cumulative sum
that assigns each token its slot inside its expert's capacity buffer, and the
construction of the dense token→expert mapping table — into one kernel,
replacing a chain of sparse one-hot einsums (and, on GPU, a Blelloch-scan
cumsum across SMs).

TPU adaptation (DESIGN.md §2): the Pallas grid on TPU executes
**sequentially**, so the running per-expert token counts live in a VMEM
scratch buffer carried across grid steps — an exact, race-free prefix sum
with no tree scan.  Each grid step processes a [BT, E] tile of router logits:
softmax, k iterative masked argmaxes (k ≤ 8), a one-hot cumsum for the
intra-tile position-in-expert, plus the running-counts offset.  Priority is
token-major (slot t*K + k), matching core/gating.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_T = 128  # token tile (VPU lane aligned)


def _gating_kernel(logits_ref, eidx_ref, w_ref, pos_ref, probs_ref, counts_ref, *, top_k: int, E: int):
    tb = pl.program_id(0)

    @pl.when(tb == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    logits = logits_ref[...].astype(jnp.float32)  # [BT, E]
    probs = jax.nn.softmax(logits, axis=-1)
    probs_ref[...] = probs

    BT = logits.shape[0]
    m = probs
    eidx_cols = []
    gate_cols = []
    for _ in range(top_k):  # static unroll: iterative masked argmax
        top = jnp.argmax(m, axis=-1)
        eidx_cols.append(top.astype(jnp.int32))
        gate_cols.append(jnp.max(m, axis=-1))
        m = jnp.where(jax.nn.one_hot(top, E, dtype=jnp.bool_), -jnp.inf, m)
    eidx = jnp.stack(eidx_cols, axis=-1)  # [BT, K]
    gate = jnp.stack(gate_cols, axis=-1)  # [BT, K]

    # token-major flat assignment order within the tile: row t*K + k
    flat = eidx.reshape(BT * top_k)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)  # [BT*K, E]
    intra = (jnp.cumsum(onehot, axis=0) - 1) * onehot
    pos_flat = jnp.sum(intra, axis=-1) + jnp.sum(onehot * counts_ref[...][None, :], axis=-1)

    counts_ref[...] = counts_ref[...] + jnp.sum(onehot, axis=0)

    eidx_ref[...] = eidx
    w_ref[...] = gate
    pos_ref[...] = pos_flat.reshape(BT, top_k).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("top_k", "capacity", "interpret", "block_t", "normalize")
)
def gating_kernel(
    logits: jax.Array,  # [T, E]
    top_k: int,
    capacity: int,
    *,
    normalize: bool = True,
    interpret: bool = True,
    block_t: int = BLOCK_T,
):
    """Fused gating.  Returns (expert_idx [T,K], combine_w [T,K],
    position [T,K], keep [T,K], probs [T,E]) — the same contract as
    core.gating.top_k_gating."""
    T, E = logits.shape
    bt = min(block_t, T)
    assert T % bt == 0, f"T={T} must be a multiple of the token block {bt}"
    nb = T // bt

    out_shapes = (
        jax.ShapeDtypeStruct((T, top_k), jnp.int32),
        jax.ShapeDtypeStruct((T, top_k), jnp.float32),
        jax.ShapeDtypeStruct((T, top_k), jnp.int32),
        jax.ShapeDtypeStruct((T, E), jnp.float32),
    )
    kern = functools.partial(_gating_kernel, top_k=top_k, E=E)
    eidx, w, pos, probs = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[pl.BlockSpec((bt, E), lambda t: (t, 0))],
        out_specs=(
            pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
            pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
            pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
            pl.BlockSpec((bt, E), lambda t: (t, 0)),
        ),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((E,), jnp.int32)],
        interpret=interpret,
    )(logits)

    if normalize and top_k > 1:
        w = w / jnp.sum(w, axis=-1, keepdims=True)
    keep = pos < capacity
    w = jnp.where(keep, w, 0.0)
    pos = jnp.where(keep, pos, capacity - 1)
    return eidx, w, pos, keep, probs
