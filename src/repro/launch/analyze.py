"""Trace-time static analysis gate (`make analyze`, ci.sh `analyze` stage).

Runs the four `repro.analysis` passes over the serving engines — before
anything executes on a device — and turns the findings into an exit code:

  1. host-sync / tracer-leak lint over the whole ``src/repro`` tree;
  2. compile-shape contract check for the continuous (paged + prefix-sharing
     + chunked-prefill) and static engines of each ``--arch``: every
     declared signature abstract-traces, the chunk family is closed under
     reachable scheduler states, and the predicted compile count is reported
     (the number the PR 6 retrace watchdog verifies at runtime — see
     ``benchmarks/run.py obs``);
  3. donation/aliasing audit: every ``donate_argnums`` leaf of every jitted
     engine function produced an input-output alias in the lowered module,
     and every donating call site rebinds the donated reference;
  4. graph audit of the decode/prefill graphs: no collectives in
     single-device serving graphs, no int8/int4 -> f32 dequant upcasts, and
     the capacity-padding dead-compute fraction for MoE archs (info).

Besides the ``--arch`` targets it also analyzes a fused-tick engine
(``nlg-350m-moe128`` with ``moe_impl="grouped"`` + ``prefill_mode="batched"``)
so the grouped dropless dispatch graph and the batched-prefill contract /
compile-count prediction are gated too (``--no-fused`` skips it), and two
expert-parallel serving-mesh engines (``nlg-350m-moe128`` over a (2, 2)
hierarchical-a2a mesh, default + grouped/batched schedules) so the sharded
jit registry's contracts, donations and collective structure are gated as
well — re-exec'd under forced fake CPU devices when the host has fewer
than 4 (``--no-ep`` skips it, ``--ep-only`` runs just these).

Exit 0 = no unsuppressed errors (``--strict``: no warnings either).

  PYTHONPATH=src python -m repro.launch.analyze                 # glm4 + gemma3
  PYTHONPATH=src python -m repro.launch.analyze --arch nlg-350m-moe128
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Sequence

import jax

from repro.analysis import (
    Report,
    Workload,
    audit_donation,
    audit_donated_rebinds,
    audit_graph,
    check_closure,
    check_contract,
    lint_tree,
    predict_compiles,
)
from repro.configs.registry import get_config, make_reduced
from repro.models.model import init_params
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine, EngineConfig

DEFAULT_ARCHS = ("glm4-9b", "gemma3-27b")

# the scenario the contract's closure/prediction passes replay: mixed prompt
# lengths (page-aligned, odd, sub-page, exactly one chunk budget)
_WORKLOAD = Workload(prompt_lens=(16, 33, 7, 64), max_new=8, ticks=24)


def _moe_ffn(cfg):
    for seg in cfg.segments:
        for ls in seg.pattern:
            if getattr(ls.ffn, "num_experts", 0):
                return ls.ffn
    return None


def _moe_spec(cfg, num_tokens: int) -> Optional[dict]:
    f = _moe_ffn(cfg)
    if f is None:
        return None
    impl = cfg.moe_impl
    # the EP serving schedules keep the reference kernels' compute shape:
    # ep_grouped is the grouped dropless layout (tile padding, no [E, C]
    # buffer) and ep_serve's per-shard dots have leading dim E_local != E,
    # so the capacity cross-check must not look for full-E buffers there.
    if impl == "ep_grouped":
        impl = "grouped"
    return {"num_tokens": num_tokens, "num_experts": f.num_experts,
            "top_k": f.top_k, "capacity_factor": f.capacity_factor,
            "impl": impl}


def build_engines(arch: str, *, reduced: bool = True, slots: int = 4,
                  capacity: int = 128, page_size: int = 16,
                  static_ec: Optional[EngineConfig] = None,
                  moe_impl: Optional[str] = None,
                  prefill_mode: str = "chunked",
                  ep_mesh: Sequence[int] = (), spec: bool = False):
    """(ContinuousEngine paged+prefix, static Engine) for ``arch``.
    ``moe_impl`` overrides the config's dispatch implementation (the grouped
    dropless target); ``prefill_mode`` selects the admission state machine
    ("chunked" default, "batched" = the fused-tick single-dispatch entry);
    ``ep_mesh`` builds the engines over an expert-parallel serving mesh
    (``(2, 2)`` = hierarchical two-hop all-to-all topology); ``spec`` arms
    draft-then-verify speculation with the self-draft oracle (drafter ==
    target), registering the verify/propose/commit jit family."""
    import dataclasses

    cfg = get_config(arch)
    if reduced:
        cfg = make_reduced(cfg)
    if moe_impl is not None:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    if ep_mesh:
        cfg = dataclasses.replace(cfg, ep_mesh=tuple(ep_mesh))
    params = init_params(cfg, jax.random.PRNGKey(0))
    cont = ContinuousEngine(
        cfg, params, slots=slots, capacity=capacity,
        paged=True, page_size=page_size, prefix_sharing=True,
        prefill_mode=prefill_mode,
        spec_draft=(cfg, params) if spec else None,
    )
    ec = static_ec if static_ec is not None else EngineConfig(
        max_batch=2, max_prefill=64, max_decode=8)
    stat = Engine(cfg, params, ec)
    return cont, stat


def analyze_contracts(tag: str, engine, report: Report, *,
                      workload: Workload = _WORKLOAD) -> None:
    """Pass 2 on one engine: trace + closure + compile-count prediction."""
    entries = engine.shape_contract()
    sub = Report()
    check_contract(entries, sub)
    if isinstance(engine, ContinuousEngine) and engine.paged:
        check_closure(entries, capacity=engine.capacity,
                      page_size=engine.page_size,
                      prefill_chunk=engine.prefill_chunk,
                      workload=workload, report=sub)
        pred = predict_compiles(
            slots=engine.n_slots, capacity=engine.capacity,
            page_size=engine.page_size, prefill_chunk=engine.prefill_chunk,
            workload=workload, prefill_mode=engine.prefill_mode,
            spec=({"commit_pass": engine._spec_commit is not None}
                  if getattr(engine, "drafter", None) is not None else None))
        sub.add("predicted-compiles", "info", tag,
                f"workload {tuple(workload.prompt_lens)} x{workload.max_new} "
                f"new over {workload.ticks} ticks compiles: "
                + ", ".join(f"{k}={v}" for k, v in pred.items() if v)
                + f" (total {sum(pred.values())})")
        sub.metrics[f"contract.{tag}.predicted_compiles"] = sum(pred.values())
    # re-home the per-pass metric keys under this engine's tag
    for k in list(sub.metrics):
        if k.startswith("contract.") and not k.startswith(f"contract.{tag}"):
            sub.metrics[f"contract.{tag}.{k[len('contract.'):]}"] = sub.metrics.pop(k)
    report.extend(sub)


def analyze_donations(tag: str, engine, report: Report) -> None:
    """Pass 3a on one engine: lowered-module alias audit per jitted fn."""
    by_name = {e.name: e for e in engine.shape_contract()}
    for name, (fn, don, _primary) in engine.jitted_functions().items():
        entry = by_name.get(name)
        if entry is None or not entry.sample:
            report.add("donation-uncovered", "error", f"{tag}.{name}",
                       "jitted fn has no contract entry to audit donation at")
            continue
        args = entry.make(*entry.sample[-1])
        audit_donation(f"{tag}.{name}", fn, args, don, report,
                       location=f"{tag}.{name}")


def _pkg_root() -> str:
    import repro

    # repro is a namespace package (no __init__.py): __file__ is None
    return list(repro.__path__)[0]


def analyze_rebinds(report: Report, donated_by_file: dict) -> None:
    """Pass 3b: donated references are rebound at every call site."""
    root = _pkg_root()
    for rel, donated in donated_by_file.items():
        path = os.path.join(root, rel)
        with open(path) as f:
            audit_donated_rebinds(f.read(), rel, donated, report)


def analyze_graphs(tag: str, engine, report: Report) -> None:
    """Pass 4 on one engine: collectives / dtype drift / dead compute in the
    decode graph (the steady-state tick) and, for the continuous engine, the
    budget-length prefill chunk (the admission graph).  Engines built over an
    expert-parallel serving mesh flip the collective check: their MoE graphs
    must *contain* the shard_map token exchange (all_gather/psum/all_to_all)
    rather than be free of it."""
    by_name = {e.name: e for e in engine.shape_contract()}
    cfg = engine.cfg
    multi = getattr(engine, "_mesh", None) is not None
    coll = dict(single_device=not multi,
                expect_collectives=multi and _moe_ffn(cfg) is not None)
    dec = by_name["decode"]
    n_dec = engine.n_slots if isinstance(engine, ContinuousEngine) else engine.ec.max_batch
    audit_graph(f"{tag}.decode", dec.fn, dec.make(*dec.sample[-1]),
                moe=_moe_spec(cfg, n_dec), report=report, **coll)
    chunk = by_name.get("prefill_chunk_first")
    if chunk is not None:
        pt = chunk.sample[-1]
        audit_graph(f"{tag}.prefill_chunk", chunk.fn, chunk.make(*pt),
                    moe=_moe_spec(cfg, pt[0]), report=report, **coll)
        return
    # batched fused-tick engines build one fixed-shape prefill entry instead
    # of the first/cont chunk family; its sample point is the singleton ()
    batched = by_name.get("prefill_chunk_batched")
    if batched is not None:
        nt = engine.n_slots * engine.prefill_chunk
        audit_graph(f"{tag}.prefill_chunk_batched", batched.fn,
                    batched.make(*batched.sample[-1]),
                    moe=_moe_spec(cfg, nt), report=report, **coll)


def analyze_arch(arch: str, report: Report, *, reduced: bool = True,
                 passes: Sequence[str] = ("contract", "donation", "graph"),
                 moe_impl: Optional[str] = None,
                 prefill_mode: str = "chunked", tag: str = "",
                 ep_mesh: Sequence[int] = (), spec: bool = False) -> None:
    cont, stat = build_engines(arch, reduced=reduced, moe_impl=moe_impl,
                               prefill_mode=prefill_mode, ep_mesh=ep_mesh,
                               spec=spec)
    base = f"{arch}{tag}"
    for tag, eng in ((f"{base}.continuous", cont), (f"{base}.static", stat)):
        if "contract" in passes:
            analyze_contracts(tag, eng, report)
        if "donation" in passes:
            analyze_donations(tag, eng, report)
        if "graph" in passes:
            analyze_graphs(tag, eng, report)


def donated_call_sites() -> dict:
    """file -> {method attr -> donated argnum}: the engines' donating call
    sites, derived from the jit registries' declared donations (the paged
    continuous registry is the superset)."""
    return {
        "serving/continuous.py": {
            "_decode": 4, "_prefill": 4, "_prefill_chunk_first": 4,
            "_prefill_chunk_cont": 4, "_prefill_chunk_batched": 6,
            "_reset_pages": 0, "_copy_page": 0, "_copy_slot": 0,
            "_verify": 4, "_spec_commit": 6, "_spec_reset_tail": 0,
        },
        "serving/engine.py": {"_decode": 3, "_prefill": 2},
        "serving/spec.py": {"_prefill": 4, "_propose": 5},
    }


# the EP serving gate shards experts over this many fake CPU devices when
# the host has fewer real ones (the (2, 2) mesh exercises the hierarchical
# two-hop all-to-all topology on the reduced 4-expert configs)
_EP_DEVICES = 4
_EP_MESH = (2, 2)


def analyze_ep(report: Report, *, reduced: bool = True,
               passes: Sequence[str] = ("contract", "donation", "graph")) -> None:
    """EP serving targets: experts sharded over a (2, 2) ("pod", ep_axis)
    mesh for both the default serving schedule (replicated-token decode +
    a2a-sharded prefill) and the grouped dropless kernel with batched
    prefill.  Gates that the sharded jit registry abstract-traces, donates,
    and that its MoE graphs actually carry the token-exchange collectives."""
    analyze_arch("nlg-350m-moe128", report, reduced=reduced, passes=passes,
                 tag="+ep", ep_mesh=_EP_MESH)
    analyze_arch("nlg-350m-moe128", report, reduced=reduced, passes=passes,
                 moe_impl="grouped", prefill_mode="batched",
                 tag="+ep-grouped", ep_mesh=_EP_MESH)


def _reexec_ep(args) -> int:
    """Re-run this module with ``--ep-only`` in a subprocess that forces
    enough fake CPU devices for the EP mesh (the parent's jax backend is
    already initialized single-device, so the flag can't be set in-process)."""
    import subprocess

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={_EP_DEVICES}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    cmd = [sys.executable, "-m", "repro.launch.analyze", "--ep-only"]
    if args.full:
        cmd.append("--full")
    if args.strict:
        cmd.append("--strict")
    if args.show_suppressed:
        cmd.append("--show-suppressed")
    if args.skip:
        cmd += ["--skip", *args.skip]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    sys.stdout.write(proc.stdout)
    if proc.returncode and proc.stderr:
        sys.stderr.write(proc.stderr)
    return proc.returncode


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", nargs="*", default=list(DEFAULT_ARCHS),
                    help=f"registry archs to analyze (default: {DEFAULT_ARCHS})")
    ap.add_argument("--full", action="store_true",
                    help="full-size configs (default: make_reduced)")
    ap.add_argument("--strict", action="store_true",
                    help="warnings fail the gate too")
    ap.add_argument("--show-suppressed", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["lint", "contract", "donation", "rebind", "graph"],
                    help="passes to skip")
    ap.add_argument("--no-fused", action="store_true",
                    help="skip the grouped-MoE + batched-prefill fused-tick "
                         "engine target")
    ap.add_argument("--no-spec", action="store_true",
                    help="skip the speculative-decoding (self-draft) engine "
                         "target")
    ap.add_argument("--no-ep", action="store_true",
                    help="skip the expert-parallel serving-mesh engine targets")
    ap.add_argument("--ep-only", action="store_true",
                    help="run only the EP targets (used by the self-re-exec "
                         "under forced fake devices; skips lint/rebind)")
    args = ap.parse_args(argv)

    report = Report()
    engine_passes = tuple(p for p in ("contract", "donation", "graph")
                          if p not in args.skip)
    if not args.ep_only:
        if "lint" not in args.skip:
            report.extend(lint_tree(_pkg_root()))
        if "rebind" not in args.skip:
            analyze_rebinds(report, donated_call_sites())
        if engine_passes:
            for arch in args.arch:
                analyze_arch(arch, report, reduced=not args.full,
                             passes=engine_passes)
            if not args.no_fused:
                # the fused-tick configuration the PR 8 work is measured
                # against: grouped (dropless) expert dispatch + single
                # batched prefill call
                analyze_arch("nlg-350m-moe128", report, reduced=not args.full,
                             passes=engine_passes, moe_impl="grouped",
                             prefill_mode="batched", tag="+fused")
            if not args.no_spec:
                # speculative decoding with the self-draft oracle; gemma3's
                # window-ring mix also registers the committed-recurrent-state
                # pass (spec_commit), the widest spec jit family
                analyze_arch("gemma3-27b", report, reduced=not args.full,
                             passes=engine_passes, prefill_mode="batched",
                             tag="+spec", spec=True)
    ep_rc = 0
    if engine_passes and not args.no_ep:
        if jax.device_count() >= _EP_DEVICES:
            analyze_ep(report, reduced=not args.full, passes=engine_passes)
        elif args.ep_only:
            report.add("ep-devices", "error", "ep",
                       f"--ep-only needs >= {_EP_DEVICES} devices, have "
                       f"{jax.device_count()} (set XLA_FLAGS="
                       f"--xla_force_host_platform_device_count={_EP_DEVICES})")
        else:
            ep_rc = _reexec_ep(args)
    print(report.render(show_suppressed=args.show_suppressed))
    failed = report.failed(strict=args.strict)
    print("analyze:", "FAIL" if failed else "OK")
    return 1 if (failed or ep_rc) else 0


if __name__ == "__main__":
    sys.exit(main())
