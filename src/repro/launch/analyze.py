"""Trace-time static analysis gate (`make analyze`, ci.sh `analyze` stage).

Runs the four `repro.analysis` passes over the serving engines — before
anything executes on a device — and turns the findings into an exit code:

  1. host-sync / tracer-leak lint over the whole ``src/repro`` tree;
  2. compile-shape contract check for the continuous (paged + prefix-sharing
     + chunked-prefill) and static engines of each ``--arch``: every
     declared signature abstract-traces, the chunk family is closed under
     reachable scheduler states, and the predicted compile count is reported
     (the number the PR 6 retrace watchdog verifies at runtime — see
     ``benchmarks/run.py obs``);
  3. donation/aliasing audit: every ``donate_argnums`` leaf of every jitted
     engine function produced an input-output alias in the lowered module,
     and every donating call site rebinds the donated reference;
  4. graph audit of the decode/prefill graphs: no collectives in
     single-device serving graphs, no int8/int4 -> f32 dequant upcasts, and
     the capacity-padding dead-compute fraction for MoE archs (info).

Besides the ``--arch`` targets it also analyzes a fused-tick engine
(``nlg-350m-moe128`` with ``moe_impl="grouped"`` + ``prefill_mode="batched"``)
so the grouped dropless dispatch graph and the batched-prefill contract /
compile-count prediction are gated too (``--no-fused`` skips it).

Exit 0 = no unsuppressed errors (``--strict``: no warnings either).

  PYTHONPATH=src python -m repro.launch.analyze                 # glm4 + gemma3
  PYTHONPATH=src python -m repro.launch.analyze --arch nlg-350m-moe128
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Sequence

import jax

from repro.analysis import (
    Report,
    Workload,
    audit_donation,
    audit_donated_rebinds,
    audit_graph,
    check_closure,
    check_contract,
    lint_tree,
    predict_compiles,
)
from repro.configs.registry import get_config, make_reduced
from repro.models.model import init_params
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine, EngineConfig

DEFAULT_ARCHS = ("glm4-9b", "gemma3-27b")

# the scenario the contract's closure/prediction passes replay: mixed prompt
# lengths (page-aligned, odd, sub-page, exactly one chunk budget)
_WORKLOAD = Workload(prompt_lens=(16, 33, 7, 64), max_new=8, ticks=24)


def _moe_ffn(cfg):
    for seg in cfg.segments:
        for ls in seg.pattern:
            if getattr(ls.ffn, "num_experts", 0):
                return ls.ffn
    return None


def _moe_spec(cfg, num_tokens: int) -> Optional[dict]:
    f = _moe_ffn(cfg)
    if f is None:
        return None
    return {"num_tokens": num_tokens, "num_experts": f.num_experts,
            "top_k": f.top_k, "capacity_factor": f.capacity_factor,
            "impl": cfg.moe_impl}


def build_engines(arch: str, *, reduced: bool = True, slots: int = 4,
                  capacity: int = 128, page_size: int = 16,
                  static_ec: Optional[EngineConfig] = None,
                  moe_impl: Optional[str] = None,
                  prefill_mode: str = "chunked"):
    """(ContinuousEngine paged+prefix, static Engine) for ``arch``.
    ``moe_impl`` overrides the config's dispatch implementation (the grouped
    dropless target); ``prefill_mode`` selects the admission state machine
    ("chunked" default, "batched" = the fused-tick single-dispatch entry)."""
    import dataclasses

    cfg = get_config(arch)
    if reduced:
        cfg = make_reduced(cfg)
    if moe_impl is not None:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cont = ContinuousEngine(
        cfg, params, slots=slots, capacity=capacity,
        paged=True, page_size=page_size, prefix_sharing=True,
        prefill_mode=prefill_mode,
    )
    ec = static_ec if static_ec is not None else EngineConfig(
        max_batch=2, max_prefill=64, max_decode=8)
    stat = Engine(cfg, params, ec)
    return cont, stat


def analyze_contracts(tag: str, engine, report: Report, *,
                      workload: Workload = _WORKLOAD) -> None:
    """Pass 2 on one engine: trace + closure + compile-count prediction."""
    entries = engine.shape_contract()
    sub = Report()
    check_contract(entries, sub)
    if isinstance(engine, ContinuousEngine) and engine.paged:
        check_closure(entries, capacity=engine.capacity,
                      page_size=engine.page_size,
                      prefill_chunk=engine.prefill_chunk,
                      workload=workload, report=sub)
        pred = predict_compiles(
            slots=engine.n_slots, capacity=engine.capacity,
            page_size=engine.page_size, prefill_chunk=engine.prefill_chunk,
            workload=workload, prefill_mode=engine.prefill_mode)
        sub.add("predicted-compiles", "info", tag,
                f"workload {tuple(workload.prompt_lens)} x{workload.max_new} "
                f"new over {workload.ticks} ticks compiles: "
                + ", ".join(f"{k}={v}" for k, v in pred.items() if v)
                + f" (total {sum(pred.values())})")
        sub.metrics[f"contract.{tag}.predicted_compiles"] = sum(pred.values())
    # re-home the per-pass metric keys under this engine's tag
    for k in list(sub.metrics):
        if k.startswith("contract.") and not k.startswith(f"contract.{tag}"):
            sub.metrics[f"contract.{tag}.{k[len('contract.'):]}"] = sub.metrics.pop(k)
    report.extend(sub)


def analyze_donations(tag: str, engine, report: Report) -> None:
    """Pass 3a on one engine: lowered-module alias audit per jitted fn."""
    by_name = {e.name: e for e in engine.shape_contract()}
    for name, (fn, don, _primary) in engine.jitted_functions().items():
        entry = by_name.get(name)
        if entry is None or not entry.sample:
            report.add("donation-uncovered", "error", f"{tag}.{name}",
                       "jitted fn has no contract entry to audit donation at")
            continue
        args = entry.make(*entry.sample[-1])
        audit_donation(f"{tag}.{name}", fn, args, don, report,
                       location=f"{tag}.{name}")


def _pkg_root() -> str:
    import repro

    # repro is a namespace package (no __init__.py): __file__ is None
    return list(repro.__path__)[0]


def analyze_rebinds(report: Report, donated_by_file: dict) -> None:
    """Pass 3b: donated references are rebound at every call site."""
    root = _pkg_root()
    for rel, donated in donated_by_file.items():
        path = os.path.join(root, rel)
        with open(path) as f:
            audit_donated_rebinds(f.read(), rel, donated, report)


def analyze_graphs(tag: str, engine, report: Report) -> None:
    """Pass 4 on one engine: collectives / dtype drift / dead compute in the
    decode graph (the steady-state tick) and, for the continuous engine, the
    budget-length prefill chunk (the admission graph)."""
    by_name = {e.name: e for e in engine.shape_contract()}
    cfg = engine.cfg
    dec = by_name["decode"]
    n_dec = engine.n_slots if isinstance(engine, ContinuousEngine) else engine.ec.max_batch
    audit_graph(f"{tag}.decode", dec.fn, dec.make(*dec.sample[-1]),
                moe=_moe_spec(cfg, n_dec), report=report)
    chunk = by_name.get("prefill_chunk_first")
    if chunk is not None:
        pt = chunk.sample[-1]
        audit_graph(f"{tag}.prefill_chunk", chunk.fn, chunk.make(*pt),
                    moe=_moe_spec(cfg, pt[0]), report=report)
        return
    # batched fused-tick engines build one fixed-shape prefill entry instead
    # of the first/cont chunk family; its sample point is the singleton ()
    batched = by_name.get("prefill_chunk_batched")
    if batched is not None:
        nt = engine.n_slots * engine.prefill_chunk
        audit_graph(f"{tag}.prefill_chunk_batched", batched.fn,
                    batched.make(*batched.sample[-1]),
                    moe=_moe_spec(cfg, nt), report=report)


def analyze_arch(arch: str, report: Report, *, reduced: bool = True,
                 passes: Sequence[str] = ("contract", "donation", "graph"),
                 moe_impl: Optional[str] = None,
                 prefill_mode: str = "chunked", tag: str = "") -> None:
    cont, stat = build_engines(arch, reduced=reduced, moe_impl=moe_impl,
                               prefill_mode=prefill_mode)
    base = f"{arch}{tag}"
    for tag, eng in ((f"{base}.continuous", cont), (f"{base}.static", stat)):
        if "contract" in passes:
            analyze_contracts(tag, eng, report)
        if "donation" in passes:
            analyze_donations(tag, eng, report)
        if "graph" in passes:
            analyze_graphs(tag, eng, report)


def donated_call_sites() -> dict:
    """file -> {method attr -> donated argnum}: the engines' donating call
    sites, derived from the jit registries' declared donations (the paged
    continuous registry is the superset)."""
    return {
        "serving/continuous.py": {
            "_decode": 4, "_prefill": 4, "_prefill_chunk_first": 4,
            "_prefill_chunk_cont": 4, "_prefill_chunk_batched": 6,
            "_reset_pages": 0, "_copy_page": 0, "_copy_slot": 0,
        },
        "serving/engine.py": {"_decode": 3, "_prefill": 2},
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", nargs="*", default=list(DEFAULT_ARCHS),
                    help=f"registry archs to analyze (default: {DEFAULT_ARCHS})")
    ap.add_argument("--full", action="store_true",
                    help="full-size configs (default: make_reduced)")
    ap.add_argument("--strict", action="store_true",
                    help="warnings fail the gate too")
    ap.add_argument("--show-suppressed", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["lint", "contract", "donation", "rebind", "graph"],
                    help="passes to skip")
    ap.add_argument("--no-fused", action="store_true",
                    help="skip the grouped-MoE + batched-prefill fused-tick "
                         "engine target")
    args = ap.parse_args(argv)

    report = Report()
    if "lint" not in args.skip:
        report.extend(lint_tree(_pkg_root()))
    if "rebind" not in args.skip:
        analyze_rebinds(report, donated_call_sites())
    engine_passes = tuple(p for p in ("contract", "donation", "graph")
                          if p not in args.skip)
    if engine_passes:
        for arch in args.arch:
            analyze_arch(arch, report, reduced=not args.full,
                         passes=engine_passes)
        if not args.no_fused:
            # the fused-tick configuration the PR 8 work is measured against:
            # grouped (dropless) expert dispatch + single batched prefill call
            analyze_arch("nlg-350m-moe128", report, reduced=not args.full,
                         passes=engine_passes, moe_impl="grouped",
                         prefill_mode="batched", tag="+fused")
    print(report.render(show_suppressed=args.show_suppressed))
    failed = report.failed(strict=args.strict)
    print("analyze:", "FAIL" if failed else "OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
