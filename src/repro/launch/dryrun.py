import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract roofline terms.  No arrays are allocated —
inputs are ShapeDtypeStructs with NamedShardings.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs.registry import ASSIGNED, get_config  # noqa: E402
from repro.configs.shapes import SHAPES, shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import analyze, model_flops  # noqa: E402
from repro.launch.steps import entry_for, input_specs  # noqa: E402
from repro.parallel.sharding import RULESETS, use_mesh  # noqa: E402


def _lower(cfg, shape, mesh, ruleset: str):
    with use_mesh(mesh, RULESETS[ruleset]):
        specs = input_specs(cfg, shape, mesh)
        fn = entry_for(cfg, shape.kind)
        # Donate the mutated state (params/opt for train, caches for serving)
        # — production steps buffer-alias these; without donation the dry-run
        # would double-count cache/optimizer HBM.
        donate = {"train": (0, 1), "prefill": (2,), "decode": (3,)}[shape.kind]
        # None args are valid empty pytrees under jit
        lowered = jax.jit(fn, donate_argnums=donate).lower(*specs["args"])
    return lowered


def dryrun(arch: str, shape_name: str, *, multi_pod: bool = False, ruleset: str = "default",
           moe_impl: str = None, cap_factor: float = None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if moe_impl:
        cfg = cfg.replace(moe_impl=moe_impl)
    if cap_factor:
        from repro.configs.registry import with_capacity_factor

        cfg = with_capacity_factor(cfg, cap_factor)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {why}")
        return {"arch": arch, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
           "ruleset": ruleset, "chips": chips}
    t0 = time.time()
    try:
        lowered = _lower(cfg, shape, mesh, ruleset)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        from repro.launch.roofline import score_dims_for

        roof = analyze(compiled, chips, score_dims_for(cfg, shape, mesh))
        mf = model_flops(cfg, shape)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            model_flops=mf,
            useful_ratio=(mf / roof.flops if roof.flops else 0.0),
            **roof.as_dict(),
        )
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = str(ma)
        except Exception:
            pass
        if verbose:
            print(
                f"[ok] {arch} x {shape_name} ({rec['mesh']}, {ruleset}): "
                f"compute {roof.t_compute*1e3:.2f}ms memory {roof.t_memory*1e3:.2f}ms "
                f"collective {roof.t_collective*1e3:.2f}ms dominant={roof.dominant} "
                f"useful={rec['useful_ratio']:.2f} hbm_peak={roof.per_device_hbm_peak/2**30:.2f}GiB "
                f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)"
            )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[ERROR] {arch} x {shape_name}: {rec['error']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--ruleset", default="default", choices=sorted(RULESETS))
    ap.add_argument("--moe-impl", default=None, choices=[None, "einsum", "dense", "ep"])
    ap.add_argument("--cap-factor", type=float, default=None)
    ap.add_argument("--train-opt", action="append", default=[],
                    help="enable a steps.TRAIN_OPTS flag (e.g. shard_grads)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.launch.steps import TRAIN_OPTS
    for opt_name in args.train_opt:
        if "=" in opt_name:
            k, v = opt_name.split("=")
            assert k in TRAIN_OPTS, k
            TRAIN_OPTS[k] = int(v)
        else:
            assert opt_name in TRAIN_OPTS, opt_name
            TRAIN_OPTS[opt_name] = True
    if TRAIN_OPTS["bf16_bwd"]:
        from repro.models.transformer import set_bf16_bwd

        set_bf16_bwd(True)
    if args.ruleset == "ep_pod":
        from repro.core.moe_parallel import set_ep_pod

        set_ep_pod(True)

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = dryrun(arch, shape, multi_pod=mp, ruleset=args.ruleset,
                             moe_impl=args.moe_impl, cap_factor=args.cap_factor)
                results.append(rec)
                if args.out:  # checkpoint progress after every pair
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors / {len(results)} pairs")
    if n_err:
        for r in results:
            if r["status"] == "error":
                print(f"  FAILED: {r['arch']} x {r['shape']} ({r['mesh']}): {r['error']}")
        sys.exit(1)


if __name__ == "__main__":
    main()
