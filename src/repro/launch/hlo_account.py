"""Trip-count-aware accounting over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-counts scan-over-layers models by the layer count.  This module parses
``compiled.as_text()`` into computations, multiplies every while body by its
``known_trip_count`` backend-config annotation, and produces:

  * flops            — 2·M·N·K summed over every `dot` (MXU work; elementwise
                       ignored, <1% for transformer workloads)
  * traffic_bytes    — Σ (operand + output bytes) over compute instructions,
                       an XLA-cost-analysis-style HBM traffic proxy
  * collective bytes — per kind (all-gather / all-reduce / reduce-scatter /
                       all-to-all / collective-permute), shapes are already
                       per-participant in SPMD HLO
  * replica-group sizes — to verify the paper's coordinated-a2a claim (group
                       size p/L, not p).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# Bits per element, NOT bytes: s4/u4 buffers pack two elements per byte, so
# byte-granular accounting overstates int4 expert/KV traffic 2x.  Bytes are
# rounded up PER BUFFER (`_buffer_bytes`) — an odd-element int4 tensor pads
# its final byte, matching how XLA sizes the allocation.
_DTYPE_BITS = {
    "f64": 64, "f32": 32, "f16": 16, "bf16": 16, "f8e4m3fn": 8, "f8e5m2": 8,
    "s64": 64, "u64": 64, "s32": 32, "u32": 32, "s16": 16, "u16": 16,
    "s8": 8, "u8": 8, "pred": 8, "c64": 64, "c128": 128, "s4": 4, "u4": 4,
    "token": 0, "opaque": 0,
}


def _buffer_bytes(dtype: str, n_elems: int) -> int:
    return (n_elems * _DTYPE_BITS.get(dtype, 32) + 7) // 8

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "copy-done", "all-gather-done", "all-reduce-done",
    "collective-permute-done", "custom-call",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+([\w\-]+)(?:-start)?\("
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _split_operands(s: str) -> List[str]:
    """Split an HLO operand list at top level.  Modern ``as_text()`` prints
    operands with inline shapes — ``f32[64,64]{1,0} %Arg_0.1, f32[...] %b`` —
    so a naive ``split(",")`` breaks inside the shape brackets / layout
    braces; track bracket depth instead."""
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return [p for p in parts if p]


def _operand_shape(op_text: str, shapes: Dict[str, str]) -> str:
    """Shape string for one operand: the inline ``dtype[dims]`` prefix when
    present (current XLA text format), else a lookup of the bare ``%name``
    in the computation's instruction table (older format)."""
    if _SHAPE_RE.search(op_text):
        return op_text
    name = op_text.split()[-1].lstrip("%") if op_text.split() else ""
    return shapes.get(name, "")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for t, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += _buffer_bytes(t, n)
    return total


def _first_shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",") if d.strip()] if m.group(2).strip() else []
    return m.group(1), dims


@dataclass
class CompStats:
    flops: float = 0.0
    traffic: float = 0.0
    dus_traffic: float = 0.0  # dynamic-update-slice bytes: counted once per
    # enclosing loop nest (in-place on TPU; a scan's slice-writes sum to the
    # full buffer exactly once)
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)
    group_sizes: List[int] = field(default_factory=list)
    # (callee, multiplier)
    calls: List[Tuple[str, float]] = field(default_factory=list)


def _merge(dst: CompStats, src: CompStats, mult: float) -> None:
    dst.flops += src.flops * mult
    dst.traffic += src.traffic * mult
    dst.dus_traffic += src.dus_traffic  # once, not x mult (in-place slices)
    for k, v in src.coll_bytes.items():
        dst.coll_bytes[k] = dst.coll_bytes.get(k, 0.0) + v * mult
    for k, v in src.coll_count.items():
        dst.coll_count[k] = dst.coll_count.get(k, 0) + int(v * mult)
    dst.group_sizes.extend(src.group_sizes)


def _is_score_shape(shape_str: str, score_dims: set) -> bool:
    """Attention score/probs tensors: trailing dim == a KV length, large.
    These live in VMEM inside a fused flash-attention kernel on TPU and are
    excluded from HBM traffic (the q/k/v streaming *is* still counted via
    dot operands, which naturally reproduces flash's K/V re-read traffic)."""
    if not score_dims:
        return False
    _, dims = _first_shape_dims(shape_str)
    if len(dims) < 3:
        return False
    return dims[-1] in score_dims and dims[-2] * dims[-1] >= (1 << 20)


def parse_computations(hlo: str, score_dims: set = frozenset()) -> Dict[str, CompStats]:
    comps: Dict[str, CompStats] = {}
    cur: Optional[CompStats] = None
    shapes: Dict[str, str] = {}
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("->" in line):
            name = hdr.group(1)
            cur = CompStats()
            comps[name] = cur
            shapes = {}
            if raw.lstrip().startswith("ENTRY"):
                entry_name = name
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        iname, shape_str, op = m.group(1), m.group(2), m.group(3)
        shapes[iname] = shape_str
        if op == "parameter":
            continue

        # --- control flow ---
        if op == "while":
            b = _BODY_RE.search(line)
            t = _TRIP_RE.search(line)
            trip = int(t.group(1)) if t else 1
            if b:
                cur.calls.append((b.group(1), float(trip)))
            continue
        if op == "conditional":
            br = _BRANCHES_RE.search(line)
            if br:
                for c in br.group(1).split(","):
                    cur.calls.append((c.strip().lstrip("%"), 1.0))
            continue
        if op in ("call", "fusion", "async-start"):
            c = _CALLS_RE.search(line)
            if c and op == "call":
                cur.calls.append((c.group(1), 1.0))
            # fusions: cost their output+operand traffic below; don't recurse

        # --- collectives ---
        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in _COLL_KINDS:
            nbytes = _shape_bytes(shape_str)
            if base_op == "all-gather" and shape_str.startswith("("):
                # ag tuple = (input, output); count output only (second)
                parts = _SHAPE_RE.findall(shape_str)
                if len(parts) >= 2:
                    t, d = parts[-1]
                    n = 1
                    for x in d.split(","):
                        if x.strip():
                            n *= int(x)
                    nbytes = _buffer_bytes(t, n)
            cur.coll_bytes[base_op] = cur.coll_bytes.get(base_op, 0.0) + nbytes
            cur.coll_count[base_op] = cur.coll_count.get(base_op, 0) + 1
            g = _GROUPS_RE.search(line)
            if g:
                first_group = g.group(1).split("}")[0].strip("{}")
                cur.group_sizes.append(len(first_group.split(",")))
            else:
                gi = _GROUPS_IOTA_RE.search(line)
                if gi:
                    cur.group_sizes.append(int(gi.group(2)))
            cur.traffic += _shape_bytes(shape_str)
            continue

        # --- dots (MXU flops) ---
        if op == "dot":
            _, out_dims = _first_shape_dims(shape_str)
            ops_m = _OPERANDS_RE.search(line[line.index("dot(") :])
            contract = 1
            cm = _CONTRACT_RE.search(line)
            if ops_m and cm:
                operands = _split_operands(ops_m.group(1))
                lhs_shape = _operand_shape(operands[0], shapes) if operands else ""
                _, lhs_dims = _first_shape_dims(lhs_shape)
                for d in cm.group(1).split(","):
                    if d.strip() and int(d) < len(lhs_dims):
                        contract *= lhs_dims[int(d)]
            cur.flops += 2.0 * math.prod(out_dims or [0]) * contract

        # --- traffic (TPU-faithful HBM proxy) ---
        # * dot: OPERANDS only (the streamed weights/activations); outputs
        #   stay in VMEM and are written by the consumer fusion.  Operands
        #   that are attention scores (VMEM-resident in the fused flash
        #   kernel) are excluded; the K/V re-reads per q-chunk remain counted,
        #   which reproduces a flash kernel's actual HBM traffic.
        # * score-shaped outputs (logits/probs): excluded for the same reason
        # * pure dtype-convert / copy / bitcast / transpose fusions: skipped —
        #   on TPU these fold into consumers (the CPU backend materialises
        #   f32 copies of bf16 buffers that a TPU never would)
        # * dynamic-update-slice: in-place on TPU; a scan's slice-writes sum
        #   to the full buffer once (dus_traffic channel)
        # * other compute fusions/ops: output bytes (materialised result)
        if op not in _SKIP_OPS:
            lname = iname.lower()
            is_dus = "dynamic-update-slice" in lname or op == "dynamic-update-slice"
            pure_layout = op == "fusion" and not any(
                t not in ("convert", "copy", "bitcast", "transpose", "broadcast", "reshape", "slice")
                for t in re.findall(r"[a-z\-]+", lname.replace("_fusion", ""))
                if t and t != "fused" and not t.isdigit()
            )
            if op == "dot":
                nbytes = 0
                ops_m = _OPERANDS_RE.search(line[line.index("=") :])
                if ops_m:
                    for otext in _split_operands(ops_m.group(1)):
                        sh = _operand_shape(otext, shapes)
                        if sh and not _is_score_shape(sh, score_dims):
                            nbytes += _shape_bytes(sh)
                cur.traffic += nbytes
            elif _is_score_shape(shape_str, score_dims):
                pass  # VMEM-resident inside the flash attention kernel
            elif is_dus:
                cur.dus_traffic += _shape_bytes(shape_str)
            elif pure_layout:
                pass  # folds on TPU
            else:
                cur.traffic += _shape_bytes(shape_str)

    comps["__entry__"] = comps.get(entry_name, CompStats()) if entry_name else CompStats()
    comps["__entry_name__"] = entry_name  # type: ignore
    return comps


def account(hlo: str, score_dims: set = frozenset()) -> CompStats:
    comps = parse_computations(hlo, score_dims)
    entry_name = comps.pop("__entry_name__", None)  # type: ignore
    comps.pop("__entry__", None)
    memo: Dict[str, CompStats] = {}

    def resolve(name: str, depth: int = 0) -> CompStats:
        if name in memo:
            return memo[name]
        base = comps.get(name)
        out = CompStats()
        if base is None or depth > 50:
            return out
        _merge(out, CompStats(base.flops, base.traffic, base.dus_traffic,
                              dict(base.coll_bytes), dict(base.coll_count),
                              list(base.group_sizes)), 1.0)
        for callee, mult in base.calls:
            _merge(out, resolve(callee, depth + 1), mult)
        memo[name] = out
        return out

    if entry_name is None:
        return CompStats()
    out = resolve(str(entry_name))
    out.traffic += out.dus_traffic
    return out
