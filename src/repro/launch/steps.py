"""Lowered entry points + abstract input specs for the multi-pod dry-run.

For every (architecture × input shape) pair this module provides:
  * ``entry_fn(cfg, kind)``    — the function that gets jitted/lowered
                                 (train_step / prefill_step / decode_step)
  * ``input_specs(cfg, shape, mesh)`` — ShapeDtypeStruct stand-ins with
                                 NamedShardings attached (no allocation).

Train steps are full fwd+bwd+AdamW updates (remat'd scan).  Decode shapes
lower ``decode_step`` with a pre-existing KV cache of shape.seq_len per
DESIGN.md §6 (window layers hold ring caches; SSM/LRU hold O(1) state).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, count_params
from repro.configs.shapes import InputShape
from repro.models.model import decode_step, encode, forward, init_caches, init_params
from repro.parallel.params import batch_pspec, cache_pspecs, param_pspecs
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw
from repro.training.schedule import warmup_cosine
from repro.training.trainer import cross_entropy, moe_aux_coef

# Parameter budget above which optimizer moments are kept in bf16 (a 1T-param
# model's f32 m/v would not fit 512 x 16 GB; bf16 moments are standard
# large-scale practice — recorded as a deliberate deviation in DESIGN.md §9).
_BF16_OPT_THRESHOLD = 2e11


def opt_dtype_for(cfg: ModelConfig):
    return jnp.bfloat16 if count_params(cfg) > _BF16_OPT_THRESHOLD else jnp.float32


# ---------------------------------------------------------------------------
# Entry functions
# ---------------------------------------------------------------------------


# Perf-iteration toggles (EXPERIMENTS.md §Perf); set via dryrun --train-opt.
TRAIN_OPTS = {
    # Constrain per-microbatch grads to the (ZeRO-)sharded accumulator layout
    # so GSPMD emits reduce-scatter per microbatch instead of a full
    # all-reduce followed by a dynamic-slice.
    "shard_grads": False,
    # Cast residual-stream cotangents back to bf16 at layer boundaries
    # (models/transformer.BF16_BWD) — see grad_cast in models/modules.py.
    # NOTE: on the CPU dry-run backend this is invisible in HLO (XLA CPU
    # float-normalization promotes every bf16 op to f32); verified at JAX
    # level by tests/test_training.py::test_grad_cast_dtype.
    "bf16_bwd": False,
    # Gradient-accumulation depth: each microbatch re-gathers the ZeRO-3
    # sharded params, so fewer microbatches = less all-gather traffic at the
    # cost of a larger activation working set.
    "accum_steps": 8,
}


def make_train_entry(cfg: ModelConfig, *, remat: bool = True, accum_steps: int = None):
    if accum_steps is None:
        accum_steps = TRAIN_OPTS["accum_steps"]
    """Full train step: grad accumulation over ``accum_steps`` microbatches
    (keeps per-device activation memory bounded at 4k seq × 256 batch), then
    one AdamW update.  Gradients accumulate in f32."""
    opt = AdamWConfig(lr=1e-4)

    def loss_fn(p, mb):
        memory = encode(cfg, p, mb["source"]) if "source" in mb else None
        logits, aux = forward(
            cfg, p, mb["tokens"], memory=memory,
            prefix_embeds=mb.get("prefix"), remat=remat,
        )
        if "prefix" in mb:
            logits = logits[:, mb["prefix"].shape[1] :]
        ce = cross_entropy(logits, mb["labels"])
        return ce + moe_aux_coef(cfg) * aux, {"ce": ce, "aux": aux}

    def train_step(params, opt_state: AdamWState, batch: dict):
        gb = next(iter(batch.values())).shape[0]
        A = accum_steps if (accum_steps > 1 and gb % accum_steps == 0) else 1
        if A > 1:
            mbs = jax.tree.map(lambda x: x.reshape((A, gb // A) + x.shape[1:]), batch)

            adt = opt_dtype_for(cfg)  # f32 accum; bf16 for ≳200B-param models

            gspecs = None
            if TRAIN_OPTS["shard_grads"]:
                from repro.parallel.params import param_pspecs
                from repro.parallel.sharding import get_mesh

                mesh = get_mesh()
                if mesh is not None:
                    from jax.sharding import NamedSharding

                    pspecs = param_pspecs(mesh, params, mode="train")
                    gspecs = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

            def body(acc, mb):
                g_acc, loss_acc = acc
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                if gspecs is not None:
                    g = jax.tree.map(jax.lax.with_sharding_constraint, g, gspecs)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(adt), g_acc, g)
                return (g_acc, loss_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (grads, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / A, grads)
            loss = loss_sum / A
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        lr_scale = warmup_cosine(opt_state.step, warmup_steps=100, decay_steps=10_000)
        params, opt_state, stats = adamw_update(opt, grads, opt_state, params, lr_scale)
        return params, opt_state, dict(metrics, loss=loss, **stats)

    return train_step


def make_prefill_entry(cfg: ModelConfig):
    from repro.models.model import prefill

    def prefill_step(params, tokens, caches, memory=None, prefix=None):
        return prefill(cfg, params, tokens, caches, memory=memory, prefix_embeds=prefix)

    return prefill_step


def make_decode_entry(cfg: ModelConfig):
    def decode_one(params, token, index, caches, memory=None):
        return decode_step(cfg, params, token, index, caches, memory=memory)

    return decode_one


# ---------------------------------------------------------------------------
# Abstract inputs with shardings
# ---------------------------------------------------------------------------


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _tree_sds(shapes_tree, pspecs_tree, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        shapes_tree,
        pspecs_tree,
    )


def abstract_params(cfg: ModelConfig, mesh, *, mode: str = "serve"):
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return _tree_sds(shapes, param_pspecs(mesh, shapes, mode=mode), mesh)


def abstract_opt_state(cfg: ModelConfig, params_sds, mesh):
    odt = opt_dtype_for(cfg)
    cast = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, odt, sharding=s.sharding), t
    )
    m = cast(params_sds)
    v = cast(params_sds)
    step = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return AdamWState(step, m, v)


def abstract_caches(cfg: ModelConfig, batch: int, capacity: int, mesh, *, cross_len: int = 0):
    shapes = jax.eval_shape(lambda: init_caches(cfg, batch, capacity, cross_len=cross_len))
    return _tree_sds(shapes, cache_pspecs(mesh, shapes, batch), mesh)


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.family == "vlm" and cfg.frontend is not None:
        return max(seq_len - cfg.frontend.n_tokens, 1)
    return seq_len


def input_specs(cfg: ModelConfig, shape: InputShape, mesh) -> dict:
    """Abstract inputs for the entry of ``shape.kind``.  Returns a dict of
    kwargs-by-position used by dryrun.py."""
    GB, S = shape.global_batch, shape.seq_len
    bspec = batch_pspec(mesh, 2, batch_divisible=_batch_divisible(mesh, GB))
    tok = lambda s: _sds((GB, s), jnp.int32, mesh, bspec)

    out = {}
    if shape.kind == "train":
        st = _text_len(cfg, S)
        batch = {"tokens": tok(st), "labels": tok(st)}
        if cfg.family == "vlm":
            fe = cfg.frontend
            batch["prefix"] = _sds(
                (GB, fe.n_tokens, fe.embed_dim), jnp.bfloat16, mesh,
                batch_pspec(mesh, 3, batch_divisible=_batch_divisible(mesh, GB)),
            )
        if cfg.family == "encdec":
            fe = cfg.frontend
            batch["source"] = _sds(
                (GB, fe.n_tokens, fe.embed_dim), jnp.bfloat16, mesh,
                batch_pspec(mesh, 3, batch_divisible=_batch_divisible(mesh, GB)),
            )
        params = abstract_params(cfg, mesh, mode="train")
        out["args"] = (params, abstract_opt_state(cfg, params, mesh), batch)
    elif shape.kind == "prefill":
        st = _text_len(cfg, S)
        params = abstract_params(cfg, mesh)
        caches = abstract_caches(
            cfg, GB, S, mesh, cross_len=(cfg.frontend.n_tokens if cfg.family == "encdec" else 0)
        )
        memory = None
        prefix = None
        if cfg.family == "encdec":
            fe = cfg.frontend
            memory = _sds((GB, fe.n_tokens, cfg.d_model), jnp.bfloat16, mesh,
                          batch_pspec(mesh, 3, batch_divisible=_batch_divisible(mesh, GB)))
        if cfg.family == "vlm":
            fe = cfg.frontend
            prefix = _sds((GB, fe.n_tokens, fe.embed_dim), jnp.bfloat16, mesh,
                          batch_pspec(mesh, 3, batch_divisible=_batch_divisible(mesh, GB)))
        out["args"] = (params, tok(st), caches, memory, prefix)
    else:  # decode
        params = abstract_params(cfg, mesh)
        caches = abstract_caches(
            cfg, GB, S, mesh, cross_len=(cfg.frontend.n_tokens if cfg.family == "encdec" else 0)
        )
        memory = None
        if cfg.family == "encdec":
            fe = cfg.frontend
            memory = _sds((GB, fe.n_tokens, cfg.d_model), jnp.bfloat16, mesh,
                          batch_pspec(mesh, 3, batch_divisible=_batch_divisible(mesh, GB)))
        token = _sds((GB, 1), jnp.int32, mesh, batch_pspec(mesh, 2, batch_divisible=_batch_divisible(mesh, GB)))
        index = _sds((), jnp.int32, mesh, P())
        out["args"] = (params, token, index, caches, memory)
    return out


def _batch_divisible(mesh, batch: int) -> bool:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("pod", 1) * sizes.get("data", 1)
    return batch % dp == 0


def entry_for(cfg: ModelConfig, kind: str):
    if kind == "train":
        return make_train_entry(cfg)
    if kind == "prefill":
        return make_prefill_entry(cfg)
    return make_decode_entry(cfg)
