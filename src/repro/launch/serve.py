"""Serving launcher: loads (or randomly initialises) a model and runs the
batched DS-MoE inference engine over synthetic requests, reporting prefill
and per-token decode latency.

  PYTHONPATH=src python -m repro.launch.serve --arch nlg-350m-moe128 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.registry import get_config, make_reduced
from repro.models.model import init_params
from repro.serving.engine import Engine, EngineConfig, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--moe-impl", default=None, choices=[None, "einsum", "dense", "ep"])
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    if args.moe_impl:
        cfg = cfg.replace(moe_impl=args.moe_impl)

    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        params, _ = ckpt.load(args.ckpt, params)

    ec = EngineConfig(
        max_batch=args.batch,
        max_prefill=args.prompt_len,
        max_decode=args.new_tokens,
        temperature=args.temperature,
    )
    eng = Engine(cfg, params, ec)

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=args.prompt_len).tolist(),
                max_new_tokens=args.new_tokens)
        for _ in range(args.requests)
    ]
    # warmup (compile)
    eng.generate(reqs[: args.batch])
    t0 = time.time()
    responses = eng.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in responses)
    print(f"served {len(responses)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s, arch={cfg.name}, moe_impl={cfg.moe_impl})")
    print("sample:", responses[0].tokens[:10])


if __name__ == "__main__":
    main()
