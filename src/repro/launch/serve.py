"""Serving launcher: loads (or randomly initialises) a model and runs the
DS-MoE inference engine over synthetic requests, reporting prefill and
per-token decode latency.

  PYTHONPATH=src python -m repro.launch.serve --arch nlg-350m-moe128 --reduced

``--paged`` switches to the continuous-batching engine with a paged KV block
pool (serving/kv_pool.py): cache memory becomes a shared pool of
``--page-size``-token pages, requests are admitted by free-block count, and
``--pages`` oversubscribes the pool below the contiguous worst case.
Composes with ``--kv-bits 8`` (int8 pages) and ``--quant-bits``.

``--prefix-sharing`` adds refcounted copy-on-write page sharing: admissions
whose context repeats an indexed full-page prefix point their block tables
at the existing physical pages, and ``--n-samples N`` serves N parallel
samples per prompt off one set of prompt pages (diverging via CoW).

``--prefill-mode batched`` fuses every mid-prefill slot's next chunk into ONE
fixed-shape jitted call per tick (the fused tick: at most one prefill + one
decode dispatch), and ``--moe-impl grouped`` serves the dropless
expert-sorted MoE dispatch — no expert_capacity, no token drops.  The
``serve.jitted_calls_per_tick`` and ``serve.batched_prefill_occupancy``
gauges in the rendered snapshot show both at work.

Observability (docs/OBSERVABILITY.md): the run's SLO histograms (queue-wait,
TTFT, TPOT, tick latency), lifecycle counters, and MoE routing gauges are
printed from one metrics ``snapshot()`` — ``--metrics-out`` appends the SAME
snapshot as a JSON line, so the CLI and the file can never disagree.
``--trace-out`` records the full request lifecycle (queued → prefill
chunk(s) → decode → complete, plus preemption/CoW/prefix-hit instants) as
Chrome ``trace_event`` JSON; load it at https://ui.perfetto.dev.
``--obs-routing`` adds per-decode-tick expert-routing telemetry.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.registry import get_config, make_reduced
from repro.models.model import init_params
from repro.obs import Obs
from repro.serving.engine import Engine, EngineConfig, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "einsum", "dense", "ep", "grouped"],
                    help="MoE dispatch implementation override; 'grouped' is "
                         "the dropless expert-sorted Pallas path (no "
                         "expert_capacity, no token drops)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=0.0)
    ap.add_argument("--quant-bits", type=int, default=0, choices=[0, 4, 8],
                    help="weight-only PTQ before serving (0 = off; MoQ §4)")
    ap.add_argument("--quant-policy", default="experts",
                    choices=["experts", "experts_attn", "all"])
    ap.add_argument("--quant-group-size", type=int, default=0,
                    help="scale group size along the contraction dim, int8 or int4 "
                         "(0 = one scale per output channel)")
    ap.add_argument("--kv-bits", type=int, default=0, choices=[0, 8],
                    help="KV-cache quantization: 8 = int8 cache with per-head, "
                         "per-timestep scales (~4x fewer decode cache bytes), "
                         "0 = full precision; composes with --quant-bits")
    ap.add_argument("--paged", action="store_true",
                    help="serve via the continuous-batching engine with a "
                         "paged KV block pool (admission by free-block count, "
                         "lazy table growth, youngest-slot preemption)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="cache tokens per page for --paged")
    ap.add_argument("--pages", type=int, default=0,
                    help="total pool pages for --paged (0 = auto: "
                         "slots * ceil(capacity / page_size), no oversubscription)")
    ap.add_argument("--slots", type=int, default=0,
                    help="decode slots for --paged (default: --batch)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="with --paged: admission-prefill tokens per engine "
                         "tick (chunked prefill-into-pages; 0 = auto: "
                         "max(64, page_size)).  Long prompts prefill one "
                         "page-aligned chunk per tick interleaved with "
                         "decode, bounding time-to-first-token head-of-line "
                         "blocking; must be >= --page-size")
    ap.add_argument("--prefill-mode", default="chunked",
                    choices=["chunked", "batched", "scatter"],
                    help="with --paged: 'chunked' prefills one slot per tick "
                         "(default), 'batched' fuses ALL mid-prefill slots "
                         "into one fixed-shape jitted call per tick (fused "
                         "tick: at most one prefill + one decode dispatch), "
                         "'scatter' is the legacy non-chunked admission")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="with --paged: refcounted copy-on-write page sharing "
                         "— contexts repeating an indexed full-page prefix "
                         "point their block tables at the existing pages "
                         "(serving/prefix_index.py)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON of the "
                         "request lifecycle (slots, requests, engine ticks) "
                         "to PATH; load in https://ui.perfetto.dev")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="append the final metrics snapshot (counters, "
                         "gauges, SLO histograms) to PATH as one JSON line")
    ap.add_argument("--obs-routing", action="store_true",
                    help="collect per-decode-tick MoE routing telemetry "
                         "(per-expert load, dropped-token fraction, gate "
                         "entropy, f*P imbalance) in the jitted step")
    ap.add_argument("--n-samples", type=int, default=1,
                    help="parallel samples per prompt (paged continuous "
                         "engine); with --prefix-sharing the samples share "
                         "ALL prompt pages and diverge via copy-on-write")
    ap.add_argument("--spec-draft", default=None, metavar="ARCH",
                    help="draft-then-verify speculative decoding "
                         "(serving/spec.py): registry arch name of the dense "
                         "drafter (randomly initialised, --reduced applies), "
                         "or 'self' for the drafter==target oracle.  The "
                         "drafter proposes --spec-k tokens per slot; the "
                         "target verifies all windows in one batched pass "
                         "over CoW page forks.  Greedy-only; needs --paged")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="with --spec-draft: drafted tokens per verify window")
    ap.add_argument("--ep-devices", default=None, metavar="N[xM]",
                    help="expert-parallel serving mesh: '8' shards experts "
                         "flat over 8 devices, '4x2' builds a (hosts, "
                         "devices-per-host) mesh whose MoE exchange is the "
                         "hierarchical two-hop all-to-all (paper Fig. 8). "
                         "Expert weights place per-device, attention runs "
                         "data-parallel over slots; the scheduler stays "
                         "host-side.  CPU testing: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    args = ap.parse_args()
    if args.prefix_sharing and not args.paged:
        ap.error("--prefix-sharing requires --paged (block tables)")
    if args.prefill_chunk and not args.paged:
        ap.error("--prefill-chunk applies to the paged admission path; pass --paged")
    if args.prefill_chunk and args.prefill_chunk < args.page_size:
        ap.error(f"--prefill-chunk {args.prefill_chunk} must be >= --page-size "
                 f"{args.page_size} (chunk boundaries are page-aligned)")
    if args.prefill_mode != "chunked" and not args.paged:
        ap.error(f"--prefill-mode {args.prefill_mode} is an admission policy "
                 "of the paged continuous engine; pass --paged")
    if args.n_samples > 1 and not args.paged:
        ap.error("--n-samples > 1 is served by the paged continuous engine; "
                 "pass --paged")
    if args.n_samples < 1:
        ap.error(f"--n-samples must be >= 1, got {args.n_samples}")
    if args.temperature <= 0.0 and (args.top_k or args.top_p):
        ap.error("--top-k/--top-p have no effect at --temperature 0 (greedy); "
                 "pass --temperature > 0")
    if args.spec_draft:
        if not args.paged:
            ap.error("--spec-draft rides the paged continuous engine "
                     "(CoW page forks); pass --paged")
        if args.temperature > 0.0:
            ap.error("--spec-draft is greedy-only: verification accepts the "
                     "longest draft prefix matching the target's argmax, "
                     "which is exact only at --temperature 0")
        if args.ep_devices:
            ap.error("--spec-draft is not implemented over an "
                     "expert-parallel serving mesh; drop --ep-devices")
        if args.spec_k < 1:
            ap.error(f"--spec-k must be >= 1, got {args.spec_k}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    if args.top_k > cfg.vocab_size:
        ap.error(f"--top-k {args.top_k} exceeds vocab_size {cfg.vocab_size}")
    if args.moe_impl:
        has_moe = any(getattr(ls.ffn, "num_experts", 0)
                      for seg in cfg.segments for ls in seg.pattern)
        if args.moe_impl == "grouped" and not has_moe:
            ap.error(f"--moe-impl grouped: arch '{cfg.name}' has no MoE "
                     "layers to dispatch — pick an MoE arch (e.g. "
                     "nlg-350m-moe128) or drop the flag")
        cfg = cfg.replace(moe_impl=args.moe_impl)
    if args.ep_devices:
        from repro.serving.ep import parse_ep_mesh

        try:
            shape = parse_ep_mesh(args.ep_devices)
        except ValueError as e:
            ap.error(str(e))
        ndev = 1
        for n in shape:
            ndev *= n
        if ndev > len(jax.devices()):
            ap.error(f"--ep-devices {args.ep_devices}: needs {ndev} devices, "
                     f"only {len(jax.devices())} visible (CPU: XLA_FLAGS="
                     f"--xla_force_host_platform_device_count={ndev})")
        cfg = cfg.replace(ep_mesh=shape)

    params = init_params(cfg, jax.random.PRNGKey(0))

    if args.quant_bits:
        from repro.configs.base import QuantConfig
        from repro.quant import quantize_params, quantized_leaf_paths, tree_bytes

        qcfg = QuantConfig(bits=args.quant_bits, group_size=args.quant_group_size,
                           policy=args.quant_policy)
        fp_bytes = tree_bytes(params)
        if args.ckpt:
            # a --ckpt may hold either an already-quantized tree (saved from
            # quantize_params output) or fp weights to PTQ after loading —
            # try the quantized structure first, fall back to fp-then-PTQ.
            try:
                params, _ = ckpt.load(args.ckpt, quantize_params(params, qcfg))
            except ValueError as q_err:
                try:
                    params, _ = ckpt.load(args.ckpt, params)
                except ValueError as fp_err:
                    raise ValueError(
                        f"--ckpt {args.ckpt!r} matches neither the quantized "
                        f"structure for {qcfg} ({q_err}) nor the fp structure "
                        f"({fp_err}); was it saved with different quant "
                        "bits/group_size/policy?"
                    ) from fp_err
                params = quantize_params(params, qcfg)
        else:
            params = quantize_params(params, qcfg)
        if not quantized_leaf_paths(params):
            print(f"WARNING: quant policy '{args.quant_policy}' matched no "
                  f"weights in {cfg.name} (dense arch with an experts-only "
                  "policy?) — serving full precision")
        print(f"PTQ int{args.quant_bits}/{args.quant_policy}: "
              f"{fp_bytes/1e6:.1f}MB -> {tree_bytes(params)/1e6:.1f}MB")
        if cfg.moe_impl == "ep":
            print("NB: under an active mesh the EP shard_map path serves "
                  "materialized fp experts (no memory win; see "
                  "repro.quant.prepare_params_for_serving)")
        if cfg.moe_impl == "grouped" and args.quant_group_size:
            print(f"NB: the grouped Pallas kernel dequantizes per-output-"
                  f"channel scales in VMEM; group_size="
                  f"{args.quant_group_size} scales take the dequant-ref "
                  "path (experts re-widened per call — drop "
                  "--quant-group-size to keep the kernel)")
    elif args.ckpt:
        params, _ = ckpt.load(args.ckpt, params)

    ec = EngineConfig(
        max_batch=args.batch,
        max_prefill=args.prompt_len,
        max_decode=args.new_tokens,
        temperature=args.temperature,
        top_k=args.top_k,
        top_p=args.top_p,
        kv_cache_bits=args.kv_bits,
        page_size=args.page_size if args.paged else 0,
        n_pages=args.pages,
        prefix_sharing=args.prefix_sharing,
        prefill_chunk=args.prefill_chunk,
    )
    obs = Obs(trace=bool(args.trace_out), routing=args.obs_routing)
    eng = None if args.paged else Engine(cfg, params, ec, obs=obs)
    if eng is not None and eng._mesh is not None:
        from repro.serving.ep import placed_param_bytes

        print(f"EP serving mesh {dict(zip(eng._mesh.axis_names, eng._mesh.devices.shape))}: "
              f"moe_impl={eng.cfg.moe_impl}, "
              f"{placed_param_bytes(eng.params)/1e6:.1f}MB params/device")
    if args.kv_bits and eng is not None:
        from repro.models.model import init_caches
        from repro.quant import kv_cache_bytes

        # abstract shapes only — sizing the banner must not allocate caches
        sizes = {
            bits: kv_cache_bytes(jax.eval_shape(
                lambda b=bits: init_caches(cfg, args.batch, eng._capacity,
                                           cross_len=eng._cross_len, kv_bits=b)
            ))
            for bits in (0, args.kv_bits)
        }
        fp_b, q_b = sizes[0], sizes[args.kv_bits]
        print(f"KV cache int{args.kv_bits}: {fp_b/1e6:.2f}MB -> {q_b/1e6:.2f}MB "
              f"({fp_b/q_b:.2f}x fewer decode cache bytes)")

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=args.prompt_len).tolist(),
                max_new_tokens=args.new_tokens)
        for _ in range(args.requests)
    ]

    if args.paged:
        from repro.configs.base import PagedKVConfig
        from repro.models.model import init_caches, init_paged_caches
        from repro.quant import kv_cache_bytes
        from repro.serving.continuous import ContinuousEngine

        # the page knobs ride on EngineConfig (built above) and are handed to
        # the continuous engine as a PagedKVConfig bundle
        pcfg = PagedKVConfig(page_size=ec.page_size, n_pages=ec.n_pages,
                             prefix_sharing=args.prefix_sharing,
                             prefill_chunk=ec.prefill_chunk)
        slots = args.slots or args.batch
        capacity = args.prompt_len + args.new_tokens
        spec_draft = None
        if args.spec_draft:
            if args.spec_draft == "self":
                dcfg, dparams = cfg, params
            else:
                dcfg = get_config(args.spec_draft)
                if args.reduced:
                    dcfg = make_reduced(dcfg)
                if dcfg.vocab_size != cfg.vocab_size:
                    ap.error(f"--spec-draft {args.spec_draft}: drafter vocab "
                             f"{dcfg.vocab_size} != target vocab "
                             f"{cfg.vocab_size} — greedy verification needs a "
                             "shared token space")
                dparams = init_params(dcfg, jax.random.PRNGKey(1))
            spec_draft = (dcfg, dparams)
        ceng = ContinuousEngine(
            cfg, params, slots=slots, capacity=capacity,
            temperature=ec.temperature, top_k=ec.top_k, top_p=ec.top_p,
            kv_cache_bits=ec.kv_cache_bits, paged_cfg=pcfg, obs=obs,
            prefill_mode=args.prefill_mode,
            spec_draft=spec_draft, spec_k=args.spec_k,
        )
        if spec_draft is not None:
            print(f"speculative decoding: drafter={spec_draft[0].name}"
                  f"{' (self)' if args.spec_draft == 'self' else ''}, "
                  f"k={args.spec_k} drafted tokens per verify window")
        contig_b = kv_cache_bytes(jax.eval_shape(
            lambda: init_caches(cfg, slots, capacity, kv_bits=args.kv_bits)))
        paged_b = kv_cache_bytes(jax.eval_shape(
            lambda: init_paged_caches(cfg, slots, capacity, n_pages=ceng.n_pages,
                                      page_size=ceng.page_size, kv_bits=args.kv_bits)))
        print(f"paged pool: {ceng.n_pages} pages x {ceng.page_size} tokens "
              f"({paged_b/1e6:.2f}MB) vs contiguous {slots} x {capacity} "
              f"({contig_b/1e6:.2f}MB)")
        if ceng._mesh is not None:
            from repro.serving.ep import placed_param_bytes

            print(f"EP serving mesh "
                  f"{dict(zip(ceng._mesh.axis_names, ceng._mesh.devices.shape))}: "
                  f"moe_impl={ceng.cfg.moe_impl}, "
                  f"{placed_param_bytes(ceng.params)/1e6:.1f}MB params/device")
        # warmup (compile prefill + decode; the request completes, so the
        # pool and metrics window start clean apart from the tick counter)
        ceng.submit(Request(prompt=reqs[0].prompt, max_new_tokens=2))
        ceng.run_until_done()
        ceng.done.clear()
        ceng.preemptions = 0
        ceng.prefill_tokens_total = 0
        ceng.prefill_tokens_skipped = 0
        ceng.metrics_log.clear()
        obs.metrics.reset_all()  # drop warmup/compile samples from the window
        t0 = time.time()
        if args.n_samples > 1:
            ids = [rid for r in reqs for rid in ceng.submit_n(r, args.n_samples)]
        else:
            ids = [ceng.submit(r) for r in reqs]
        done = ceng.run_until_done()
        dt = time.time() - t0
        n_tok = sum(len(done[i].tokens) for i in ids)
        print(f"served {len(ids)} requests, {n_tok} tokens in {dt:.2f}s "
              f"({n_tok/dt:.1f} tok/s, arch={cfg.name}, paged, "
              f"prefill_mode={ceng.prefill_mode})")
        if ceng.drafter is not None:
            sp = [m["spec"] for m in ceng.metrics_log if "spec" in m]
            drafted = sum(s["drafted"] for s in sp)
            accepted = sum(s["accepted"] for s in sp)
            windows = sum(s["windows"] for s in sp)
            emitted = sum(s["emitted"] for s in sp)
            print(f"speculation: {emitted} tokens / {windows} verify passes "
                  f"= {emitted/max(windows,1):.2f} tok/verify "
                  f"(accept rate {accepted/max(drafted,1):.2f}, "
                  f"k={ceng.spec_k})")
        # everything below — preemptions, page occupancy, prefix-sharing
        # hits/CoW, chunked-prefill split, SLO percentiles — renders from
        # the ONE snapshot that --metrics-out also writes
        print(obs.metrics.render(prefix="  "))
        if args.metrics_out:
            obs.metrics.write_jsonl(args.metrics_out, extra={
                "arch": cfg.name, "paged": True, "requests": len(ids),
                "tokens": n_tok, "wall_s": dt,
                "prefill_mode": ceng.prefill_mode,
            })
            print(f"metrics snapshot -> {args.metrics_out}")
        if args.trace_out:
            obs.tracer.export(args.trace_out)
            print(f"trace ({obs.tracer.n_events} events) -> {args.trace_out}; "
                  "load in https://ui.perfetto.dev")
        print("sample:", done[ids[0]].tokens[:10])
        return

    # warmup (compile)
    eng.generate(reqs[: args.batch])
    obs.metrics.reset_all()  # drop warmup/compile samples from the window
    t0 = time.time()
    responses = eng.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in responses)
    print(f"served {len(responses)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s, arch={cfg.name}, moe_impl={cfg.moe_impl})")
    print(obs.metrics.render(prefix="  "))
    if args.metrics_out:
        obs.metrics.write_jsonl(args.metrics_out, extra={
            "arch": cfg.name, "paged": False, "requests": len(responses),
            "tokens": n_tok, "wall_s": dt,
        })
        print(f"metrics snapshot -> {args.metrics_out}")
    if args.trace_out:
        obs.tracer.export(args.trace_out)
        print(f"trace ({obs.tracer.n_events} events) -> {args.trace_out}; "
              "load in https://ui.perfetto.dev")
    print("sample:", responses[0].tokens[:10])


if __name__ == "__main__":
    main()
