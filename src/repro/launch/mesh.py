"""Production meshes.  A FUNCTION (not module-level constant) so importing
never touches jax device state.  Single pod: (data=16, model=16) = 256 chips
of TPU v5e; multi-pod adds a leading 'pod' axis (2 pods = 512 chips)."""
from __future__ import annotations

from repro.parallel.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for subprocess multi-device tests (8 host devices)."""
    return make_mesh(shape, axes)


# TPU v5e hardware constants (roofline):
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per-chip usable for collectives, 1 link)
