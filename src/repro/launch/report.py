"""Render the dry-run JSON into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.launch.report results/baseline_dryrun.json
"""
from __future__ import annotations

import json
import sys


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1.0:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def table(results, mesh: str) -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | useful (6ND/HLO) | "
        "HBM peak/dev | max coll. group |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        c = r.get("collectives", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} "
            f"| {fmt_s(r['t_collective_s'])} | **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['per_device_hbm_peak']/2**30:.1f}GiB | {c.get('max_group', '—')} |"
        )
    return "\n".join(rows)


def bottleneck_notes(results) -> str:
    out = []
    for r in results:
        if r.get("mesh") != "single" or r["status"] != "ok":
            continue
        dom = r["dominant"]
        if dom == "memory":
            note = "shrink traffic: lower-precision reads / better fusion / smaller replication"
        elif dom == "collective":
            note = "re-schedule comms: reduce-scatter grads, coordinated a2a, overlap"
        else:
            note = "compute-bound: near roofline, improve MXU utilization via tiling"
        out.append(f"- **{r['arch']} × {r['shape']}**: dominant={dom} -> {note}")
    return "\n".join(out)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/baseline_dryrun.json"
    with open(path) as f:
        results = json.load(f)
    print("### Single-pod mesh (16x16 = 256 chips)\n")
    print(table(results, "single"))
    print("\n### Multi-pod mesh (2x16x16 = 512 chips)\n")
    print(table(results, "multi"))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\n{n_ok} ok / {n_skip} documented skips / {len(results)} pairs.")


if __name__ == "__main__":
    main()
