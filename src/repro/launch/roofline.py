"""Roofline-term extraction from compiled dry-run artifacts.

  compute    = HLO_FLOPs / (chips × 197e12)
  memory     = HLO_bytes / (chips × 819e9)
  collective = collective_bytes / (chips × 50e9)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are NOT
in cost_analysis — we parse the post-SPMD HLO (``compiled.as_text()``) and sum
the output-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, attributing per-chip bytes (each collective's
reported shape is already the per-participant shard in SPMD HLO).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

# bits per element (s4/u4 pack two per byte); bytes rounded up per buffer
_DTYPE_BITS = {
    "f64": 64, "f32": 32, "f16": 16, "bf16": 16, "f8e4m3fn": 8, "f8e5m2": 8,
    "s64": 64, "u64": 64, "s32": 32, "u32": 32, "s16": 16, "u16": 16,
    "s8": 8, "u8": 8, "pred": 8, "c64": 64, "c128": 128, "s4": 4, "u4": 4,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# e.g.:  %x = bf16[8,128,256]{2,1,0} all-to-all(...), replica_groups={{0,1},{2,3}}
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return (n * _DTYPE_BITS.get(dtype, 32) + 7) // 8


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    group_sizes: List[int] = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(4)
        if "-done" in line.split("=")[1][:60]:
            continue
        if m.group(1) is not None:  # tuple shape
            nbytes = sum(_shape_bytes(t, d) for t, d in _SHAPE_RE.findall(m.group(1)))
        else:
            nbytes = _shape_bytes(m.group(2), m.group(3))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
        g = _GROUPS_RE.search(line)
        if g:
            stats.group_sizes.append(len(g.group(1).split(",")))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                stats.group_sizes.append(int(gi.group(2)))
    return stats


@dataclass
class Roofline:
    flops: float  # total HLO flops (whole program, all chips)
    hbm_bytes: float  # cost_analysis 'bytes accessed' (per-chip program)
    collective_bytes: float  # per-chip collective bytes
    chips: int
    per_device_hbm_peak: float  # from memory_analysis
    stats: CollectiveStats

    @property
    def t_compute(self) -> float:
        return self.flops / self.chips / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory, "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "per_device_hbm_peak": self.per_device_hbm_peak,
            "collectives": {
                "bytes_by_kind": self.stats.bytes_by_kind,
                "count_by_kind": self.stats.count_by_kind,
                "max_group": max(self.stats.group_sizes or [1]),
            },
        }


def score_dims_for(cfg, shape, mesh) -> set:
    """KV-length dims identifying attention score tensors (excluded from HBM
    traffic: VMEM-resident inside the fused flash-attention kernel)."""
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    dims = {shape.seq_len, shape.seq_len // tp}
    from repro.configs.base import AttnSpec

    specs = list(cfg.layer_specs())
    if cfg.encoder is not None:
        for seg in cfg.encoder.segments:
            specs.extend(seg.pattern)
    for ls in specs:
        for m in (ls.mixer, ls.cross):
            if isinstance(m, AttnSpec) and m.window:
                w_pad = -(-m.window // 1024) * 1024
                dims.update({m.window, w_pad, w_pad + 1024, min(m.window, shape.seq_len)})
    if cfg.frontend is not None:
        dims.add(cfg.frontend.n_tokens)
    return {d for d in dims if d >= 512}


def analyze(compiled, chips: int, score_dims: set = frozenset()) -> Roofline:
    """Roofline terms via trip-count-aware HLO accounting (hlo_account.py).
    XLA's cost_analysis counts while bodies once, so scan-over-layers models
    would be undercounted by ~the layer count; we parse the scheduled HLO and
    multiply loop bodies by their known_trip_count instead."""
    from repro.launch.hlo_account import account

    acct = account(compiled.as_text(), score_dims)
    try:
        ma = compiled.memory_analysis()
        peak = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        peak = float("nan")
    stats = CollectiveStats(
        bytes_by_kind={k: int(v) for k, v in acct.coll_bytes.items()},
        count_by_kind=dict(acct.coll_count),
        group_sizes=list(acct.group_sizes),
    )
    return Roofline(
        flops=acct.flops * chips,  # per-chip dot flops -> global
        hbm_bytes=acct.traffic,  # per-chip traffic proxy (operands+outputs)
        collective_bytes=float(stats.total_bytes),
        chips=chips,
        per_device_hbm_peak=peak,
        stats=stats,
    )


def model_flops(cfg, shape, *, active: bool = True) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference (N = activated
    params for MoE — the paper's critical-path measure)."""
    from repro.configs.base import count_active_params, count_params

    n = count_active_params(cfg) if active else count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per row
