"""Training launcher.

On real TPU hardware this drives the full production configs through the
pjit train step with the DESIGN.md §4 sharding; on CPU (this container) use
``--reduced`` for smoke-scale runs.  Example:

  PYTHONPATH=src python -m repro.launch.train --arch nlg-350m-moe128 \
      --reduced --steps 100 --batch 16 --seq 128
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.checkpoint import ckpt
from repro.configs.registry import get_config, make_reduced
from repro.data.pipeline import data_stream
from repro.training.trainer import TrainConfig, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="2-layer tiny variant (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vocab", type=int, default=0, help="override vocab (synthetic data)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--moe-impl", default=None, choices=[None, "einsum", "dense", "ep"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    if args.vocab:
        cfg = cfg.replace(vocab_size=args.vocab)
    if args.moe_impl:
        cfg = cfg.replace(moe_impl=args.moe_impl)

    tc = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1), decay_steps=args.steps)
    it = data_stream(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
    params, opt_state, history = train_loop(cfg, tc, it, args.steps, seed=args.seed)

    if args.ckpt_dir:
        ckpt.save(os.path.join(args.ckpt_dir, "params"), params, step=args.steps)
        with open(os.path.join(args.ckpt_dir, "history.json"), "w") as f:
            json.dump(history, f, indent=1)
        print(f"saved checkpoint to {args.ckpt_dir}")
    print(f"final loss: {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
