"""Trace-time static analysis suite (src/repro/analysis/):

* golden lint fixtures — each trips exactly one rule, the clean fixture
  trips none, the pragma fixture is suppressed-not-active;
* donation/aliasing audit — aliases verified in the lowered module, the
  deleted-donation mutation caught, the pruned-unused-arg index mapping
  regression, and the host-side rebind audit;
* compile-shape contracts — chunk arithmetic, primary-singleton, trace and
  closure failures on synthetic entries, green on the real engine;
* predicted-vs-observed compile parity: ``predict_compiles`` equals the
  retrace watchdog's per-function cache sizes after a real engine run;
* graph audits — stray collectives, int8->f32 drift, capacity dead compute;
* the int4 fractional-byte HLO accounting regression.
"""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    ContractEntry,
    Report,
    Workload,
    audit_donated_rebinds,
    audit_donation,
    audit_dtype_drift,
    audit_graph,
    capacity_dead_compute,
    check_closure,
    check_contract,
    chunk_lengths,
    predict_compiles,
)
from repro.analysis.graph import audit_collectives, audit_dead_compute
from repro.analysis.lint import LintConfig, lint_source
from repro.configs.registry import all_configs, make_reduced
from repro.launch.analyze import build_engines
from repro.launch.hlo_account import _shape_bytes, account
from repro.models.model import init_params
from repro.obs.retrace import jit_cache_size
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine, EngineConfig, Request

FIXDIR = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
SDS = jax.ShapeDtypeStruct


def _lint_fixture(fname: str, relpath: str = None) -> Report:
    with open(os.path.join(FIXDIR, fname)) as f:
        src = f.read()
    # fixtures are linted AS IF they lived in a hot+traced module so every
    # rule is active at error severity
    return lint_source(src, relpath or f"models/{fname}")


class TestLintFixtures:
    @pytest.mark.parametrize("fname,rule", [
        ("host_item.py", "host-item"),
        ("host_cast.py", "host-cast"),
        ("host_asarray.py", "host-asarray"),
        ("tracer_branch.py", "tracer-branch"),
        ("debug_call.py", "debug-call"),
        ("block_sync.py", "block-sync"),
    ])
    def test_fixture_trips_exactly_one_rule(self, fname, rule):
        rep = _lint_fixture(fname)
        assert [f.rule for f in rep.active()] == [rule], rep.render()
        assert rep.active()[0].severity == "error"

    def test_clean_fixture_trips_nothing(self):
        rep = _lint_fixture("clean.py")
        assert not rep.findings, rep.render()

    def test_pragma_suppresses_but_stays_visible(self):
        rep = _lint_fixture("pragma_ok.py")
        assert not rep.active(), rep.render()
        assert [f.rule for f in rep.findings] == ["host-asarray"]
        assert rep.findings[0].suppressed

    def test_severity_follows_module_map(self):
        with open(os.path.join(FIXDIR, "host_cast.py")) as f:
            src = f.read()
        assert _lint_fixture("host_cast.py", "serving/x.py").errors
        cold = lint_source(src, "launch/x.py")
        assert not cold.errors and [f.rule for f in cold.warnings] == ["host-cast"]
        # tracer-branch only applies to traced modules; serving is hot but
        # hosts the scheduler (Python control flow on host state is its job)
        with open(os.path.join(FIXDIR, "tracer_branch.py")) as f:
            tb = f.read()
        assert not lint_source(tb, "serving/x.py").findings


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = make_reduced(all_configs()["glm4-9b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ContinuousEngine(cfg, params, slots=2, capacity=64, paged=True,
                            page_size=16, prefix_sharing=True)


class TestDonationAudit:
    def test_honored_donation_is_clean(self):
        caches = {"k": SDS((8, 8), jnp.float32), "pos": SDS((8,), jnp.int32)}
        jf = jax.jit(lambda c, x: {"k": c["k"] + x, "pos": c["pos"] + 1},
                     donate_argnums=(0,))
        rep = audit_donation("f", jf, (caches, SDS((), jnp.float32)), (0,))
        assert not rep.errors, rep.render()
        assert rep.metrics["donation.f.aliased"] == "2/2"

    def test_pruned_unused_arg_does_not_shift_mapping(self):
        """jit(keep_unused=False) drops unread flat args from the lowered
        module; the audit must map donated leaves through kept_var_idx, not
        raw flat positions (regression: the bool mask below is pruned and
        used to shift every cache leaf's alias lookup by one)."""
        caches = {"k": SDS((4, 4), jnp.float32), "pos": SDS((4,), jnp.int32)}
        jg = jax.jit(lambda mask, c: {"k": c["k"] * 2.0, "pos": c["pos"] + 1},
                     donate_argnums=(1,))
        rep = audit_donation("g", jg, (SDS((4,), jnp.bool_), caches), (1,))
        assert not rep.errors, rep.render()

    def test_engine_decode_donation_honored(self, tiny_engine):
        entry = {e.name: e for e in tiny_engine.shape_contract()}["decode"]
        fn, don, _ = tiny_engine.jitted_functions()["decode"]
        rep = audit_donation("decode", fn, entry.make(*entry.sample[-1]), don)
        assert not rep.errors, rep.render()

    def test_deleted_donation_mutation_caught(self, tiny_engine):
        """Mutation: re-jit the engine's decode WITHOUT its donate_argnums
        entry while the registry still declares it — the auditor must fail."""
        entry = {e.name: e for e in tiny_engine.shape_contract()}["decode"]
        fn, don, _ = tiny_engine.jitted_functions()["decode"]
        mutated = jax.jit(lambda *a: fn(*a))  # donation entry deleted
        rep = audit_donation("decode", mutated, entry.make(*entry.sample[-1]), don)
        assert any(f.rule == "donation-dropped" for f in rep.errors), rep.render()

    def test_rebind_audit(self):
        good = (
            "class E:\n"
            "    def step(self):\n"
            "        logits, self.caches, r = self._decode(p, t, self.caches)\n"
        )
        rep = audit_donated_rebinds(good, "serving/x.py", {"_decode": 2})
        assert not rep.errors, rep.render()

        bad = (
            "class E:\n"
            "    def step(self):\n"
            "        out = self._decode(p, t, self.caches)\n"
        )
        rep = audit_donated_rebinds(bad, "serving/x.py", {"_decode": 2})
        assert [f.rule for f in rep.errors] == ["donation-host-read"]

        arity = "class E:\n    def step(self):\n        x = self._decode(p)\n"
        rep = audit_donated_rebinds(arity, "serving/x.py", {"_decode": 2})
        assert [f.rule for f in rep.errors] == ["donation-arity"]


class TestContracts:
    def test_chunk_lengths(self):
        assert chunk_lengths(100, 0, 64, 16) == [64]
        assert chunk_lengths(100, 96, 64, 16) == [4]  # final, unaligned OK
        assert chunk_lengths(10, 0, 64, 16) == [10]
        # sub-page leftover budget defers to the next tick
        assert chunk_lengths(100, 0, 20, 16) == [16]
        for ctx in (1, 15, 16, 17, 63, 64, 65, 100):
            for start in range(0, ctx, 16):
                out = chunk_lengths(ctx, start, 32, 16)
                assert sum(out) <= 32
                for n in out[:-1]:  # every non-final chunk page-aligned
                    assert (start + sum(out[:out.index(n) + 1])) % 16 == 0

    def test_primary_must_be_singleton(self):
        e = ContractEntry(
            name="decode", fn=lambda x: x + 1,
            make=lambda n: (SDS((n,), jnp.float32),),
            points=((4,), (8,)), sample=((4,),), primary=True)
        rep = check_contract([e])
        assert [f.rule for f in rep.errors] == ["contract-open"]

    def test_untraceable_signature_flagged(self):
        e = ContractEntry(
            name="bad", fn=lambda x: x @ x,
            make=lambda: (SDS((3, 4), jnp.float32),),
            points=((),), sample=((),))
        rep = check_contract([e])
        assert [f.rule for f in rep.errors] == ["contract-trace-failed"]

    def test_infeasible_donation_flagged(self):
        e = ContractEntry(
            name="upcast", fn=lambda c: c.astype(jnp.float32),
            make=lambda: (SDS((8,), jnp.int8),),
            points=((),), sample=((),), donate_argnums=(0,))
        rep = check_contract([e])
        assert [f.rule for f in rep.errors] == ["contract-donation-infeasible"]

    def test_closure_escape(self):
        e = ContractEntry(
            name="prefill_chunk_first", fn=lambda x: x,
            make=lambda n: (SDS((1, n), jnp.int32),),
            points=((16,), (32,)), sample=((16,),))
        rep = check_closure([e], capacity=64, page_size=16, prefill_chunk=32,
                            workload=Workload((7,), 4, 8))
        assert any(f.rule == "contract-escape" for f in rep.errors), rep.render()

    def test_engine_contract_green(self, tiny_engine):
        entries = tiny_engine.shape_contract()
        rep = check_contract(entries)
        check_closure(entries, capacity=tiny_engine.capacity,
                      page_size=tiny_engine.page_size,
                      prefill_chunk=tiny_engine.prefill_chunk,
                      workload=Workload((5, 20), 4, 32), report=rep)
        assert not rep.errors, rep.render()

    def test_predict_compiles_obs_scenario(self):
        """The benchmarks/run.py obs workload: 4x len-16 prompts, 47 ticks of
        long decodes — exactly one decode compile, one chunk compile, zero
        everything else (no completions inside the run)."""
        pred = predict_compiles(
            slots=4, capacity=256, page_size=16, prefill_chunk=64,
            workload=Workload((16, 16, 16, 16), 236, 47))
        assert pred == {"decode": 1, "prefill": 0, "prefill_chunk_first": 1,
                        "prefill_chunk_cont": 0, "reset_pages": 0,
                        "copy_slot": 0, "copy_page": 0}

    def test_predicted_equals_observed_compiles(self):
        """The acceptance contract: the static prediction must equal the
        retrace watchdog's observed per-function compile counts on a real
        engine run (fresh engine, mixed prompt lengths, completions)."""
        cfg = make_reduced(all_configs()["glm4-9b"])
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = ContinuousEngine(cfg, params, slots=2, capacity=64, paged=True,
                               page_size=16, prefix_sharing=True,
                               prefill_chunk=32)
        prompts = [[(i % 50) + 1 for i in range(n)] for n in (5, 20)]
        for p in prompts:
            eng.submit(Request(prompt=p, max_new_tokens=4))
        eng.run_until_done()
        observed = {name: jit_cache_size(fn) or 0
                    for name, (fn, _, _) in eng.jitted_functions().items()}
        pred = predict_compiles(slots=2, capacity=64, page_size=16,
                                prefill_chunk=32,
                                workload=Workload((5, 20), 4, 32))
        assert observed == pred, (observed, pred)
        assert eng.obs.watchdog.snapshot()["steady_retraces"] == 0

    def test_predicted_equals_observed_compiles_batched(self):
        """Same acceptance contract for the fused tick: in
        ``prefill_mode="batched"`` the chunk family collapses to ONE
        fixed-shape entry that compiles exactly once, and the prediction's
        key set swaps accordingly (no first/cont keys at all)."""
        cfg = make_reduced(all_configs()["glm4-9b"])
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = ContinuousEngine(cfg, params, slots=3, capacity=64, paged=True,
                               page_size=16, prefix_sharing=True,
                               prefill_chunk=32, prefill_mode="batched")
        prompts = [[(i % 50) + 1 for i in range(n)] for n in (5, 20, 40)]
        for p in prompts:
            eng.submit(Request(prompt=p, max_new_tokens=4))
        eng.run_until_done()
        observed = {name: jit_cache_size(fn) or 0
                    for name, (fn, _, _) in eng.jitted_functions().items()}
        pred = predict_compiles(slots=3, capacity=64, page_size=16,
                                prefill_chunk=32,
                                workload=Workload((5, 20, 40), 4, 32),
                                prefill_mode="batched")
        assert "prefill_chunk_batched" in pred
        assert "prefill_chunk_first" not in pred
        assert pred["prefill_chunk_batched"] == 1
        assert observed == pred, (observed, pred)
        assert eng.obs.watchdog.snapshot()["steady_retraces"] == 0

    def test_predicted_equals_observed_compiles_spec(self):
        """The acceptance contract for speculative decoding: with the
        self-draft oracle (accept pattern fully determined: every window
        fully accepts, no rollbacks) the fused-tick + spec prediction —
        decode never dispatched, one compile each for verify / propose /
        reset-tail, one drafter prefill per distinct context length — must
        equal the observed per-function cache sizes, with zero steady-state
        retraces."""
        cfg = make_reduced(all_configs()["glm4-9b"])
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = ContinuousEngine(cfg, params, slots=3, capacity=64, paged=True,
                               page_size=16, prefix_sharing=True,
                               prefill_chunk=32, prefill_mode="batched",
                               spec_draft=(cfg, params), spec_k=3)
        prompts = [[(i % 50) + 1 for i in range(n)] for n in (5, 20, 40)]
        for p in prompts:
            eng.submit(Request(prompt=p, max_new_tokens=6))
        eng.run_until_done()
        observed = {name: jit_cache_size(fn) or 0
                    for name, (fn, _, _) in eng.jitted_functions().items()}
        pred = predict_compiles(slots=3, capacity=64, page_size=16,
                                prefill_chunk=32,
                                workload=Workload((5, 20, 40), 6, 32),
                                prefill_mode="batched",
                                spec={"commit_pass":
                                      eng._spec_commit is not None})
        assert pred["decode"] == 0  # registered, never dispatched
        assert pred["verify"] == pred["draft_propose"] == 1
        assert pred["spec_reset_tail"] == 1
        assert "spec_commit" not in pred  # glm4 is fully paged
        assert pred["draft_prefill"] == 3  # one per distinct context length
        assert observed == pred, (observed, pred)
        assert eng.obs.watchdog.snapshot()["steady_retraces"] == 0

    def test_watchdog_registry_matches_contract(self, tiny_engine):
        """One source of truth: the watchdog's primary classification equals
        the jit registry's, and every contract entry agrees."""
        wd = tiny_engine.obs.watchdog.registry()
        reg = {n: primary for n, (_, _, primary) in
               tiny_engine.jitted_functions().items()}
        assert wd == reg
        for e in tiny_engine.shape_contract():
            assert e.primary == reg[e.name], e.name


class TestGraphAudit:
    def test_stray_collective_detected(self):
        f = jax.pmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")
        closed = jax.make_jaxpr(f)(jnp.ones((1, 4)))
        rep = audit_collectives(closed, "toy")
        assert [f_.rule for f_ in rep.errors] == ["stray-collective"]

    def test_single_device_graph_clean(self):
        closed = jax.make_jaxpr(lambda x: x @ x)(SDS((8, 8), jnp.float32))
        rep = audit_collectives(closed, "mm")
        audit_dtype_drift(closed, "mm", rep)
        assert not rep.findings, rep.render()

    def test_dtype_drift_threshold(self):
        deq = lambda q: q.astype(jnp.float32) * 0.1
        big = jax.make_jaxpr(deq)(SDS((64, 128), jnp.int8))
        rep = audit_dtype_drift(big, "big")
        assert [f.rule for f in rep.errors] == ["dtype-drift"]
        small = jax.make_jaxpr(deq)(SDS((8,), jnp.int8))
        rep = audit_dtype_drift(small, "small")
        assert not rep.findings
        # int32 position math is exempt by design
        pos = jax.make_jaxpr(lambda i: i.astype(jnp.float32))(
            SDS((64, 128), jnp.int32))
        assert not audit_dtype_drift(pos, "pos").findings

    def test_capacity_dead_compute_math(self):
        st = capacity_dead_compute(64, 4, 2, 2.0)
        assert st["capacity"] == 64 and st["slots"] == 256
        assert st["padded_fraction"] == pytest.approx(0.5)

    def test_expert_dot_capacity_crosscheck(self):
        E, C, d, f = 4, 8, 16, 32
        experts = lambda x, w: jnp.einsum("ecd,edf->ecf", x, w)
        closed = jax.make_jaxpr(experts)(
            SDS((E, C, d), jnp.float32), SDS((E, d, f), jnp.float32))
        # T=16, k=1, cf=2.0 -> cap = int(2*16*1/4) = 8 == C: consistent
        rep = audit_dead_compute(closed, "moe", num_tokens=16, num_experts=E,
                                 top_k=1, capacity_factor=2.0)
        assert not rep.errors
        assert [f_.rule for f_ in rep.active("info")] == ["capacity-padding"]
        assert rep.metrics["graph.moe.expert_dots"] == 1
        # T=32 -> analytic cap 16 != graph's 8: the contract and graph disagree
        rep = audit_dead_compute(closed, "moe2", num_tokens=32, num_experts=E,
                                 top_k=1, capacity_factor=2.0)
        assert [f_.rule for f_ in rep.errors] == ["capacity-mismatch"]

    def test_engine_decode_graph_clean(self, tiny_engine):
        entry = {e.name: e for e in tiny_engine.shape_contract()}["decode"]
        rep = audit_graph("decode", entry.fn, entry.make(*entry.sample[-1]))
        assert not rep.errors, rep.render()

    def test_expect_collectives(self):
        """The EP inverse of the stray-collective check: a multi-device MoE
        serving graph with NO communication primitive means the shard_map
        exchange silently traced away."""
        f = jax.pmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")
        rep = audit_graph("ep", f, (jnp.ones((1, 4)),), single_device=False,
                          expect_collectives=True)
        assert not rep.errors, rep.render()
        assert rep.metrics["graph.ep.collectives"] == 1
        rep = audit_graph("ep0", lambda x: x + 1, (jnp.ones((4,)),),
                          single_device=False, expect_collectives=True)
        assert [f_.rule for f_ in rep.errors] == ["missing-collective"]
        # multi-device without the expectation (dense arch): just the metric
        rep = audit_graph("ep1", lambda x: x + 1, (jnp.ones((4,)),),
                          single_device=False)
        assert not rep.errors and rep.metrics["graph.ep1.collectives"] == 0

    def test_ep_dead_compute_skips_full_e_crosscheck(self):
        """Under ``impl="ep_serve"`` the expert dots run per-shard inside
        shard_map ([E_local, C] buffers), so a leading-dim==E scan would only
        catch unrelated batch dots (e.g. attention over n_slots == E) — the
        audit must report the analytic padding and skip the cross-check."""
        E, C, d, f = 4, 8, 16, 32
        experts = lambda x, w: jnp.einsum("ecd,edf->ecf", x, w)
        closed = jax.make_jaxpr(experts)(
            SDS((E, C, d), jnp.float32), SDS((E, d, f), jnp.float32))
        # same graph/arithmetic that trips capacity-mismatch under "einsum"
        # (T=32 -> analytic cap 16 != graph's 8) stays clean under EP
        rep = audit_dead_compute(closed, "ep", num_tokens=32, num_experts=E,
                                 top_k=1, capacity_factor=2.0, impl="ep_serve")
        assert not rep.errors, rep.render()
        assert [f_.rule for f_ in rep.active("info")] == ["capacity-padding"]
        assert rep.metrics["graph.ep.expert_dots"] == 0


INT4_HLO = """\
HloModule int4_regression

ENTRY %main (p0: s4[64,128]) -> s4[64,128] {
  %p0 = s4[64,128]{1,0} parameter(0)
  ROOT %neg.1 = s4[64,128]{1,0} negate(%p0)
}
"""


class TestInt4Accounting:
    def test_shape_bytes_subbyte(self):
        assert _shape_bytes("s4[64,128]") == 64 * 128 // 2  # was 2x this
        assert _shape_bytes("u4[64,128]") == 64 * 128 // 2
        assert _shape_bytes("s4[5]") == 3  # odd element count rounds up
        assert _shape_bytes("u4[3,3]") == 5
        assert _shape_bytes("s8[64,128]") == 64 * 128
        assert _shape_bytes("f32[4]") == 16

    def test_int4_hlo_traffic(self):
        st = account(INT4_HLO)
        # the negate materializes one s4[64,128] buffer: 4096 bytes, not 8192
        assert st.traffic == 64 * 128 // 2, st.traffic


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(all_configs()))
def test_contract_checker_whole_registry(arch):
    """Every registry entry's declared compile-shape contract abstract-traces
    clean (continuous paged + static engines; encoder-decoder archs go
    through the static engine with synthesized encoder memory — the
    continuous engine does not serve cross-attention)."""
    rep = Report()
    cfg = make_reduced(all_configs()[arch])
    if cfg.encoder is not None:
        from repro.models.model import encode

        params = init_params(cfg, jax.random.PRNGKey(0))
        ms = jax.eval_shape(
            lambda: encode(cfg, params, jnp.zeros((1, 8), jnp.int32)))
        mem = jnp.zeros(ms.shape, ms.dtype)
        eng = Engine(cfg, params,
                     EngineConfig(max_batch=1, max_prefill=32, max_decode=8),
                     memory=mem)
        check_contract(eng.shape_contract(), rep)
    else:
        cont, stat = build_engines(arch)
        for eng in (cont, stat):
            check_contract(eng.shape_contract(), rep)
    assert not rep.errors, rep.render()


@pytest.mark.dist
def test_ep_engine_contract_closure():
    """The sharded jit registry's compile-shape contract is closed and the
    full ``--ep-only`` gate (contract + closure + donation + graph, incl.
    the missing-collective check) passes on the expert-parallel serving
    engines.  Subprocess under forced fake devices, like tests/test_dist.py
    — the main pytest process keeps its single CPU device."""
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.analyze", "--ep-only"],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, \
        f"EP analyze gate failed:\n{r.stdout[-3000:]}\n{r.stderr[-2000:]}"
    assert "analyze: OK" in r.stdout
