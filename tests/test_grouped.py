"""Grouped "dropless" MoE dispatch (MegaBlocks-style, PR 8).

Locks in the fused-tick PR's expert path:

  * layout invariants: static ``grouped_rows`` worst case, unique in-tile
    destinations, every (token, k) slot lands in a tile owned by its expert,
    total-skew routings still place every assignment (no drops by
    construction);
  * the grouped Pallas kernel (fp + int8/int4 dequant-in-VMEM) against the
    gather-einsum oracle, tile-for-tile;
  * token-exact dispatch parity: ``moe_grouped`` vs the dropless einsum
    reference ``moe_einsum_dropless`` (fp and quantized weights), INCLUDING
    a routing skew that overflows any practical ``expert_capacity`` — the
    case capacity-factor dispatch drops tokens on and dropless must not;
  * ``moe_layer(impl="grouped")`` wiring: matches ``impl="einsum"`` at a
    generous capacity factor (nothing dropped -> same math), works under
    jit, and keeps reporting RoutingStats f/P for the balance telemetry.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FFNSpec, ModelConfig
from repro.core.dispatch_einsum import moe_einsum_dropless
from repro.core.dispatch_grouped import (
    GROUPED_TILE,
    grouped_layout,
    grouped_rows,
    moe_grouped,
)
from repro.core.gating import top_k_gating
from repro.core.moe import experts_ffn, grouped_experts_ffn, init_moe, moe_layer
from repro.kernels.expert_mlp_grouped import (
    grouped_mlp_kernel,
    grouped_mlp_quant,
    grouped_mlp_quant_ref,
    grouped_mlp_ref,
)
from repro.quant.qarrays import QuantizedArray


def tiny_cfg(**kw):
    return ModelConfig(
        name="t", family="moe", source="x", d_model=32, num_heads=2,
        num_kv_heads=2, head_dim=16, vocab_size=64, segments=(),
        param_dtype="float32", compute_dtype="float32", **kw,
    )


def make(T=24, E=8, K=2, seed=0, skew=0.0):
    """(cfg, spec, params, x [T,D], dropless gating).  ``skew`` adds a router
    bias toward expert 0 — large values overflow any capacity buffer."""
    cfg = tiny_cfg()
    spec = FFNSpec(kind="moe", d_ff=64, num_experts=E, top_k=K,
                   capacity_factor=1.25)
    params = init_moe(jax.random.PRNGKey(seed), cfg, spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, cfg.d_model))
    logits = x.astype(jnp.float32) @ params["router"]
    logits = logits.at[:, 0].add(skew)
    g = top_k_gating(logits, K, T * K)  # dropless: capacity = T*K
    return cfg, spec, params, x, g


def quantize_experts(params, bits, group_size=0):
    q = dict(params)
    for name, axes in (("wi", (-2,)), ("wg", (-2,)), ("wo", (-2,))):
        if name in params:
            q[name] = QuantizedArray.quantize(
                params[name], bits=bits, group_size=group_size,
                reduce_axes=axes)
    return q


# ---------------------------------------------------------------------------
# Layout invariants
# ---------------------------------------------------------------------------


class TestLayout:
    def test_static_rows_worst_case(self):
        for T, K, E, tile in [(24, 2, 8, 8), (7, 1, 4, 8), (128, 2, 16, 8)]:
            ct = grouped_rows(T, K, E, tile)
            assert ct % tile == 0
            assert ct >= T * K
            # worst case: each non-empty group wastes < tile rows
            assert ct <= ((T * K + E * (tile - 1)) // tile + 1) * tile

    def test_every_slot_lands_in_its_experts_tile(self):
        _, _, _, _, g = make(T=24, E=8, K=2)
        lay = grouped_layout(g, 8)
        dst = np.asarray(lay.dst)
        te = np.asarray(lay.tile_expert)
        flat_e = np.asarray(g.expert_idx).reshape(-1)
        assert len(set(dst.tolist())) == dst.size  # injective: no collisions
        np.testing.assert_array_equal(te[dst // GROUPED_TILE], flat_e)
        np.testing.assert_array_equal(
            np.asarray(lay.counts), np.bincount(flat_e, minlength=8))

    def test_total_skew_keeps_every_assignment(self):
        """All T*K slots route to expert 0: capacity dispatch at any sane
        factor would drop most of them; the grouped layout places all."""
        _, _, _, _, g = make(T=24, E=8, K=2, skew=1e4)
        flat = np.asarray(g.expert_idx)
        assert np.all(flat[:, 0] == 0)  # every k=0 slot routes to expert 0
        assert np.all(np.asarray(g.keep))  # ...and dropless keeps them all
        lay = grouped_layout(g, 8)
        dst = np.asarray(lay.dst)
        assert len(set(dst.tolist())) == dst.size
        np.testing.assert_array_equal(
            np.asarray(lay.tile_expert)[dst // GROUPED_TILE], flat.reshape(-1))
        # expert 0's group holds all 24 tokens — far past the capacity
        # (1.25 * 48 / 8 = 7) the einsum path would truncate it to
        assert int(np.asarray(lay.counts)[0]) == 24


# ---------------------------------------------------------------------------
# Grouped Pallas kernel vs gather-einsum oracle (interpret mode on CPU)
# ---------------------------------------------------------------------------


class TestKernelVsRef:
    def _buffers(self, E=4, D=32, F=64, nt=6, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        xg = jax.random.normal(ks[0], (nt * GROUPED_TILE, D), jnp.float32)
        te = jax.random.randint(ks[1], (nt,), 0, E, jnp.int32)
        wi = jax.random.normal(ks[2], (E, D, F), jnp.float32) * 0.1
        wg = jax.random.normal(ks[3], (E, D, F), jnp.float32) * 0.1
        wo = jax.random.normal(ks[4], (E, F, D), jnp.float32) * 0.1
        return xg, te, wi, wg, wo

    def test_fp_kernel_matches_ref(self):
        xg, te, wi, wg, wo = self._buffers()
        got = grouped_mlp_kernel(xg, te, wi, wg, wo, interpret=True)
        want = grouped_mlp_ref(xg, te, wi, wg, wo)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    @pytest.mark.parametrize("bits", [8, 4])
    def test_quant_kernel_matches_ref(self, bits):
        xg, te, wi, wg, wo = self._buffers()
        qwi = QuantizedArray.quantize(wi, bits=bits, reduce_axes=(-2,))
        qwg = QuantizedArray.quantize(wg, bits=bits, reduce_axes=(-2,))
        qwo = QuantizedArray.quantize(wo, bits=bits, reduce_axes=(-2,))
        got = grouped_mlp_quant(xg, te, qwi, qwg, qwo, interpret=True)
        want = grouped_mlp_quant_ref(xg, te, qwi, qwg, qwo)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)

    def test_quant_kernel_rejects_groupwise_scales(self):
        xg, te, wi, wg, wo = self._buffers()
        qwi = QuantizedArray.quantize(wi, bits=8, group_size=16,
                                      reduce_axes=(-2,))
        qwg = QuantizedArray.quantize(wg, bits=8, group_size=16,
                                      reduce_axes=(-2,))
        qwo = QuantizedArray.quantize(wo, bits=8, group_size=16,
                                      reduce_axes=(-2,))
        with pytest.raises(ValueError, match="per-output-channel"):
            grouped_mlp_quant(xg, te, qwi, qwg, qwo, interpret=True)


# ---------------------------------------------------------------------------
# Dispatch parity: moe_grouped vs the dropless einsum reference
# ---------------------------------------------------------------------------


class TestDispatchParity:
    @pytest.mark.parametrize("skew", [0.0, 1e4],
                             ids=["balanced", "capacity-overflow"])
    def test_fp_matches_einsum_dropless(self, skew):
        """Token-exact (to f32 reduction-order noise) against the einsum
        dropless oracle — including the skew where every token routes to one
        expert, the case any fixed expert_capacity would drop on."""
        _, spec, params, x, g = make(skew=skew)
        got = moe_grouped(
            x, g, spec.num_experts,
            lambda xg, te: grouped_experts_ffn(params, xg, te, spec.act))
        want = moe_einsum_dropless(
            x, g, lambda xe: experts_ffn(params, xe, spec.act))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4)

    @pytest.mark.parametrize("bits", [8, 4])
    def test_quant_matches_einsum_dropless(self, bits):
        """int8/int4 expert weights through the grouped path vs the same
        quantized weights through the einsum dropless path, under the
        capacity-overflowing skew."""
        _, spec, params, x, g = make(skew=1e4)
        qp = quantize_experts(params, bits)
        got = moe_grouped(
            x, g, spec.num_experts,
            lambda xg, te: grouped_experts_ffn(qp, xg, te, spec.act))
        want = moe_einsum_dropless(
            x, g, lambda xe: experts_ffn(qp, xe, spec.act))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4)


# ---------------------------------------------------------------------------
# Layer wiring: cfg.moe_impl="grouped"
# ---------------------------------------------------------------------------


class TestLayerWiring:
    def test_matches_einsum_when_nothing_drops(self):
        """At a capacity factor high enough that einsum drops nothing, the
        two implementations compute the same mixture."""
        cfg = tiny_cfg()
        spec = FFNSpec(kind="moe", d_ff=64, num_experts=8, top_k=2,
                       capacity_factor=8.0)  # capacity >= T*K: no drops
        params = init_moe(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32))
        yg, ag = moe_layer(cfg, spec, params, x, impl="grouped")
        ye, ae = moe_layer(cfg, spec, params, x, impl="einsum")
        np.testing.assert_allclose(np.asarray(yg), np.asarray(ye), atol=2e-4)
        assert abs(float(ag) - float(ae)) < 1e-5

    def test_under_jit_and_stats(self):
        cfg, spec, params, x, _ = make()
        xb = x.reshape(2, 12, 32)

        @jax.jit
        def f(p, xin):
            return moe_layer(cfg, spec, p, xin, impl="grouped",
                             with_stats=True)

        y, aux, stats = f(params, xb)
        assert y.shape == xb.shape and np.isfinite(float(aux))
        # dropless still reports the balance telemetry (f, P per expert)
        assert stats.tokens_per_expert.shape == (spec.num_experts,)
        assert abs(float(stats.dropped_frac)) < 1e-6
