"""End-to-end behaviour tests for the paper's system-level claims that are
verifiable at CPU scale: PR-MoE/MoS size reductions (§4), the active-vs-total
parameter gap that drives the inference design (§5.1), dispatch-complexity
reduction (§5.4), and the HLO accounting used by the roofline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import count_active_params, count_params
from repro.configs.registry import all_configs, make_reduced
from repro.configs.shapes import SHAPES, shape_applicable
from repro.launch.hlo_account import account


class TestPaperSizeClaims:
    """Table 1/2 + §4: parameter-count claims reproduced exactly from configs."""

    def setup_method(self):
        self.cfgs = all_configs()

    def _b(self, name):
        return count_params(self.cfgs[name]) / 1e9

    def test_standard_moe_sizes(self):
        assert self._b("nlg-350m-moe128") == pytest.approx(13.0, rel=0.03)  # paper: 13B
        assert self._b("nlg-1.3b-moe128") == pytest.approx(52.0, rel=0.03)  # paper: 52B

    def test_prmoe_reduction(self):
        # §4.1.4: "PR-MoE uses less than 1/3 of the parameters" (350M case)
        assert self._b("nlg-350m-prmoe-32-64") < self._b("nlg-350m-moe128") / 3 * 1.05
        # 1.3B case: ~60% of standard MoE
        ratio = self._b("nlg-1.3b-prmoe-64-128") / self._b("nlg-1.3b-moe128")
        assert 0.55 < ratio < 0.65

    def test_mos_reduction(self):
        # §4.2 + abstract: PR-MoE + MoS reduces model size up to 3.7x
        full = self._b("nlg-350m-moe128")
        mos = self._b("nlg-350m-prmoe-mos")
        assert full / mos > 3.5, f"only {full/mos:.2f}x"

    def test_active_params_match_base_model(self):
        """§3.1/§5.1: per-token activated params ≈ the dense base model —
        the MoE 'critical data path'."""
        active = count_active_params(self.cfgs["nlg-1.3b-moe128"]) / 1e9
        dense = count_params(self.cfgs["nlg-1.3b"]) / 1e9
        assert active == pytest.approx(dense, rel=0.05)

    def test_moe_flops_equal_base_not_quality_equiv(self):
        """Table 3 basis: 1.3B+MoE-128 activates ~5x fewer params than the
        quality-equivalent 6.7B dense model."""
        active = count_active_params(self.cfgs["nlg-1.3b-moe128"])
        dense67 = count_params(self.cfgs["nlg-6.7b"])
        assert dense67 / active > 4.5


class TestDispatchComplexity:
    """§5.4: einsum dispatch does E× more multiply work than dense mapping."""

    def test_flop_ratio(self):
        from repro.configs.base import FFNSpec, ModelConfig
        from repro.core.moe import init_moe, moe_layer

        cfg = ModelConfig(name="t", family="moe", source="x", d_model=32, num_heads=2,
                          num_kv_heads=2, head_dim=16, vocab_size=64, segments=(),
                          param_dtype="float32", compute_dtype="float32")
        spec = FFNSpec(kind="moe", d_ff=32, num_experts=16, top_k=1, capacity_factor=2.0)
        params = init_moe(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))

        def flops(impl):
            c = jax.jit(lambda p, x: moe_layer(cfg, spec, p, x, impl=impl)).lower(params, x).compile()
            ca = c.cost_analysis()
            ca = ca[0] if isinstance(ca, list) else ca
            return float(ca.get("flops", 0))

        f_einsum, f_dense = flops("einsum"), flops("dense")
        # dispatch einsum term: T*E*C*D each way; expert GEMMs shared.
        assert f_einsum > f_dense * 1.5, (f_einsum, f_dense)


class TestShapeApplicability:
    def test_long500k_gating(self):
        cfgs = all_configs()
        runs = {a: shape_applicable(cfgs[a], SHAPES["long_500k"])[0] for a in
                ["gemma3-27b", "mamba2-370m", "recurrentgemma-2b", "glm4-9b", "llama3-8b",
                 "deepseek-67b", "kimi-k2-1t-a32b", "llama4-maverick-400b-a17b",
                 "seamless-m4t-medium", "internvl2-1b"]}
        assert runs["gemma3-27b"] and runs["mamba2-370m"] and runs["recurrentgemma-2b"]
        assert not any(runs[a] for a in ["glm4-9b", "llama3-8b", "deepseek-67b",
                                         "kimi-k2-1t-a32b", "llama4-maverick-400b-a17b",
                                         "seamless-m4t-medium", "internvl2-1b"])

    def test_other_shapes_always_run(self):
        from repro.configs.registry import ASSIGNED

        cfgs = all_configs()
        for a in ASSIGNED:
            for s in ("train_4k", "prefill_32k", "decode_32k"):
                ok, _ = shape_applicable(cfgs[a], SHAPES[s])
                assert ok, (a, s)


class TestHLOAccounting:
    def test_trip_count_multiplication(self):
        """account() must multiply while-loop bodies by their trip count."""
        def f_scan(x, w):
            def body(c, wi):
                return c @ wi, None
            y, _ = jax.lax.scan(body, x, w)
            return y

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
        c = jax.jit(f_scan).lower(x, w).compile()
        st = account(c.as_text())
        want = 2 * 128**3 * 10
        assert st.flops == pytest.approx(want, rel=0.05), (st.flops, want)

    def test_collectives_counted(self):
        # single-device program has no collectives
        c = jax.jit(lambda x: x @ x).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        st = account(c.as_text())
        assert st.coll_bytes == {}
        assert st.flops == pytest.approx(2 * 64**3, rel=0.05)


class TestReducedConfigs:
    def test_reduced_within_limits(self):
        for name, cfg in all_configs().items():
            r = make_reduced(cfg)
            assert r.d_model <= 512
            # one repeat of each segment pattern (gemma3's 5:1 pattern -> 6+2)
            assert r.num_layers <= 8
            for ls in r.layer_specs():
                if ls.ffn.kind == "moe":
                    assert ls.ffn.num_experts <= 4
