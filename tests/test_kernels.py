"""Pallas kernel validation (interpret mode) against pure-jnp oracles,
with shape/dtype sweeps — one test class per kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a single-draw fallback shim

from repro.kernels.ops import fused_expert_mlp, fused_gating
from repro.kernels.ref import expert_mlp_ref, gating_ref


class TestGatingKernel:
    @pytest.mark.parametrize(
        "T,E,K,cap",
        [
            (128, 8, 1, 24),
            (256, 16, 2, 40),
            (384, 32, 4, 56),
            (256, 64, 8, 40),
            (512, 128, 1, 16),
        ],
    )
    def test_matches_ref(self, T, E, K, cap):
        logits = jax.random.normal(jax.random.PRNGKey(T + E + K), (T, E))
        got = fused_gating(logits, K, cap)
        want = gating_ref(logits, K, cap)
        np.testing.assert_array_equal(np.asarray(got.expert_idx), np.asarray(want.expert_idx))
        np.testing.assert_array_equal(np.asarray(got.position), np.asarray(want.position))
        np.testing.assert_array_equal(np.asarray(got.keep), np.asarray(want.keep))
        np.testing.assert_allclose(np.asarray(got.combine_w), np.asarray(want.combine_w), atol=2e-6)
        np.testing.assert_allclose(np.asarray(got.probs), np.asarray(want.probs), atol=2e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        logits = jax.random.normal(jax.random.PRNGKey(0), (128, 16)).astype(dtype)
        got = fused_gating(logits, 2, 24)
        want = gating_ref(logits, 2, 24)
        np.testing.assert_array_equal(np.asarray(got.expert_idx), np.asarray(want.expert_idx))
        np.testing.assert_allclose(
            np.asarray(got.combine_w), np.asarray(want.combine_w), atol=1e-2
        )

    def test_multiblock_carry(self):
        """Counts must carry across token tiles (capacity fills in order)."""
        T, E = 512, 4  # 4 tiles of 128
        logits = jnp.zeros((T, E)).at[:, 1].set(9.0)  # everyone to expert 1
        cap = 200
        got = fused_gating(logits, 1, cap)
        kept = np.asarray(got.keep[:, 0])
        assert kept[:cap].all() and not kept[cap:].any()
        pos = np.asarray(got.position[:cap, 0])
        np.testing.assert_array_equal(pos, np.arange(cap))

    @settings(max_examples=15, deadline=None)
    @given(
        nb=st.integers(1, 3),
        E=st.sampled_from([4, 8, 16]),
        K=st.integers(1, 4),
        seed=st.integers(0, 99),
    )
    def test_property_sweep(self, nb, E, K, seed):
        K = min(K, E)
        T = 128 * nb
        logits = jax.random.normal(jax.random.PRNGKey(seed), (T, E))
        got = fused_gating(logits, K, 32)
        want = gating_ref(logits, K, 32)
        np.testing.assert_array_equal(np.asarray(got.expert_idx), np.asarray(want.expert_idx))
        np.testing.assert_array_equal(np.asarray(got.position), np.asarray(want.position))


class TestExpertMLPKernel:
    @pytest.mark.parametrize(
        "E,C,D,F",
        [
            (2, 128, 64, 256),
            (4, 256, 128, 512),
            (8, 128, 32, 256),
            (1, 512, 256, 1024),
        ],
    )
    def test_matches_ref(self, E, C, D, F):
        ks = jax.random.split(jax.random.PRNGKey(E * C), 4)
        xe = jax.random.normal(ks[0], (E, C, D), jnp.float32) * 0.5
        wi = jax.random.normal(ks[1], (E, D, F), jnp.float32) * 0.1
        wg = jax.random.normal(ks[2], (E, D, F), jnp.float32) * 0.1
        wo = jax.random.normal(ks[3], (E, F, D), jnp.float32) * 0.1
        got = fused_expert_mlp(xe, wi, wg, wo)
        want = expert_mlp_ref(xe, wi, wg, wo)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3)

    def test_bf16(self):
        ks = jax.random.split(jax.random.PRNGKey(7), 4)
        xe = (jax.random.normal(ks[0], (2, 128, 64)) * 0.5).astype(jnp.bfloat16)
        wi = (jax.random.normal(ks[1], (2, 64, 256)) * 0.1).astype(jnp.bfloat16)
        wg = (jax.random.normal(ks[2], (2, 64, 256)) * 0.1).astype(jnp.bfloat16)
        wo = (jax.random.normal(ks[3], (2, 256, 64)) * 0.1).astype(jnp.bfloat16)
        got = fused_expert_mlp(xe, wi, wg, wo)
        want = expert_mlp_ref(xe, wi, wg, wo)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=0.1, rtol=0.1
        )

    def test_f_accumulation(self):
        """Output accumulates across F blocks (block_f < F)."""
        ks = jax.random.split(jax.random.PRNGKey(9), 4)
        E, C, D, F = 1, 128, 32, 1024
        xe = jax.random.normal(ks[0], (E, C, D)) * 0.5
        wi = jax.random.normal(ks[1], (E, D, F)) * 0.1
        wg = jax.random.normal(ks[2], (E, D, F)) * 0.1
        wo = jax.random.normal(ks[3], (E, F, D)) * 0.1
        from repro.kernels.expert_mlp import expert_mlp_kernel

        got = expert_mlp_kernel(xe, wi, wg, wo, interpret=True, block_f=128)
        want = expert_mlp_ref(xe, wi, wg, wo)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3)
