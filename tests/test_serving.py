"""Serving integration: prefill+decode == teacher-forced forward for every
arch family (the core cache invariant), engine batching, sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, all_configs, make_reduced
from repro.models.model import decode_step, encode, forward, init_caches, init_params, prefill
from repro.serving.engine import Engine, EngineConfig, Request
from repro.serving.sampling import sample


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_matches_forward(arch):
    cfg = make_reduced(all_configs()[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, extra_dec = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra_dec), 0, cfg.vocab_size)
    kw = {}
    pe = None
    if cfg.family == "encdec":
        src = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
        kw["memory"] = encode(cfg, params, src)
    if cfg.family == "vlm":
        pe = jax.random.normal(jax.random.PRNGKey(3), (B, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    full_logits, _ = forward(cfg, params, toks, prefix_embeds=pe, **kw)
    offset = cfg.frontend.n_tokens if cfg.family == "vlm" else 0
    caches = init_caches(
        cfg, B, capacity=S + extra_dec + offset,
        cross_len=(cfg.frontend.n_tokens if cfg.family == "encdec" else 0),
    )
    lg, caches = prefill(cfg, params, toks[:, :S], caches, prefix_embeds=pe, **kw)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full_logits[:, S - 1 + offset]), atol=2e-4
    )
    for i in range(extra_dec):
        idx = jnp.asarray(S + i + offset, jnp.int32)
        lg, caches = decode_step(cfg, params, toks[:, S + i : S + i + 1], idx, caches, **kw)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, S + i + offset]), atol=5e-3,
            err_msg=f"{arch} decode step {i}",
        )


def test_window_cache_beyond_window():
    """Decoding past the sliding window stays exact (ring buffer eviction)."""
    cfg = make_reduced(all_configs()["gemma3-27b"])  # window 8 in reduced form
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, extra = 1, 10, 8  # decode well past window=8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra), 0, cfg.vocab_size)
    full_logits, _ = forward(cfg, params, toks)
    caches = init_caches(cfg, B, capacity=S + extra)
    lg, caches = prefill(cfg, params, toks[:, :S], caches)
    for i in range(extra):
        idx = jnp.asarray(S + i, jnp.int32)
        lg, caches = decode_step(cfg, params, toks[:, S + i : S + i + 1], idx, caches)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, S + i]), atol=5e-3,
            err_msg=f"step {i} (pos {S+i})",
        )


class TestEngine:
    def _engine(self, arch="glm4-9b", **ec_kw):
        cfg = make_reduced(all_configs()[arch])
        params = init_params(cfg, jax.random.PRNGKey(0))
        ec = EngineConfig(max_batch=4, max_prefill=16, max_decode=8, **ec_kw)
        return cfg, Engine(cfg, params, ec)

    def test_greedy_deterministic(self):
        cfg, eng = self._engine()
        reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=6) for _ in range(2)]
        r1 = eng.generate(reqs)
        r2 = eng.generate(reqs)
        assert [r.tokens for r in r1] == [r.tokens for r in r2]
        assert all(len(r.tokens) == 6 for r in r1)

    def test_batch_matches_single(self):
        """Batched generation == one-at-a-time generation (greedy)."""
        cfg, eng = self._engine()
        prompts = [[1, 2, 3, 4, 5], [9, 8, 7, 6, 5]]
        batched = eng.generate([Request(prompt=p, max_new_tokens=5) for p in prompts])
        singles = [eng.generate([Request(prompt=p, max_new_tokens=5)])[0] for p in prompts]
        for b, s in zip(batched, singles):
            assert b.tokens == s.tokens

    def test_overflow_batches(self):
        cfg, eng = self._engine()
        reqs = [Request(prompt=[i + 1, i + 2], max_new_tokens=3) for i in range(9)]
        out = eng.generate(reqs)
        assert len(out) == 9

    def test_moe_engine(self):
        cfg, eng = self._engine(arch="llama4-maverick-400b-a17b")
        out = eng.generate([Request(prompt=[5, 6, 7], max_new_tokens=4)])
        assert len(out[0].tokens) == 4


class TestSampling:
    def test_greedy(self):
        logits = jnp.asarray([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]])
        t = sample(logits, jax.random.PRNGKey(0), temperature=0.0)
        assert t.tolist() == [1, 0]

    def test_topk_restricts(self):
        logits = jnp.asarray([[0.0, 5.0, 4.9, -10.0]])
        for seed in range(20):
            t = sample(logits, jax.random.PRNGKey(seed), temperature=1.0, top_k=2)
            assert int(t[0]) in (1, 2)

    def test_topp_restricts_to_nucleus(self):
        # probs ~ [0.64, 0.23, 0.09, 0.03, ...]: top_p=0.6 keeps only token 0,
        # top_p=0.8 keeps {0, 1} (the first token crossing the mass threshold
        # is included).
        logits = jnp.asarray([[4.0, 3.0, 2.0, 1.0, 0.0]])
        for seed in range(20):
            t = sample(logits, jax.random.PRNGKey(seed), temperature=1.0, top_p=0.6)
            assert int(t[0]) == 0
            t = sample(logits, jax.random.PRNGKey(seed), temperature=1.0, top_p=0.8)
            assert int(t[0]) in (0, 1)

    @pytest.mark.parametrize("top_p", [0.0, 1.0])
    def test_topp_boundaries_keep_full_distribution(self, top_p):
        # both 0.0 (off) and 1.0 (whole nucleus) must leave the distribution
        # intact — the filter only engages strictly inside (0, 1)
        logits = jnp.asarray([[0.0, 1.0, 2.0]])
        seen = {
            int(sample(logits, jax.random.PRNGKey(s), temperature=2.0, top_p=top_p)[0])
            for s in range(40)
        }
        assert seen == {0, 1, 2}

    def test_topp_composes_with_topk(self):
        logits = jnp.asarray([[5.0, 4.9, 4.8, -1.0]])
        for seed in range(20):
            t = sample(logits, jax.random.PRNGKey(seed), temperature=1.0, top_k=2, top_p=0.99)
            assert int(t[0]) in (0, 1)

    def test_engine_threads_topp(self):
        cfg = make_reduced(all_configs()["llama3-8b"])
        params = init_params(cfg, jax.random.PRNGKey(0))
        ec = EngineConfig(max_batch=2, max_prefill=16, max_decode=4,
                          temperature=1.0, top_p=0.9)
        out = Engine(cfg, params, ec).generate([Request(prompt=[1, 2, 3], max_new_tokens=4)])
        assert len(out[0].tokens) == 4
