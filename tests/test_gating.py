"""Gating unit + property tests (core/gating.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a single-draw fallback shim

from repro.core.gating import (
    expert_capacity,
    load_balance_loss,
    router_z_loss,
    top_k_gating,
)


def _logits(T, E, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (T, E))


class TestTopKGating:
    def test_shapes(self):
        g = top_k_gating(_logits(32, 8), 2, 16)
        assert g.expert_idx.shape == (32, 2)
        assert g.combine_w.shape == (32, 2)
        assert g.position.shape == (32, 2)
        assert g.keep.shape == (32, 2)
        assert g.probs.shape == (32, 8)

    def test_probs_sum_to_one(self):
        g = top_k_gating(_logits(64, 16), 1, 64)
        np.testing.assert_allclose(np.asarray(jnp.sum(g.probs, -1)), 1.0, atol=1e-5)

    def test_topk_normalized(self):
        g = top_k_gating(_logits(64, 16), 4, 64, normalize=True)
        np.testing.assert_allclose(np.asarray(jnp.sum(g.combine_w, -1)), 1.0, atol=1e-5)

    def test_top1_weight_is_max_prob(self):
        g = top_k_gating(_logits(64, 16), 1, 64)
        np.testing.assert_allclose(
            np.asarray(g.combine_w[:, 0]), np.asarray(jnp.max(g.probs, -1)), atol=1e-6
        )

    def test_positions_unique_within_expert(self):
        g = top_k_gating(_logits(128, 4), 2, 1024)
        eidx = np.asarray(g.expert_idx).reshape(-1)
        pos = np.asarray(g.position).reshape(-1)
        for e in range(4):
            p = pos[eidx == e]
            assert len(np.unique(p)) == len(p), f"duplicate slots in expert {e}"

    def test_capacity_drops(self):
        # force all tokens to expert 0 with capacity 8 -> only 8 kept
        logits = jnp.zeros((32, 4)).at[:, 0].set(10.0)
        g = top_k_gating(logits, 1, 8)
        assert int(jnp.sum(g.keep)) == 8
        assert np.all(np.asarray(g.combine_w)[~np.asarray(g.keep)] == 0.0)

    def test_earlier_tokens_win_capacity(self):
        logits = jnp.zeros((32, 4)).at[:, 0].set(10.0)
        g = top_k_gating(logits, 1, 8)
        kept = np.asarray(g.keep[:, 0])
        assert kept[:8].all() and not kept[8:].any()

    def test_sort_equals_cumsum(self):
        for T, E, K in [(64, 8, 1), (128, 16, 2), (96, 32, 4)]:
            logits = _logits(T, E, seed=T)
            g1 = top_k_gating(logits, K, 16, method="cumsum")
            g2 = top_k_gating(logits, K, 16, method="sort")
            np.testing.assert_array_equal(np.asarray(g1.expert_idx), np.asarray(g2.expert_idx))
            np.testing.assert_array_equal(np.asarray(g1.position), np.asarray(g2.position))
            np.testing.assert_array_equal(np.asarray(g1.keep), np.asarray(g2.keep))

    @settings(max_examples=20, deadline=None)
    @given(
        T=st.integers(4, 96),
        E=st.sampled_from([2, 4, 8, 16]),
        K=st.integers(1, 3),
        seed=st.integers(0, 1000),
    )
    def test_property_positions_bounded(self, T, E, K, seed):
        K = min(K, E)
        cap = expert_capacity(T, E, K, 1.25)
        g = top_k_gating(_logits(T, E, seed), K, cap)
        pos = np.asarray(g.position)
        assert (pos >= 0).all() and (pos < cap).all()
        # kept fraction per expert never exceeds capacity
        eidx = np.asarray(g.expert_idx)
        keep = np.asarray(g.keep)
        for e in range(E):
            assert keep[eidx == e].sum() <= cap

    @settings(max_examples=20, deadline=None)
    @given(E=st.sampled_from([4, 8, 16]), seed=st.integers(0, 100))
    def test_property_sort_cumsum_agree(self, E, seed):
        logits = _logits(64, E, seed)
        g1 = top_k_gating(logits, 2, 8, method="cumsum")
        g2 = top_k_gating(logits, 2, 8, method="sort")
        np.testing.assert_array_equal(np.asarray(g1.position), np.asarray(g2.position))


class TestAuxLosses:
    def test_load_balance_minimized_uniform(self):
        # perfectly uniform routing -> loss == 1.0 (its minimum)
        T, E = 64, 8
        logits = jnp.zeros((T, E))
        eidx = jnp.tile(jnp.arange(E, dtype=jnp.int32), T // E)[:, None]
        probs = jnp.full((T, E), 1.0 / E)
        lb = load_balance_loss(probs, eidx, E)
        assert abs(float(lb) - 1.0) < 1e-5

    def test_load_balance_penalizes_collapse(self):
        T, E = 64, 8
        probs = jnp.zeros((T, E)).at[:, 0].set(1.0)
        eidx = jnp.zeros((T, 1), jnp.int32)
        lb = load_balance_loss(probs, eidx, E)
        assert float(lb) > 7.0  # E * 1 * 1 = 8

    def test_z_loss_nonneg(self):
        assert float(router_z_loss(_logits(32, 8))) >= 0.0


class TestCapacity:
    def test_capacity_formula(self):
        assert expert_capacity(1024, 8, 1, 1.0) == 128
        assert expert_capacity(1024, 8, 2, 1.0) == 256
        # padded to multiple of 8, floor 8
        assert expert_capacity(4, 64, 1, 1.0) == 8
