"""Multi-device expert-parallel SERVING tests (dist marker).

Parity tier: a sharded ``ContinuousEngine``/``Engine`` (``cfg.ep_mesh``)
must produce greedy decode output token-IDENTICAL to the single-device
engine — across arch mixes (glm4 attention-only, gemma3 sliding-window +
int8 KV, the paper's NLG MoE), mesh shapes (8,), (4, 2), (2, 4) (the 2-d
shapes take the hierarchical two-hop all-to-all), the grouped dropless
kernel, batched multi-slot prefill, and prefix sharing.  Exactness is by
construction: the EP schedules reconstruct the reference kernels'
arithmetic (global gating + all_gather/psum of expert outputs, or a
trailing-padded a2a with drop-free capacity), so the assertion is ``==``
on token lists, not allclose.

Invariant tier: property-fuzzed (tests/_hyp.py shim) routing/collective
conservation — after the all-to-all exchange no token is duplicated or
dropped under skewed routing, per-device received counts sum to the global
dispatch, hierarchical == flat — plus preemption/resume on a sharded
engine draining the page pool, and the ``moe_impl="dense"`` multi-device
guard regression.

Like tests/test_dist.py, everything runs in SUBPROCESSES under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps its single CPU device.
"""
import os
import subprocess
import sys

import pytest

from tests._hyp import given, settings, st

pytestmark = pytest.mark.dist

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_script(body: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", body], capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, f"subprocess failed:\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    return r.stdout


# prompts mix: two sharing an 8-token prefix (page-aligned at page_size=8),
# one long (chunked prefill), one single-token
ENGINE_PREAMBLE = """
import jax, jax.numpy as jnp, numpy as np
jax.config.update("jax_default_matmul_precision", "highest")
from repro.configs.registry import all_configs, make_reduced, with_moe_ffn
from repro.models.model import init_params
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine, EngineConfig, Request

def serve(cfg, params, prompts, n_new, **kw):
    eng = ContinuousEngine(cfg, params, **kw)
    ids = [eng.submit(Request(prompt=p, max_new_tokens=n_new)) for p in prompts]
    done = eng.run_until_done()
    return [done[i].tokens for i in ids], eng

PRE = [7, 7, 3, 5, 1, 2, 9, 4]
PROMPTS = [PRE + [3, 5, 1], PRE + [8, 2], [11, 2, 3, 7, 5, 6, 1, 9, 2, 3], [5]]
"""


class TestShardedEngineParity:
    def test_glm4_flat_mesh(self):
        """Dense arch on (8,): attention/KV data-parallel over slots, weights
        replicated — the no-MoE degenerate case of the serving mesh."""
        run_script(ENGINE_PREAMBLE + """
cfg = make_reduced(all_configs()["glm4-9b"])
params = init_params(cfg, jax.random.PRNGKey(0))
kw = dict(slots=4, capacity=64, paged=True, page_size=8)
base, _ = serve(cfg, params, PROMPTS, 8, **kw)
ep, _ = serve(cfg.replace(ep_mesh=(8,)), params, PROMPTS, 8, **kw)
assert base == ep, (base, ep)
print("glm4 (8,) OK")
""")

    def test_nlg_moe_hier_mesh(self):
        """The paper's NLG MoE on (4, 2): experts sharded over both axes, the
        chunked-prefill dense kernel goes through the hierarchical two-hop
        a2a, decode through the replicated-token all_gather schedule.
        capacity_factor=8.0 gives the a2a schedule drop-free headroom (the
        parity-by-construction precondition for the token-sharded path)."""
        run_script(ENGINE_PREAMBLE + """
cfg = with_moe_ffn(make_reduced(all_configs()["nlg-350m-moe128"]),
                   num_experts=8, capacity_factor=8.0)
params = init_params(cfg, jax.random.PRNGKey(0))
kw = dict(slots=4, capacity=64, paged=True, page_size=8)
base, _ = serve(cfg, params, PROMPTS, 8, **kw)
ep, eng = serve(cfg.replace(ep_mesh=(4, 2)), params, PROMPTS, 8, **kw)
assert eng.cfg.moe_impl == "ep_serve", eng.cfg.moe_impl
assert base == ep, (base, ep)
print("nlg (4,2) OK")
""")

    def test_gemma3_int8_kv(self):
        """Arch mix + quantized KV: gemma3 (sliding-window/global interleave)
        with int8 KV cache blocks, sharded over (4, 2)."""
        run_script(ENGINE_PREAMBLE + """
cfg = make_reduced(all_configs()["gemma3-27b"])
params = init_params(cfg, jax.random.PRNGKey(0))
kw = dict(slots=4, capacity=64, paged=True, page_size=8, kv_cache_bits=8)
base, _ = serve(cfg, params, PROMPTS, 8, **kw)
ep, _ = serve(cfg.replace(ep_mesh=(4, 2)), params, PROMPTS, 8, **kw)
assert base == ep, (base, ep)
print("gemma3 int8 (4,2) OK")
""")

    def test_nlg_grouped_batched_prefix(self):
        """Composition: grouped (dropless) expert kernel per device + batched
        multi-slot prefill + prefix sharing, experts over (2, 4)."""
        run_script(ENGINE_PREAMBLE + """
cfg = with_moe_ffn(make_reduced(all_configs()["nlg-350m-moe128"]), num_experts=8)
cfg = cfg.replace(moe_impl="grouped")
params = init_params(cfg, jax.random.PRNGKey(0))
kw = dict(slots=4, capacity=64, paged=True, page_size=8,
          prefix_sharing=True, prefill_mode="batched")
base, _ = serve(cfg, params, PROMPTS, 8, **kw)
ep, eng = serve(cfg.replace(ep_mesh=(2, 4)), params, PROMPTS, 8, **kw)
assert eng.cfg.moe_impl == "ep_grouped", eng.cfg.moe_impl
assert base == ep, (base, ep)
print("nlg grouped batched prefix (2,4) OK")
""")

    def test_static_engine(self):
        """The static (non-continuous) Engine over (8,): same placement and
        shard_map wrapping, contiguous caches instead of paged."""
        run_script(ENGINE_PREAMBLE + """
cfg = with_moe_ffn(make_reduced(all_configs()["nlg-350m-moe128"]),
                   num_experts=8, capacity_factor=8.0)
params = init_params(cfg, jax.random.PRNGKey(0))
ec = EngineConfig(max_batch=4, max_prefill=32, max_decode=8)
reqs = [Request(prompt=p, max_new_tokens=8) for p in PROMPTS]
base = [r.tokens for r in Engine(cfg, params, ec).generate(reqs)]
ep = [r.tokens for r in Engine(cfg.replace(ep_mesh=(8,)), params, ec).generate(reqs)]
assert base == ep, (base, ep)
print("static Engine (8,) OK")
""")


class TestPreemptionDrain:
    def test_sharded_pool_drains_after_preemption(self):
        """Page-pressure preemption + resume on a SHARDED engine: the host
        scheduler must stay mesh-agnostic (identical preemption decisions and
        token output as single-device), and after completion every per-shard
        page is back on the freelist (extends the test_kv_pool_prop.py drain
        invariant to the sharded engine)."""
        run_script(ENGINE_PREAMBLE + """
cfg = with_moe_ffn(make_reduced(all_configs()["nlg-350m-moe128"]),
                   num_experts=8, capacity_factor=8.0)
params = init_params(cfg, jax.random.PRNGKey(0))
# 10 pages cannot hold 4 slots' prompt+decode footprint -> forced preemption
kw = dict(slots=4, capacity=32, paged=True, page_size=4, n_pages=10)
base, ref = serve(cfg, params, PROMPTS, 8, **kw)
ep, eng = serve(cfg.replace(ep_mesh=(4, 2)), params, PROMPTS, 8, **kw)
assert eng.preemptions > 0, "workload did not exercise preemption"
assert eng.preemptions == ref.preemptions, (eng.preemptions, ref.preemptions)
assert base == ep, (base, ep)
eng.pool.check()
assert eng.pool.free_count == eng.n_pages, (eng.pool.free_count, eng.n_pages)
assert eng.pool.used_count == 0
print("preempt/drain OK", eng.preemptions)
""")


class TestMoEDenseGuard:
    def test_dense_impl_raises_under_multi_device_mesh(self):
        """Regression for the documented XLA SPMD hazard: the GSPMD-partitioned
        dense scatter/gather dispatch miscomputes under a >1-device mesh, so
        requesting it there must raise an informative error instead of
        silently serving wrong numbers (single-device use stays fine)."""
        run_script("""
import jax, jax.numpy as jnp
from repro.configs.base import FFNSpec
from repro.core.moe import init_moe, moe_layer
from repro.serving.ep import build_serving_mesh
from repro.parallel.sharding import use_mesh

class C:
    d_model = 32
    moe_impl = "dense"

spec = FFNSpec(kind="moe", d_ff=64, num_experts=8, top_k=2, capacity_factor=2.0)
p = init_moe(jax.random.PRNGKey(0), C, spec, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 32), jnp.float32)
moe_layer(C, spec, p, x, impl="dense")  # no mesh: allowed
mesh, rules = build_serving_mesh((4, 2))
with use_mesh(mesh, rules):
    try:
        moe_layer(C, spec, p, x, impl="dense")
    except ValueError as e:
        assert "numerically unsafe" in str(e), str(e)
    else:
        raise AssertionError("dense dispatch under a multi-device mesh did not raise")
print("dense guard OK")
""")


# ---------------------------------------------------------------------------
# Property fuzz: routing / collective conservation invariants
# ---------------------------------------------------------------------------

# Per-shard gating is replayed on the HOST (no mesh) — identical arithmetic —
# then the dispatch buffers go through the real shard_map all-to-all; every
# invariant is checked against the host replay.  Token payloads carry their
# global id in channel 0 and a count of 1.0 in channel 1.
_A2A_FUZZ = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.gating import top_k_gating
from repro.core.dispatch import dispatch_dense
from repro.parallel.collectives import flat_all_to_all, hierarchical_all_to_all
from repro.parallel.compat import make_mesh, shard_map

SEED = %d
rng = np.random.default_rng(SEED)
E, K, T_loc = 8, 2, 8
CAP = T_loc * K  # >= worst-case per-shard skew: zero drops by construction

for trial in range(4):
    for shape, names in [((8,), ("data",)), ((4, 2), ("pod", "data"))]:
        mesh = make_mesh(shape, names)
        n_dev = int(np.prod(shape))
        E_loc = E // n_dev
        # skewed routing: 1-2 hot experts soak up most of the probability
        hot = rng.choice(E, size=int(rng.integers(1, 3)), replace=False)
        logits = rng.normal(size=(n_dev, T_loc, E)).astype(np.float32)
        logits[..., hot] += 4.0
        gs = [top_k_gating(jnp.asarray(logits[r]), K, CAP) for r in range(n_dev)]
        assert all(bool(jnp.all(g.keep)) for g in gs), "capacity headroom violated"
        bufs = []
        for r, g in enumerate(gs):
            ids = jnp.arange(T_loc, dtype=jnp.float32) + 1 + r * T_loc  # 1-based
            x = jnp.stack([ids, jnp.ones_like(ids)], axis=-1)  # [T_loc, 2]
            bufs.append(dispatch_dense(x, g, CAP, E))
        xg = jnp.stack(bufs)  # [n_dev, E, CAP, 2]
        spec = P(names, None, None, None)
        def run(fn):
            body = lambda xs: fn(xs.reshape(E, CAP, 2))[None]
            return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec)(xg)
        flat = np.asarray(run(lambda b: flat_all_to_all(b, names)))
        if len(names) == 2:
            hier = np.asarray(run(lambda b: hierarchical_all_to_all(b, names[1], names[0])))
            assert np.array_equal(flat, hier), "hierarchical a2a != flat a2a"
        # flat[r]: [E_loc, n_dev*CAP, 2] = device r's received expert rows
        counts = flat[..., 1]
        ids = flat[..., 0]
        assert set(np.unique(counts)) <= {0.0, 1.0}
        # (1) per-device received counts == host-replayed routing to its experts,
        #     and they sum to the global dispatch total
        eidx = np.stack([np.asarray(g.expert_idx) for g in gs])  # [n_dev, T_loc, K]
        for r in range(n_dev):
            lo = r * E_loc
            expect = int(((eidx >= lo) & (eidx < lo + E_loc)).sum())
            got = int(counts[r].sum())
            assert got == expect, (r, got, expect)
        assert int(counts.sum()) == n_dev * T_loc * K
        # (2) no token duplicated or dropped: every global id arrives exactly K times
        arrived = ids[counts > 0].astype(np.int64)
        want = np.repeat(np.arange(1, n_dev * T_loc + 1), K)
        assert np.array_equal(np.sort(arrived), want), "token multiset mismatch"
        # (3) expert ownership: rows land only in their owner's local buffer
        for r in range(n_dev):
            for e_loc in range(E_loc):
                e = r * E_loc + e_loc
                expect_ids = sorted(
                    int(t + 1 + s * T_loc)
                    for s in range(n_dev) for t in range(T_loc) for k in range(K)
                    if eidx[s, t, k] == e)
                got_ids = sorted(ids[r, e_loc][counts[r, e_loc] > 0].astype(np.int64).tolist())
                assert got_ids == expect_ids, (e, got_ids, expect_ids)
print("a2a conservation OK")
"""


class TestRoutingInvariants:
    @settings(max_examples=3, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_a2a_token_conservation(self, seed):
        """Skewed-routing fuzz over (8,) and (4, 2) meshes: per-device counts
        after the all-to-all sum to the global dispatch, no token duplicated
        or dropped, expert rows land only on the owning device, hierarchical
        two-hop identical to flat."""
        run_script(_A2A_FUZZ % seed)

    @settings(max_examples=2, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_hier_roundtrip_random_buffers(self, seed):
        """hierarchical a2a then its inverse is the identity on random
        buffers, and matches flat, for both 2-d mesh factorizations."""
        run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.collectives import (flat_all_to_all, flat_all_to_all_back,
    hierarchical_all_to_all, hierarchical_all_to_all_back)
from repro.parallel.compat import make_mesh, shard_map

rng = np.random.default_rng(%d)
for shape in [(2, 4), (4, 2)]:
    mesh = make_mesh(shape, ("pod", "data"))
    E = 8 * int(rng.integers(1, 3))
    C, D = int(rng.integers(1, 5)), int(rng.integers(1, 9))
    xg = jnp.asarray(rng.normal(size=(8, E, C, D)).astype(np.float32))
    spec = P(("pod", "data"), None, None, None)
    def run(fn):
        body = lambda xs: fn(xs.reshape(E, C, D))[None]
        return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec)(xg)
    flat = run(lambda x: flat_all_to_all(x, ("pod", "data")))
    hier = run(lambda x: hierarchical_all_to_all(x, "data", "pod"))
    assert np.array_equal(np.asarray(flat), np.asarray(hier))
    rt = run(lambda x: hierarchical_all_to_all_back(
        hierarchical_all_to_all(x, "data", "pod"), "data", "pod"))
    assert np.array_equal(np.asarray(rt), np.asarray(xg))
    rtf = run(lambda x: flat_all_to_all_back(flat_all_to_all(x, ("pod", "data")), ("pod", "data")))
    assert np.array_equal(np.asarray(rtf), np.asarray(xg))
print("hier roundtrip OK")
""" % seed)


# ---------------------------------------------------------------------------
# Speculative decoding under an EP mesh: refuse loudly, never miscompute
# ---------------------------------------------------------------------------


class TestSpecUnderEPMesh:
    def test_spec_draft_raises_clear_not_implemented(self):
        """Speculation's CoW fork plan is host-side per slot while the EP
        mesh places the page pool per rank — until the verify pass is
        taught to shard, arming both together must raise a clear
        NotImplementedError at engine construction (NOT silently serve
        wrong tokens or crash mid-tick)."""
        run_script("""
import jax
from repro.configs.registry import all_configs, make_reduced
from repro.models.model import init_params
from repro.serving.continuous import ContinuousEngine

cfg = make_reduced(all_configs()["nlg-350m-moe128"]).replace(ep_mesh=(4,))
params = init_params(cfg.replace(ep_mesh=()), jax.random.PRNGKey(0))
try:
    ContinuousEngine(cfg, params, slots=2, capacity=32, paged=True,
                     page_size=4, spec_draft=(cfg.replace(ep_mesh=()), params))
except NotImplementedError as e:
    msg = str(e)
    assert "expert-parallel" in msg and "spec" in msg, msg
    print("spec+EP refused OK")
else:
    raise AssertionError("spec_draft over an EP mesh must refuse")
""", n_dev=4)
