"""MoQ quantization subsystem (repro/quant + kernels/expert_mlp_quant):
QuantizedArray numerics/pytree behavior, PTQ policies, the Pallas
dequant-in-kernel expert MLP vs its einsum oracle, end-to-end serving parity,
and checkpoint round-trips."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.base import QuantConfig
from repro.core.moe import set_quant_expert_backend
from repro.core.prmoe import nlg_moe
from repro.kernels.expert_mlp_quant import expert_mlp_quant, expert_mlp_quant_ref
from repro.kernels.ref import expert_mlp_ref
from repro.models.model import forward, init_params
from repro.quant import (
    QuantizedArray,
    dequantize_params,
    materialize,
    quantize_params,
    quantized_leaf_paths,
    tree_bytes,
)
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine, EngineConfig, Request


def _moe_cfg(vocab=512):
    return nlg_moe("quant-test", 4, 128, 4, 8, vocab=vocab).replace(
        param_dtype="float32", compute_dtype="float32"
    )


# ---------------------------------------------------------------------------
# QuantizedArray
# ---------------------------------------------------------------------------


class TestQuantizedArray:
    def test_int8_roundtrip_error(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (3, 64, 32), jnp.float32)
        qa = QuantizedArray.quantize(w, bits=8, reduce_axes=(-2,))
        rel = float(jnp.abs(qa.dequantize() - w).max() / jnp.abs(w).max())
        assert rel < 0.01
        assert qa.q.dtype == jnp.int8
        assert qa.scale.shape == (3, 1, 32)
        assert qa.shape == w.shape and qa.dtype == w.dtype

    def test_int4_packing_and_groups(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16), jnp.float32)
        qa = QuantizedArray.quantize(w, bits=4, group_size=16, reduce_axes=(-2,))
        assert qa.q.shape == (2, 32, 16)  # two nibbles per byte along axis -2
        assert qa.scale.shape == (2, 4, 16)  # 64/16 groups
        assert qa.shape == (2, 64, 16)
        rel = float(jnp.abs(qa.dequantize() - w).max() / jnp.abs(w).max())
        assert rel < 0.15
        # quantizing the dequantized values is a fixed point (exact int match)
        qa2 = QuantizedArray.quantize(qa.dequantize(), bits=4, group_size=16, reduce_axes=(-2,))
        np.testing.assert_array_equal(np.asarray(qa.q), np.asarray(qa2.q))

    def test_attention_shapes(self):
        wq = jax.random.normal(jax.random.PRNGKey(2), (32, 4, 16))
        qa = QuantizedArray.quantize(wq, bits=8, reduce_axes=(-3,))
        assert qa.scale.shape == (1, 4, 16)
        wo = jax.random.normal(jax.random.PRNGKey(3), (4, 16, 32))
        qo = QuantizedArray.quantize(wo, bits=8, reduce_axes=(-3, -2))
        assert qo.scale.shape == (1, 1, 32)

    def test_pytree_jit_and_scan_slicing(self):
        w = jax.random.normal(jax.random.PRNGKey(4), (3, 64, 32))
        qa = QuantizedArray.quantize(w)
        y = jax.jit(lambda qa, x: x @ materialize(qa)[0])(qa, jnp.ones((5, 64)))
        assert y.shape == (5, 32)
        # leading-axis slicing (what lax.scan does to stacked layer params)
        sliced = jax.tree_util.tree_map(lambda l: l[1], qa)
        np.testing.assert_allclose(
            np.asarray(sliced.dequantize()), np.asarray(qa.dequantize()[1]), rtol=1e-6
        )
        # keyed flatten exposes .q/.scale children (checkpoint manifest names)
        paths = [jax.tree_util.keystr(p) for p, _ in jax.tree_util.tree_flatten_with_path(qa)[0]]
        assert paths == [".q", ".scale"]

    def test_rejects_bad_args(self):
        w = jnp.ones((8, 8))
        with pytest.raises(ValueError):
            QuantizedArray.quantize(w, bits=3)
        with pytest.raises(ValueError):
            QuantizedArray.quantize(w, bits=4, group_size=3)
        with pytest.raises(ValueError):
            QuantizedArray.quantize(w, bits=8, group_size=5)


# ---------------------------------------------------------------------------
# PTQ policies
# ---------------------------------------------------------------------------


class TestPTQ:
    def test_experts_only_policy(self):
        cfg = _moe_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        qp = quantize_params(params, QuantConfig(bits=8, policy="experts"))
        paths = quantized_leaf_paths(qp)
        assert paths and all("/moe/" in p for p in paths)
        # router / norms / embeddings untouched
        assert not any("router" in p or "norm" in p or "embed" in p for p in paths)

    def test_policy_widening(self):
        cfg = _moe_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        n_exp = len(quantized_leaf_paths(quantize_params(params, QuantConfig(policy="experts"))))
        n_attn = len(
            quantized_leaf_paths(quantize_params(params, QuantConfig(policy="experts_attn")))
        )
        n_all = len(quantized_leaf_paths(quantize_params(params, QuantConfig(policy="all"))))
        assert n_exp < n_attn < n_all
        with pytest.raises(ValueError):
            quantize_params(params, QuantConfig(policy="everything"))

    def test_expert_bytes_reduction_3x(self):
        """Acceptance: int8+scales vs fp32 expert bytes >= 3x smaller."""
        cfg = _moe_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        qp = quantize_params(params, QuantConfig(bits=8, policy="experts"))
        fp_expert = tree_bytes(params) - (tree_bytes(qp) - tree_bytes(qp, only_quantized=True))
        q_expert = tree_bytes(qp, only_quantized=True)
        assert fp_expert / q_expert >= 3.0

    def test_dequantize_params_restores_structure(self):
        cfg = _moe_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        qp = quantize_params(params, QuantConfig(bits=8, policy="all"))
        deq = dequantize_params(qp)
        assert jax.tree_util.tree_structure(deq) == jax.tree_util.tree_structure(params)


# ---------------------------------------------------------------------------
# Pallas dequant-in-kernel expert MLP
# ---------------------------------------------------------------------------


class TestQuantKernel:
    @pytest.mark.parametrize("E,C,D,F", [(2, 128, 64, 256), (4, 256, 128, 256), (3, 128, 32, 512)])
    def test_kernel_matches_einsum_ref(self, E, C, D, F):
        k = jax.random.PRNGKey(E * C + D + F)
        xe = jax.random.normal(jax.random.fold_in(k, 1), (E, C, D), jnp.float32)
        wi = jax.random.normal(jax.random.fold_in(k, 2), (E, D, F)) / np.sqrt(D)
        wg = jax.random.normal(jax.random.fold_in(k, 3), (E, D, F)) / np.sqrt(D)
        wo = jax.random.normal(jax.random.fold_in(k, 4), (E, F, D)) / np.sqrt(F)
        qi, qg, qo = (QuantizedArray.quantize(w) for w in (wi, wg, wo))
        got = expert_mlp_quant(xe, qi, qg, qo, interpret=True)
        want = expert_mlp_quant_ref(xe, qi, qg, qo)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-2)

    def test_quant_close_to_fp(self):
        k = jax.random.PRNGKey(7)
        E, C, D, F = 2, 128, 64, 256
        xe = jax.random.normal(jax.random.fold_in(k, 1), (E, C, D), jnp.float32)
        wi = jax.random.normal(jax.random.fold_in(k, 2), (E, D, F)) / np.sqrt(D)
        wg = jax.random.normal(jax.random.fold_in(k, 3), (E, D, F)) / np.sqrt(D)
        wo = jax.random.normal(jax.random.fold_in(k, 4), (E, F, D)) / np.sqrt(F)
        qi, qg, qo = (QuantizedArray.quantize(w) for w in (wi, wg, wo))
        got = expert_mlp_quant_ref(xe, qi, qg, qo)
        fp = expert_mlp_ref(xe, wi, wg, wo)
        scale = float(jnp.abs(fp).max())
        assert float(jnp.abs(got - fp).max()) < 0.05 * max(scale, 1.0)

    def test_kernel_mode_falls_back_on_nondivisible_shapes(self):
        """expert_capacity pads to 8, not 128 — forced-kernel routing must
        fall back to the einsum ref for C not divisible by the block."""
        from repro.core.moe import experts_ffn
        from repro.kernels.expert_mlp_quant import _check_kernel_compat

        k = jax.random.PRNGKey(0)
        E, C, D, F = 2, 136, 32, 256  # C=136: block_c=128 does not divide
        xe = jax.random.normal(jax.random.fold_in(k, 1), (E, C, D), jnp.float32)
        wi = jax.random.normal(jax.random.fold_in(k, 2), (E, D, F)) / np.sqrt(D)
        wg = jax.random.normal(jax.random.fold_in(k, 3), (E, D, F)) / np.sqrt(D)
        wo = jax.random.normal(jax.random.fold_in(k, 4), (E, F, D)) / np.sqrt(F)
        qp = {"wi": QuantizedArray.quantize(wi), "wg": QuantizedArray.quantize(wg),
              "wo": QuantizedArray.quantize(wo)}
        assert not _check_kernel_compat(xe, qp["wi"], qp["wg"], qp["wo"])
        got = experts_ffn(qp, xe, "swiglu", backend="kernel")  # must not crash
        want = expert_mlp_quant_ref(xe, qp["wi"], qp["wg"], qp["wo"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_int8_group_size_is_honored(self):
        cfg = _moe_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        qp = quantize_params(params, QuantConfig(bits=8, group_size=32, policy="experts"))
        leaves = [l for l in jax.tree_util.tree_leaves(
            qp, is_leaf=lambda l: isinstance(l, QuantizedArray)) if isinstance(l, QuantizedArray)]
        assert leaves and all(l.group_size == 32 for l in leaves)

    def test_moe_layer_swiglu_quant_path(self):
        """Full moe_layer with quantized swiglu experts (the kernel-eligible
        layout) stays close to the fp layer output."""
        from repro.configs.base import FFNSpec, ModelConfig
        from repro.core.moe import init_moe, moe_layer

        cfg = ModelConfig(name="q", family="moe", source="t", d_model=64, num_heads=4,
                          num_kv_heads=4, head_dim=16, vocab_size=128, segments=(),
                          param_dtype="float32", compute_dtype="float32")
        spec = FFNSpec(kind="moe", d_ff=128, num_experts=4, top_k=1, act="swiglu")
        params = init_moe(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
        y_fp, _ = moe_layer(cfg, spec, params, x)
        qparams = quantize_params({"moe": params}, QuantConfig(bits=8, policy="experts"))["moe"]
        assert isinstance(qparams["wi"], QuantizedArray) and isinstance(qparams["wg"], QuantizedArray)
        y_q, _ = moe_layer(cfg, spec, qparams, x)
        scale = float(jnp.abs(y_fp).max())
        assert float(jnp.abs(y_q - y_fp).max()) < 0.05 * max(scale, 1.0)

    def test_moe_layer_backend_toggle(self):
        """experts_ffn routes quantized weights through kernel and ref paths
        identically (the kernel is exact vs the ref in interpret mode)."""
        cfg = _moe_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        qp = quantize_params(params, QuantConfig(bits=8, policy="experts"))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
        try:
            set_quant_expert_backend("ref")
            ref_logits, _ = forward(cfg, qp, toks)
        finally:
            set_quant_expert_backend(None)
        # NLG configs use gelu experts -> both modes take the dequant path;
        # just assert the default path agrees with the forced-ref path.
        logits, _ = forward(cfg, qp, toks)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits), atol=1e-5)


# ---------------------------------------------------------------------------
# End-to-end serving parity (acceptance: >= 95% greedy token match)
# ---------------------------------------------------------------------------


class TestServingParity:
    def _generate(self, cfg, params, reqs):
        ec = EngineConfig(max_batch=8, max_prefill=32, max_decode=8)
        return Engine(cfg, params, ec).generate(reqs)

    def test_engine_greedy_matches_fp(self):
        cfg = _moe_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        qp = quantize_params(params, QuantConfig(bits=8, policy="experts"))
        rng = np.random.default_rng(0)
        reqs = [
            Request(prompt=rng.integers(1, cfg.vocab_size, size=16).tolist(), max_new_tokens=8)
            for _ in range(8)
        ]
        fp_out = self._generate(cfg, params, reqs)
        q_out = self._generate(cfg, qp, reqs)
        tot = match = 0
        for a, b in zip(fp_out, q_out):
            assert len(a.tokens) == len(b.tokens)
            tot += len(a.tokens)
            match += sum(int(x == y) for x, y in zip(a.tokens, b.tokens))
        assert match / tot >= 0.95, f"greedy match {match}/{tot}"

    def test_continuous_engine_accepts_quantized(self):
        cfg = _moe_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        qp = quantize_params(params, QuantConfig(bits=8, policy="experts"))
        rng = np.random.default_rng(1)
        prompts = [rng.integers(1, cfg.vocab_size, size=12).tolist() for _ in range(3)]

        def run(p):
            eng = ContinuousEngine(cfg, p, slots=2, capacity=64)
            for pr in prompts:
                eng.submit(Request(prompt=pr, max_new_tokens=6))
            return eng.run_until_done()

        fp_done, q_done = run(params), run(qp)
        assert set(fp_done) == set(q_done)
        tot = match = 0
        for rid in fp_done:
            a, b = fp_done[rid].tokens, q_done[rid].tokens
            tot += len(a)
            match += sum(int(x == y) for x, y in zip(a, b))
        assert match / tot >= 0.95


# ---------------------------------------------------------------------------
# Checkpoint round-trip
# ---------------------------------------------------------------------------


class TestCheckpointRoundtrip:
    def test_quantized_tree_roundtrips(self, tmp_path):
        cfg = _moe_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        qp = quantize_params(params, QuantConfig(bits=4, group_size=16, policy="experts"))
        ckpt.save(str(tmp_path / "q"), qp, step=7)
        like = quantize_params(
            init_params(cfg, jax.random.PRNGKey(1)), QuantConfig(bits=4, group_size=16)
        )
        loaded, step = ckpt.load(str(tmp_path / "q"), like)
        assert step == 7
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), qp, loaded
        )
        # metadata survives via the like-tree
        leaves = jax.tree_util.tree_leaves(
            loaded, is_leaf=lambda l: isinstance(l, QuantizedArray)
        )
        assert any(isinstance(l, QuantizedArray) and l.bits == 4 for l in leaves)

    def test_fp_checkpoint_into_quantized_like_fails_clearly(self, tmp_path):
        cfg = _moe_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        ckpt.save(str(tmp_path / "fp"), params, step=0)
        like = quantize_params(params, QuantConfig(bits=8, policy="experts"))
        with pytest.raises(ValueError, match="missing"):
            ckpt.load(str(tmp_path / "fp"), like)

    def test_shape_mismatch_fails_clearly(self, tmp_path):
        ckpt.save(str(tmp_path / "c"), {"x": jnp.ones((3,))}, step=0)
        with pytest.raises(ValueError, match="shape"):
            ckpt.load(str(tmp_path / "c"), {"x": jnp.ones((4,))})

    def test_missing_dir_fails_clearly(self, tmp_path):
        with pytest.raises(ValueError, match="manifest"):
            ckpt.load(str(tmp_path / "nope"), {"x": jnp.ones((1,))})
