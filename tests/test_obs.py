"""Observability layer (src/repro/obs/): histogram percentile math, tracer
nesting + Chrome trace_event schema, SLO accounting through a hand-scheduled
two-request run, routing-stats parity with ``load_balance_stats`` under jit,
and the retrace watchdog's steady-state contract."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gating import (
    load_balance_stats,
    routing_stats,
    summarize_routing,
    top_k_gating,
)
from repro.core.prmoe import nlg_moe
from repro.models.model import forward, init_params
from repro.obs import MetricsRegistry, Obs, RetraceWatchdog, Tracer, jit_cache_size
from repro.obs.metrics import Histogram
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine, EngineConfig, Request


@pytest.fixture(scope="module")
def setup():
    cfg = nlg_moe("obs-test", 2, 64, 2, 8, vocab=128).replace(
        param_dtype="float32", compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# Histogram percentile math
# ---------------------------------------------------------------------------


class TestHistogram:
    def _bucket_ratio(self, h: Histogram) -> float:
        """One bucket's geometric width — the percentile error bound."""
        return (h.hi / h.lo) ** (1.0 / (len(h.counts) - 2))

    @pytest.mark.parametrize("q", [0.5, 0.9, 0.95, 0.99])
    def test_uniform_within_bucket_error(self, q):
        h = Histogram("t", lo=1e-3, hi=10.0, n_buckets=64)
        xs = np.linspace(0.01, 1.0, 20_000)
        for v in xs:
            h.observe(float(v))
        true = float(np.quantile(xs, q))
        est = h.percentile(q)
        r = self._bucket_ratio(h)
        assert true / r <= est <= true * r, (q, est, true, r)

    @pytest.mark.parametrize("q", [0.5, 0.9, 0.99])
    def test_exponential_within_bucket_error(self, q):
        h = Histogram("t", lo=1e-4, hi=100.0, n_buckets=64)
        xs = np.random.default_rng(0).exponential(scale=0.05, size=50_000)
        for v in xs:
            h.observe(float(v))
        true = float(np.quantile(xs, q))
        est = h.percentile(q)
        r = self._bucket_ratio(h)
        assert true / r <= est <= true * r, (q, est, true, r)

    def test_percentiles_monotone_and_clamped(self):
        h = Histogram("t", lo=1e-3, hi=1.0, n_buckets=16)
        # values straddling underflow and overflow buckets
        for v in (0.0, 1e-5, 0.01, 0.2, 5.0, 40.0):
            h.observe(v)
        ps = [h.percentile(q) for q in (0.0, 0.25, 0.5, 0.75, 0.99, 1.0)]
        assert all(a <= b + 1e-12 for a, b in zip(ps, ps[1:])), ps
        assert all(h.min_seen <= p <= h.max_seen for p in ps), ps

    def test_exact_aggregates_and_edge_cases(self):
        h = Histogram("t", lo=1e-3, hi=1.0, n_buckets=8)
        assert math.isnan(h.percentile(0.5)) and math.isnan(h.mean)
        h.observe(0.25)
        assert h.percentile(0.99) == 0.25  # single sample -> the sample
        h.observe(0.75)
        assert h.count == 2 and h.total == pytest.approx(1.0)
        assert h.mean == pytest.approx(0.5)
        assert h.min_seen == 0.25 and h.max_seen == 0.75

    def test_snapshot_schema(self):
        h = Histogram("t", unit="s")
        assert h.snapshot() == {"count": 0, "unit": "s"}
        h.observe(0.1)
        snap = h.snapshot()
        for k in ("count", "sum", "mean", "min", "max", "p50", "p95", "p99"):
            assert k in snap


# ---------------------------------------------------------------------------
# Tracer: nesting + export schema
# ---------------------------------------------------------------------------


def _span_stacks_balanced(events):
    depth = {}
    for e in events:
        if e["ph"] == "B":
            depth[(e["pid"], e["tid"])] = depth.get((e["pid"], e["tid"]), 0) + 1
        elif e["ph"] == "E":
            k = (e["pid"], e["tid"])
            depth[k] = depth.get(k, 0) - 1
            assert depth[k] >= 0, "E without matching B"
    return all(v == 0 for v in depth.values())


class TestTracer:
    def test_nesting_lifo(self):
        tr = Tracer()
        tr.begin(("engine", 0), "outer")
        tr.begin(("engine", 0), "inner")
        tr.end(("engine", 0))
        tr.end(("engine", 0))
        evs = [e for e in tr.trace_events() if e["ph"] in "BE"]
        assert [e["name"] for e in evs] == ["outer", "inner", "inner", "outer"]
        assert _span_stacks_balanced(evs)

    def test_close_open_at_export(self):
        tr = Tracer()
        tr.begin(("slot", 1), "decode")
        evs = tr.trace_events(close_open=True)
        assert _span_stacks_balanced([e for e in evs if e["ph"] in "BE"])
        # the live tracer still considers the span open
        tr.end(("slot", 1))
        assert _span_stacks_balanced(
            [e for e in tr.trace_events(close_open=False) if e["ph"] in "BE"])

    def test_export_schema(self, tmp_path):
        tr = Tracer()
        with tr.span(("engine", 0), "tick", args={"n": 1}):
            tr.instant(("request", 7), "preempted")
        tr.end(("engine", 0))  # stray end tolerated
        path = tmp_path / "trace.json"
        n = tr.export(str(path))
        doc = json.loads(path.read_text())
        assert set(doc) == {"traceEvents", "displayTimeUnit"}
        assert len(doc["traceEvents"]) == n
        for e in doc["traceEvents"]:
            assert e["ph"] in ("B", "E", "i", "M")
            assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
            if e["ph"] != "M":
                assert e["ts"] >= 0
        # metadata names both track groups
        meta = {e["args"]["name"] for e in doc["traceEvents"] if e["ph"] == "M"
                and e["name"] == "process_name"}
        assert meta == {"engine", "request"}

    def test_timestamps_monotone_per_span(self):
        tr = Tracer()
        tr.begin(("engine", 0), "s", ts=5.0)
        tr.end(("engine", 0), ts=1.0)  # out-of-order ts is clamped
        b, e = [ev for ev in tr.trace_events() if ev["ph"] in "BE"]
        assert e["ts"] >= b["ts"]

    def test_disabled_is_noop(self):
        tr = Tracer(enabled=False)
        tr.begin(("engine", 0), "s")
        tr.instant(("engine", 0), "i")
        tr.end(("engine", 0))
        assert tr.n_events == 0 and tr.trace_events() == []


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_reset_all_in_place(self):
        M = MetricsRegistry()
        c, g, h = M.counter("c"), M.gauge("g"), M.histogram("h")
        c.inc(3), g.set(1.5), h.observe(0.1)
        M.reset_all()
        # same objects (engines hold direct references), zeroed state
        assert M.counter("c") is c and c.value == 0
        assert M.gauge("g") is g and g.value is None
        assert M.histogram("h") is h and h.count == 0

    def test_disabled_registry_discards(self):
        M = MetricsRegistry(enabled=False)
        M.counter("c").inc(5)
        assert M.counter("c").value == 0  # fresh throwaway each get
        assert M.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}

    def test_snapshot_render_jsonl_agree(self, tmp_path):
        M = MetricsRegistry()
        M.counter("serve.reqs").inc(2)
        M.gauge("serve.depth").set(3)
        M.histogram("serve.lat_s").observe(0.5)
        path = tmp_path / "m.jsonl"
        M.write_jsonl(str(path), extra={"run": "t"})
        row = json.loads(path.read_text())
        assert row["run"] == "t"
        snap = M.snapshot()
        assert row["counters"] == snap["counters"]
        assert row["histograms"] == snap["histograms"]
        out = M.render()
        assert "serve.reqs=2" in out and "serve.lat_s" in out


# ---------------------------------------------------------------------------
# SLO accounting: hand-scheduled two requests through one slot
# ---------------------------------------------------------------------------


class TestSLOAccounting:
    def test_two_requests_one_slot(self, setup):
        """slots=1 forces request 2 to queue behind request 1's full service:
        queue-wait, TTFT, and TPOT histograms must account every request and
        every decoded token exactly."""
        cfg, params = setup
        obs = Obs(trace=True)
        eng = ContinuousEngine(cfg, params, slots=1, capacity=64, obs=obs)
        prompt = list(range(1, 9))
        n_new = 4
        r1 = eng.submit(Request(prompt=prompt, max_new_tokens=n_new))
        r2 = eng.submit(Request(prompt=prompt[::-1], max_new_tokens=n_new))
        for _ in range(64):  # hand-stepped, bounded
            eng.step()
            if r1 in eng.done and r2 in eng.done:
                break
        assert len(eng.done[r1].tokens) == n_new
        assert len(eng.done[r2].tokens) == n_new

        M = obs.metrics
        assert M.counter("serve.requests_submitted").value == 2
        assert M.counter("serve.requests_completed").value == 2
        # each request's first token comes off the prefill logits, so decode
        # ticks account for the remaining n_new - 1 tokens per request
        assert M.counter("serve.decode_tokens").value == 2 * (n_new - 1)

        q = M.histogram("serve.queue_wait_s")
        ttft = M.histogram("serve.ttft_s")
        tpot = M.histogram("serve.tpot_s")
        assert q.count == 2 and ttft.count == 2
        # every decoded token is TTFT or TPOT, never both
        assert tpot.count == 2 * n_new - 2
        assert ttft.min_seen > 0 and tpot.min_seen > 0
        # r1 is admitted on the first tick (waits ~µs); r2 waits out r1's
        # entire service (>= n_new jitted decode ticks), orders of magnitude
        # longer — and never longer than the whole hand-stepped run
        assert q.max_seen > 10 * q.min_seen
        # r2's TTFT >= its own prefill; every wait is positive and finite
        assert math.isfinite(q.max_seen) and math.isfinite(ttft.max_seen)
        pre = M.histogram("serve.preempts_per_req")
        assert pre.count == 2 and pre.max_seen == 0  # no preemptions occurred

        # lifecycle spans: each request shows queued -> prefill -> decode,
        # balanced, with a complete instant
        evs = obs.tracer.trace_events(close_open=False)
        assert _span_stacks_balanced([e for e in evs if e["ph"] in "BE"])
        req_names = [e["name"] for e in evs
                     if e.get("cat") == "request" and e["ph"] == "B"]
        assert req_names.count("queued") == 2
        assert req_names.count("prefill") == 2
        assert req_names.count("decode") == 2
        completes = [e for e in evs if e["ph"] == "i" and e["name"] == "complete"]
        assert len(completes) == 2

    def test_preemption_accounting_and_trace(self, setup):
        """An oversubscribed pool preempts the youngest slot: the request's
        span stack must re-enter ``queued`` cleanly, preempts land in the
        per-request histogram, and broken TPOT intervals are dropped rather
        than misreported."""
        cfg, params = setup
        obs = Obs(trace=True)
        eng = ContinuousEngine(cfg, params, slots=3, capacity=32, paged=True,
                               page_size=4, n_pages=8, obs=obs)
        rids = [eng.submit(Request(prompt=[i + 1] * 6, max_new_tokens=8))
                for i in range(3)]
        done = eng.run_until_done()
        assert all(len(done[r].tokens) == 8 for r in rids)
        M = obs.metrics
        n_pre = M.counter("serve.preemptions").value
        assert n_pre >= 1  # the pool really was too small
        pre = M.histogram("serve.preempts_per_req")
        assert pre.count == 3 and pre.max_seen >= 1
        # queue-wait observes FIRST admission only — re-admissions after a
        # preemption must not double-count
        assert M.histogram("serve.queue_wait_s").count == 3
        # each preemption breaks one inter-token interval (dropped from TPOT)
        total = sum(len(done[r].tokens) for r in rids)
        assert M.histogram("serve.tpot_s").count <= total - 3
        evs = obs.tracer.trace_events(close_open=False)
        assert _span_stacks_balanced([e for e in evs if e["ph"] in "BE"])
        preempted = [e for e in evs if e["ph"] == "i" and e["name"] == "preempted"]
        assert len(preempted) == n_pre
        # a preempted request re-enters queued before decoding again
        req_b = [e["name"] for e in evs if e.get("cat") == "request"
                 and e["ph"] == "B"]
        assert req_b.count("queued") == 3 + n_pre

    def test_tick_histogram_counts_ticks(self, setup):
        cfg, params = setup
        obs = Obs()
        eng = ContinuousEngine(cfg, params, slots=2, capacity=32, obs=obs)
        eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=3))
        eng.run_until_done()
        h = obs.metrics.histogram("serve.tick_s")
        assert h.count == len(eng.metrics_log)  # one observation per tick
        assert h.min_seen > 0


# ---------------------------------------------------------------------------
# Routing stats: parity with load_balance_stats under jit
# ---------------------------------------------------------------------------


class TestRoutingStats:
    def test_parity_with_load_balance_stats_under_jit(self):
        T, E, k, cap = 64, 8, 2, 24

        @jax.jit
        def both(logits):
            g = top_k_gating(logits, k, cap)
            return routing_stats(g, E), load_balance_stats(g.probs, g.expert_idx, E)

        logits = jax.random.normal(jax.random.PRNGKey(3), (T, E))
        rs, (f, p) = both(logits)
        # f/P inside RoutingStats ARE load_balance_stats — exact, not approx
        np.testing.assert_array_equal(np.asarray(rs.f), np.asarray(f))
        np.testing.assert_array_equal(np.asarray(rs.p), np.asarray(p))
        np.testing.assert_allclose(
            float(rs.imbalance), E * float(jnp.sum(f * p)), rtol=1e-6)

    def test_token_accounting(self):
        T, E, k = 32, 4, 1
        cap = 6  # tight capacity -> guaranteed drops for a skewed router
        logits = jnp.zeros((T, E)).at[:, 0].add(5.0)  # everyone wants expert 0
        g = top_k_gating(logits, k, cap)
        rs = routing_stats(g, E)
        kept = int(np.asarray(rs.tokens_per_expert).sum())
        assert kept == int(np.asarray(g.keep).sum())
        np.testing.assert_allclose(
            float(rs.dropped_frac), 1.0 - kept / (T * k), rtol=1e-6)
        assert float(rs.dropped_frac) > 0  # capacity really did bind

    def test_entropy_bounds(self):
        T, E = 64, 8
        g_uni = top_k_gating(jnp.zeros((T, E)), 1, T)
        assert float(routing_stats(g_uni, E).entropy) == pytest.approx(
            math.log(E), rel=1e-5)
        skew = jnp.zeros((T, E)).at[:, 0].add(100.0)
        g_skew = top_k_gating(skew, 1, T)
        assert float(routing_stats(g_skew, E).entropy) < 0.05

    def test_forward_routing_does_not_change_logits(self, setup):
        cfg, params = setup
        toks = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 0, cfg.vocab_size)
        lg0, aux0 = forward(cfg, params, toks)
        lg1, aux1, routing = forward(cfg, params, toks, return_routing=True)
        np.testing.assert_array_equal(np.asarray(lg0), np.asarray(lg1))
        np.testing.assert_array_equal(np.asarray(aux0), np.asarray(aux1))
        summ = summarize_routing(routing)
        assert summ["moe_layers"] == 1  # 2 layers, every other FFN is MoE
        (layer,) = summ["per_layer"].values()
        assert len(layer["tokens_per_expert"]) == 8
        assert isinstance(summ["dropped_frac"], float)


# ---------------------------------------------------------------------------
# Retrace watchdog
# ---------------------------------------------------------------------------


class _FakeJit:
    """Stands in for a jitted callable: _cache_size() is the trace-cache."""

    def __init__(self):
        self.n = 0

    def _cache_size(self):
        return self.n


class TestRetraceWatchdog:
    def test_steady_state_warning_fires_for_primary_only(self):
        warns = []
        wd = RetraceWatchdog(steady_after=2, warn_fn=warns.append)
        dec, pre = _FakeJit(), _FakeJit()
        wd.register("decode", dec)
        wd.register("prefill", pre, aux=True)

        dec.n = 1  # warmup compile
        assert wd.tick() == 1 and not warns and not wd.steady
        assert wd.tick() == 0
        assert wd.tick() == 0 and wd.steady
        pre.n = 1  # aux compile after steady: counted, never warned
        assert wd.tick() == 1
        assert not warns and wd.steady_retraces == 0 and wd.steady
        dec.n = 2  # primary retrace after steady: the bug this exists for
        assert wd.tick() == 1
        assert len(warns) == 1 and "decode(+1)" in warns[0]
        assert wd.steady_retraces == 1
        assert wd.total_compiles == 3
        snap = wd.snapshot()
        assert snap["steady_retraces"] == 1 and snap["per_fn"]["decode"] == 2

    def test_late_first_compile_is_warmup_not_retrace(self):
        """All slots can spend the early ticks in chunked prefill, so the
        decode fn's first compile may land AFTER the zero-compile streak
        declared the engine steady — that is warmup, not a retrace."""
        warns = []
        wd = RetraceWatchdog(steady_after=2, warn_fn=warns.append)
        dec = _FakeJit()
        wd.register("decode", dec)
        wd.tick(), wd.tick(), wd.tick()
        assert wd.steady
        dec.n = 1  # first-ever compile, post-steady
        assert wd.tick() == 1
        assert not warns and wd.steady_retraces == 0
        dec.n = 2  # now a genuine retrace
        wd.tick()
        assert len(warns) == 1 and wd.steady_retraces == 1

    def test_jit_cache_size_real_jit(self):
        f = jax.jit(lambda x: x + 1)
        n0 = jit_cache_size(f)
        if n0 is None:
            pytest.skip("this jax does not expose _cache_size")
        f(jnp.ones((2,)))
        assert jit_cache_size(f) == n0 + 1
        f(jnp.ones((2,)))  # cache hit
        assert jit_cache_size(f) == n0 + 1
        f(jnp.ones((3,)))  # new shape -> retrace
        assert jit_cache_size(f) == n0 + 2

    def test_inactive_without_cache_accessor(self):
        wd = RetraceWatchdog()
        wd.register("f", object())
        assert wd.tick() == 0
        assert wd.active is False

    def test_engine_steady_state_zero_retrace_regression(self, setup):
        """A full continuous-batching run — staggered admissions, chunked
        prefill, completions — must never retrace the decode tick after
        steady state.  This is the regression the watchdog exists to catch."""
        cfg, params = setup
        obs = Obs()
        eng = ContinuousEngine(cfg, params, slots=2, capacity=64, paged=True,
                               page_size=8, obs=obs)
        eng.submit(Request(prompt=list(range(1, 9)), max_new_tokens=10))
        for _ in range(12):
            eng.step()
        eng.submit(Request(prompt=list(range(9, 29)), max_new_tokens=6))
        eng.run_until_done()
        snap = obs.watchdog.snapshot()
        assert snap["active"] and snap["steady"]
        assert snap["steady_retraces"] == 0
        assert obs.metrics.counter("serve.retraces").value == snap["total_compiles"]


# ---------------------------------------------------------------------------
# Engine + trainer smoke: telemetry on, results unchanged
# ---------------------------------------------------------------------------


class TestIntegration:
    def test_static_engine_obs_parity(self, setup):
        cfg, params = setup
        reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=4),
                Request(prompt=[5, 6, 7], max_new_tokens=4)]
        ec = EngineConfig(max_batch=2, max_prefill=8, max_decode=4)
        base = Engine(cfg, params, ec, obs=Obs.disabled()).generate(reqs)
        obs = Obs(routing=True)
        eng = Engine(cfg, params, ec, obs=obs)
        out = eng.generate(reqs)
        # telemetry must not perturb greedy decoding
        assert [r.tokens for r in out] == [r.tokens for r in base]
        assert obs.metrics.histogram("serve.batch_prefill_s").count == 1
        assert obs.metrics.histogram("serve.decode_step_s").count > 0
        assert obs.metrics.counter("serve.decode_tokens").value == 8
        assert eng.last_routing is not None and eng.last_routing["moe_layers"] == 1
        assert obs.metrics.gauge("routing.entropy").value is not None

    def test_continuous_engine_routing_metrics(self, setup):
        cfg, params = setup
        obs = Obs(routing=True)
        eng = ContinuousEngine(cfg, params, slots=2, capacity=32, obs=obs)
        eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
        eng.run_until_done()
        m = eng.last_metrics
        assert "routing" in m and m["routing"]["moe_layers"] == 1
        assert obs.metrics.gauge("routing.dropped_frac").value is not None

    def test_trainer_routing_in_history_and_sink(self, setup):
        from repro.data.pipeline import data_stream
        from repro.training.trainer import TrainConfig, train_loop

        cfg, _ = setup
        rows = []
        _, _, history = train_loop(
            cfg, TrainConfig(lr=1e-3, warmup_steps=1, decay_steps=4),
            data_stream(cfg.vocab_size, 2, 16), num_steps=2,
            log_every=1, log_fn=lambda s: None,
            routing_stats=True, metrics_sink=rows.append,
        )
        assert rows == history and len(history) == 2
        for row in history:
            r = row["routing"]
            assert set(r) >= {"moe_layers", "dropped_frac", "entropy",
                              "imbalance", "per_layer"}
            assert r["moe_layers"] == 1


# ---------------------------------------------------------------------------
# Speculative decoding: accept-rate/tokens-per-verify metrics + spans
# ---------------------------------------------------------------------------


class TestSpeculationObs:
    def test_spec_metrics_and_spans_from_one_snapshot(self, setup):
        """Self-draft run: every spec counter/histogram and the per-request
        speculation lifecycle must be consistent inside ONE ``snapshot()``
        (the same dict ``--metrics-out`` writes), and the engine tick trace
        must carry the spec_draft -> spec_verify -> spec_commit span triple
        plus one spec_commit instant per verify window."""
        cfg, params = setup
        obs = Obs(trace=True)
        k = 3
        eng = ContinuousEngine(cfg, params, slots=2, capacity=64, paged=True,
                               page_size=4, spec_draft=(cfg, params),
                               spec_k=k, obs=obs)
        rids = [eng.submit(Request(prompt=[i + 1] * 6, max_new_tokens=9))
                for i in range(3)]
        done = eng.run_until_done()
        assert all(len(done[r].tokens) == 9 for r in rids)

        snap = obs.metrics.snapshot()
        c = snap["counters"]
        windows = c["spec.verify_windows"]
        drafted = c["spec.draft_tokens"]
        accepted = c["spec.accepted_tokens"]
        assert windows > 0 and drafted > 0
        assert accepted == drafted, "self-draft must accept every token"
        assert c["spec.rolled_back_pages"] == 0
        assert c["spec.committed_pages"] > 0
        assert c["spec.draft_resyncs"] == 0

        h_rate = snap["histograms"]["spec.accept_rate"]
        h_tok = snap["histograms"]["spec.tokens_per_verify"]
        # tokens_per_verify observes EVERY window; accept_rate only k>0 ones
        assert h_tok["count"] == windows
        assert 0 < h_rate["count"] <= windows
        assert h_rate["max"] == 1.0  # self-draft: every rate is exactly 1
        assert h_rate["min"] == 1.0
        assert 1.0 <= h_tok["min"] <= h_tok["max"] <= k + 1
        # every decoded token was emitted by a verify window: the TPOT
        # histogram and the emitted totals must agree with decode_tokens
        emitted = sum(s["emitted"]
                      for m in eng.metrics_log for s in [m.get("spec")] if s)
        assert c["serve.decode_tokens"] == emitted

        evs = obs.tracer.trace_events(close_open=False)
        assert _span_stacks_balanced([e for e in evs if e["ph"] in "BE"])
        eng_spans = [e["name"] for e in evs
                     if e.get("cat") == "engine" and e["ph"] == "B"]
        n_draft = eng_spans.count("spec_draft")
        assert n_draft > 0
        assert eng_spans.count("spec_verify") == n_draft
        assert eng_spans.count("spec_commit") == n_draft
        commits = [e for e in evs if e["ph"] == "i"
                   and e["name"] == "spec_commit"]
        assert len(commits) == windows
        for e in commits:
            a = e["args"]
            assert 0 <= a["accepted"] <= a["drafted"] <= k
            assert 1 <= a["emitted"] <= a["accepted"] + 1

    def test_spec_rollback_and_resync_metrics(self, setup):
        """A fresh-init drafter rejects nearly everything: rolled-back pages
        must show up, accept_rate must fall below 1, and (this config mixes
        non-paged state) partial accepts must resync the drafter."""
        cfg, params = setup
        dparams = init_params(cfg, jax.random.PRNGKey(7))
        obs = Obs()
        eng = ContinuousEngine(cfg, params, slots=2, capacity=64, paged=True,
                               page_size=4, spec_draft=(cfg, dparams),
                               spec_k=3, obs=obs)
        rid = eng.submit(Request(prompt=[3, 1, 4, 1, 5], max_new_tokens=10))
        eng.run_until_done()
        snap = obs.metrics.snapshot()
        c = snap["counters"]
        assert c["spec.accepted_tokens"] < c["spec.draft_tokens"]
        assert c["spec.rolled_back_pages"] > 0
        h_rate = snap["histograms"]["spec.accept_rate"]
        assert h_rate["count"] > 0 and h_rate["min"] < 1.0
