"""MoE layer tests: dispatch equivalences (einsum == dense mapping table),
residual branch, gradients, aux loss wiring — the §5.4 correctness story."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a single-draw fallback shim

from repro.configs.base import FFNSpec, ModelConfig
from repro.core import dispatch, dispatch_einsum
from repro.core.gating import expert_capacity, top_k_gating
from repro.core.moe import experts_ffn, init_moe, moe_layer


def tiny_cfg(**kw):
    return ModelConfig(
        name="t", family="moe", source="x", d_model=32, num_heads=2, num_kv_heads=2,
        head_dim=16, vocab_size=64, segments=(),
        param_dtype="float32", compute_dtype="float32", **kw,
    )


def make(spec_kw=None, seed=0):
    cfg = tiny_cfg()
    spec = FFNSpec(kind="moe", d_ff=64, num_experts=8, top_k=2, capacity_factor=2.0,
                   **(spec_kw or {}))
    params = init_moe(jax.random.PRNGKey(seed), cfg, spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, 32))
    return cfg, spec, params, x


class TestDispatchEquivalence:
    def test_dense_equals_einsum(self):
        cfg, spec, params, x = make()
        y1, a1 = moe_layer(cfg, spec, params, x, impl="dense")
        y2, a2 = moe_layer(cfg, spec, params, x, impl="einsum")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
        assert abs(float(a1) - float(a2)) < 1e-6

    def test_grads_match(self):
        cfg, spec, params, x = make()

        def loss(p, impl):
            y, a = moe_layer(cfg, spec, p, x, impl=impl)
            return jnp.sum(y**2) + 0.01 * a

        g1 = jax.grad(loss)(params, "dense")
        g2 = jax.grad(loss)(params, "einsum")
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4),
            g1, g2,
        )

    @settings(max_examples=10, deadline=None)
    @given(E=st.sampled_from([2, 4, 8]), K=st.integers(1, 2), seed=st.integers(0, 50))
    def test_property_equivalence(self, E, K, seed):
        cfg = tiny_cfg()
        spec = FFNSpec(kind="moe", d_ff=32, num_experts=E, top_k=min(K, E), capacity_factor=4.0)
        params = init_moe(jax.random.PRNGKey(seed), cfg, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(seed + 99), (1, 24, 32))
        y1, _ = moe_layer(cfg, spec, params, x, impl="dense")
        y2, _ = moe_layer(cfg, spec, params, x, impl="einsum")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


class TestDispatchPrimitives:
    def test_roundtrip_no_drop(self):
        """dispatch then combine with weight 1 reconstructs kept tokens."""
        T, D, E = 32, 16, 4
        x = jax.random.normal(jax.random.PRNGKey(0), (T, D))
        logits = jax.random.normal(jax.random.PRNGKey(1), (T, E))
        cap = expert_capacity(T, E, 1, 4.0)  # ample capacity: nothing dropped
        g = top_k_gating(logits, 1, cap)
        assert bool(jnp.all(g.keep))
        buf = dispatch.dispatch_dense(x, g, cap, E)
        # identity expert
        y = dispatch.combine_dense(buf, g._replace(combine_w=jnp.ones_like(g.combine_w)), cap, E)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)

    def test_dropped_tokens_get_zero(self):
        T, D, E = 16, 8, 2
        logits = jnp.zeros((T, E)).at[:, 0].set(5.0)  # all to expert 0
        x = jax.random.normal(jax.random.PRNGKey(0), (T, D))
        g = top_k_gating(logits, 1, 8)
        y = dispatch.moe_dense(x, g, 8, E, lambda b: b)
        dropped = ~np.asarray(g.keep[:, 0])
        assert np.all(np.asarray(y)[dropped] == 0.0)

    def test_einsum_dispatch_tensors(self):
        T, E = 16, 4
        g = top_k_gating(jax.random.normal(jax.random.PRNGKey(2), (T, E)), 2, 8)
        disp, comb = dispatch_einsum.dispatch_combine_tensors(g, 8)
        assert disp.shape == (T, E, 8) and comb.shape == (T, E, 8)
        # each kept (token, k) occupies exactly one (e, c) slot
        assert int(disp.sum()) == int(g.keep.sum())


class TestResidualMoE:
    def test_residual_branch_adds(self):
        cfg, spec, params, x = make({"residual": True, "residual_d_ff": 64})
        y_res, _ = moe_layer(cfg, spec, params, x, impl="dense")
        y_no, _ = moe_layer(cfg, spec.__class__(**{**spec.__dict__, "residual": False}), params, x, impl="dense")
        from repro.models.modules import mlp

        manual = y_no + mlp(params["residual"], x, spec.act)
        np.testing.assert_allclose(np.asarray(y_res), np.asarray(manual), atol=1e-5)

    def test_residual_param_shapes(self):
        cfg, spec, params, _ = make({"residual": True, "residual_d_ff": 48})
        assert params["residual"]["wi"].shape == (32, 48)


class TestExpertsFFN:
    def test_matches_per_expert_mlp(self):
        cfg, spec, params, _ = make()
        xe = jax.random.normal(jax.random.PRNGKey(5), (8, 4, 32))
        y = experts_ffn(params, xe, "swiglu")
        for e in range(8):
            he = xe[e] @ params["wi"][e]
            ge = jax.nn.silu(xe[e] @ params["wg"][e])
            ref = (ge * he) @ params["wo"][e]
            np.testing.assert_allclose(np.asarray(y[e]), np.asarray(ref), atol=1e-4)
