"""Quantized KV cache (repro/quant/kv.py + kernels/attention_quant.py +
models/attention.py cache paths): QuantizedKV numerics/pytree behavior, the
Pallas dequant-in-kernel decode attention vs its einsum oracle, cache
write/read round-trips, end-to-end decode parity against the fp cache, the
cache-byte reduction claim, and continuous-batching slot reuse."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.core.prmoe import nlg_moe
from repro.kernels.attention_quant import decode_attention_quant, decode_attention_quant_ref
from repro.models.attention import init_kv_cache, _cache_write_decode, _cache_write_prefill
from repro.models.model import (
    decode_step,
    init_caches,
    init_params,
    prefill,
    ragged_decode_step,
)
from repro.quant import QuantizedKV, kv_cache_bytes, kv_quantize_values, materialize_kv
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine, EngineConfig, Request


def _demo_cfg(vocab=512, layers=4, d_model=192, heads=4, experts=16):
    """Same family/shape as examples/quantize_and_serve.py's demo model
    (head_dim = 48, the shape the ≥3.5x cache-byte claim is made on)."""
    return nlg_moe("kv-quant-test", layers, d_model, heads, experts, vocab=vocab).replace(
        param_dtype="float32", compute_dtype="float32"
    )


# ---------------------------------------------------------------------------
# QuantizedKV numerics + pytree behavior
# ---------------------------------------------------------------------------


class TestQuantizedKV:
    def test_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 4, 48))
        kv = QuantizedKV.quantize(x)
        err = jnp.max(jnp.abs(kv.dequantize() - x))
        # symmetric int8: error <= scale/2 = amax/254 per (t, h) group
        bound = jnp.max(jnp.abs(x)) / 254.0 + 1e-6
        assert float(err) <= float(bound)
        assert kv.q.dtype == jnp.int8 and kv.scale.dtype == jnp.float32
        assert kv.scale.shape == (2, 32, 4, 1)

    def test_zeros_dequantize_exact(self):
        kv = QuantizedKV.zeros((1, 8, 2, 16), jnp.float32)
        np.testing.assert_array_equal(np.asarray(kv.dequantize()), 0.0)

    def test_per_timestep_scales_are_independent(self):
        """A huge token must not degrade other timesteps' resolution."""
        x = jnp.ones((1, 4, 1, 16)) * 0.01
        x = x.at[0, 2].set(1000.0)
        kv = QuantizedKV.quantize(x)
        err_small = jnp.max(jnp.abs(kv.dequantize()[0, 0] - x[0, 0]))
        assert float(err_small) < 1e-4  # would be ~4.0 with a shared scale

    def test_pytree_flatten_keys_and_jit(self):
        kv = QuantizedKV.quantize(jax.random.normal(jax.random.PRNGKey(1), (2, 8, 2, 16)))
        kvs, treedef = jax.tree_util.tree_flatten_with_path(kv)
        names = ["".join(str(p) for p in path) for path, _ in kvs]
        assert names == [".q", ".scale"]  # checkpoint manifest names
        out = jax.jit(lambda c: c.dequantize())(kv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(kv.dequantize()))

    def test_scan_slices_leading_axis_consistently(self):
        stacked = QuantizedKV.quantize(jax.random.normal(jax.random.PRNGKey(2), (3, 2, 8, 2, 16)))

        def body(c, kv):
            return c, jnp.sum(kv.dequantize())

        _, sums = jax.lax.scan(body, 0.0, stacked)
        want = [float(jnp.sum(stacked.dequantize()[i])) for i in range(3)]
        np.testing.assert_allclose(np.asarray(sums), want, rtol=1e-6)

    def test_materialize_kv_passthrough(self):
        x = jnp.ones((2, 3))
        assert materialize_kv(x) is x

    def test_nbytes_counts_ints_plus_scales(self):
        kv = QuantizedKV.zeros((1, 16, 2, 48), jnp.float32)
        assert kv.nbytes == 16 * 2 * 48 + 16 * 2 * 4


# ---------------------------------------------------------------------------
# Pallas decode kernel vs einsum oracle
# ---------------------------------------------------------------------------


class TestDecodeKernel:
    @pytest.mark.parametrize("window,softcap", [(0, 0.0), (16, 0.0), (0, 30.0)])
    def test_kernel_matches_ref(self, window, softcap):
        B, T, Hkv, G, dh = 3, 48, 2, 3, 16
        k = jax.random.normal(jax.random.PRNGKey(0), (B, T, Hkv, dh))
        v = jax.random.normal(jax.random.PRNGKey(1), (B, T, Hkv, dh))
        q = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, G, dh))
        kq, ks = kv_quantize_values(k)
        vq, vs = kv_quantize_values(v)
        kpos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        kpos = kpos.at[:, 40:].set(-1)  # empty ring slots
        qpos = jnp.full((B, 1), 39, jnp.int32)
        args = dict(scale=0.25, window=window, softcap=softcap)
        yk = decode_attention_quant(q, kq, ks, vq, vs, kpos, qpos, **args)
        yr = decode_attention_quant_ref(q, kq, ks, vq, vs, kpos, qpos, **args)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-5)

    def test_kernel_tiles_nondivisible_t(self):
        """T=48 with block 128 falls back to a fitting divisor tile."""
        B, T, Hkv, G, dh = 1, 40, 1, 2, 16
        k = jax.random.normal(jax.random.PRNGKey(0), (B, T, Hkv, dh))
        q = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, G, dh))
        kq, ks = kv_quantize_values(k)
        kpos = jnp.arange(T, dtype=jnp.int32)[None]
        qpos = jnp.full((B, 1), T - 1, jnp.int32)
        yk = decode_attention_quant(q, kq, ks, kq, ks, kpos, qpos, scale=0.25, block_t=16)
        yr = decode_attention_quant_ref(q, kq, ks, kq, ks, kpos, qpos, scale=0.25)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-5)

    def test_ref_matches_fp_attention_closely(self):
        """Quantization error at the attention output stays ~1% scale."""
        B, T, Hkv, G, dh = 2, 32, 2, 2, 32
        k = jax.random.normal(jax.random.PRNGKey(0), (B, T, Hkv, dh))
        v = jax.random.normal(jax.random.PRNGKey(1), (B, T, Hkv, dh))
        q = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, G, dh))
        kq, ks = kv_quantize_values(k)
        vq, vs = kv_quantize_values(v)
        kpos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        qpos = jnp.full((B, 1), T - 1, jnp.int32)
        yq = decode_attention_quant_ref(q, kq, ks, vq, vs, kpos, qpos, scale=dh**-0.5)
        # fp oracle
        s = jnp.einsum("bhgd,bthd->bhgt", q, k) * dh**-0.5
        mask = (kpos[:, None, None, :] <= qpos[:, :, None, None])
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        y_fp = jnp.einsum("bhgt,bthd->bhgd", p, v)
        assert float(jnp.max(jnp.abs(yq - y_fp))) < 0.05


# ---------------------------------------------------------------------------
# Cache write/read round-trips
# ---------------------------------------------------------------------------


class TestCacheOps:
    def test_init_rejects_bad_bits(self):
        with pytest.raises(ValueError):
            init_kv_cache(1, 8, 2, 16, jnp.float32, kv_bits=4)

    def test_quantized_layout(self):
        c = init_kv_cache(2, 16, 4, 48, jnp.float32, kv_bits=8)
        assert isinstance(c["k"], QuantizedKV) and isinstance(c["v"], QuantizedKV)
        assert c["k"].q.shape == (2, 16, 4, 48)
        assert c["k"].scale.shape == (2, 16, 4, 1)
        assert c["pos"].shape == (2, 16)

    def test_decode_write_roundtrip(self):
        """Writing one token then dequantizing equals quantize(token)."""
        c = init_kv_cache(2, 8, 2, 16, jnp.float32, kv_bits=8)
        k_new = jax.random.normal(jax.random.PRNGKey(0), (2, 1, 2, 16))
        v_new = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 2, 16))
        c2 = _cache_write_decode(c, k_new, v_new, jnp.asarray(3, jnp.int32))
        got = materialize_kv(c2["k"])[:, 3:4]
        want = QuantizedKV.quantize(k_new).dequantize()
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
        # untouched slots stay zero / pos -1
        assert float(jnp.abs(materialize_kv(c2["v"])[:, :3]).max()) == 0.0
        assert int(c2["pos"][0, 3]) == 3 and int(c2["pos"][0, 0]) == -1

    def test_ragged_write_matches_uniform(self):
        c = init_kv_cache(3, 8, 2, 16, jnp.float32, kv_bits=8)
        k_new = jax.random.normal(jax.random.PRNGKey(0), (3, 1, 2, 16))
        v_new = jax.random.normal(jax.random.PRNGKey(1), (3, 1, 2, 16))
        c_u = _cache_write_decode(c, k_new, v_new, jnp.asarray(5, jnp.int32))
        c_r = _cache_write_decode(c, k_new, v_new, jnp.full((3,), 5, jnp.int32))
        for key in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(c_u[key].q), np.asarray(c_r[key].q))
            np.testing.assert_allclose(np.asarray(c_u[key].scale), np.asarray(c_r[key].scale))

    def test_prefill_ring_write(self):
        """capacity < S: last `cap` tokens land at slot pos%cap, quantized."""
        cap, S = 8, 12
        c = init_kv_cache(1, cap, 2, 16, jnp.float32, kv_bits=8)
        k = jax.random.normal(jax.random.PRNGKey(0), (1, S, 2, 16))
        pos = jnp.arange(S, dtype=jnp.int32)[None]
        c2 = _cache_write_prefill(c, k, k, pos)
        got = materialize_kv(c2["k"])
        for p in range(S - cap, S):
            slot = p % cap
            want = QuantizedKV.quantize(k[:, p : p + 1]).dequantize()[0, 0]
            np.testing.assert_allclose(np.asarray(got[0, slot]), np.asarray(want), atol=1e-6)
            assert int(c2["pos"][0, slot]) == p


# ---------------------------------------------------------------------------
# End-to-end decode parity + the byte-reduction claim
# ---------------------------------------------------------------------------


class TestServingParity:
    def test_cache_byte_reduction_3_5x(self):
        """Acceptance: ≥3.5x fewer cache bytes on the demo shape (dh=48)."""
        cfg = _demo_cfg()
        fp = kv_cache_bytes(init_caches(cfg, 8, 128))
        q8 = kv_cache_bytes(init_caches(cfg, 8, 128, kv_bits=8))
        assert fp / q8 >= 3.5, f"only {fp/q8:.2f}x"

    def test_decode_logits_close_and_caches_quantized(self):
        cfg = _demo_cfg(layers=2)
        params = init_params(cfg, jax.random.PRNGKey(0))
        B, S = 2, 10
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
        lg_fp, c_fp = prefill(cfg, params, toks[:, :S], init_caches(cfg, B, S + 4))
        lg_q, c_q = prefill(cfg, params, toks[:, :S], init_caches(cfg, B, S + 4, kv_bits=8))
        # prefill logits identical: prefill attends over in-flight fp K/V
        np.testing.assert_allclose(np.asarray(lg_fp), np.asarray(lg_q), atol=1e-5)
        d_fp, _ = decode_step(cfg, params, toks[:, S:], jnp.asarray(S, jnp.int32), c_fp)
        d_q, _ = decode_step(cfg, params, toks[:, S:], jnp.asarray(S, jnp.int32), c_q)
        # decode reads the quantized history: close, not exact
        assert float(jnp.max(jnp.abs(d_fp - d_q))) < 0.5
        assert isinstance(c_q["seg0"]["pos0"]["self"]["k"], QuantizedKV)

    def test_uniform_ragged_matches_decode_quant(self):
        cfg = _demo_cfg(layers=2)
        params = init_params(cfg, jax.random.PRNGKey(0))
        B, S = 3, 8
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab_size)
        _, caches = prefill(cfg, params, toks[:, :S], init_caches(cfg, B, S + 4, kv_bits=8))
        lg_u, c_u = decode_step(cfg, params, toks[:, S:], jnp.asarray(S, jnp.int32), caches)
        lg_r, c_r = ragged_decode_step(
            cfg, params, toks[:, S:], jnp.full((B,), S, jnp.int32), jnp.ones((B,), bool), caches
        )
        np.testing.assert_allclose(np.asarray(lg_u), np.asarray(lg_r), atol=1e-5)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6),
            c_u, c_r,
        )

    def test_engine_greedy_agreement_trained(self):
        """Acceptance: 100% greedy-token agreement on a trained demo
        checkpoint (the briefly-trained analogue of the example's 80-step
        run; an untrained model's near-uniform logits would make this a
        coin-flip test of fp noise, not of the KV cache)."""
        from repro.data.pipeline import data_stream
        from repro.training.trainer import TrainConfig, train_loop

        cfg = _demo_cfg(layers=2, d_model=96, experts=4)
        it = data_stream(cfg.vocab_size, 8, 32, seed=0)
        params, _, _ = train_loop(
            cfg, TrainConfig(lr=1.5e-3, warmup_steps=5, decay_steps=40), it, 40, log_every=100
        )
        rng = np.random.default_rng(0)
        reqs = [
            Request(prompt=rng.integers(1, cfg.vocab_size, size=16).tolist(), max_new_tokens=8)
            for _ in range(8)
        ]
        ec = EngineConfig(max_batch=8, max_prefill=32, max_decode=8)
        fp_out = Engine(cfg, params, ec).generate(reqs)
        q_out = Engine(
            cfg, params, EngineConfig(max_batch=8, max_prefill=32, max_decode=8, kv_cache_bits=8)
        ).generate(reqs)
        tot = match = 0
        for a, b in zip(fp_out, q_out):
            assert len(a.tokens) == len(b.tokens)
            tot += len(a.tokens)
            match += sum(int(x == y) for x, y in zip(a.tokens, b.tokens))
        assert match == tot, f"greedy agreement {match}/{tot}"

    def test_quant_config_knob(self):
        qcfg = QuantConfig(kv_cache_bits=8)
        assert qcfg.kv_cache_bits == 8
        assert QuantConfig().kv_cache_bits == 0
        assert EngineConfig().kv_cache_bits == 0


# ---------------------------------------------------------------------------
# Continuous batching: slot reuse with a quantized pool
# ---------------------------------------------------------------------------


class TestContinuousSlotReuse:
    def test_long_context_slot_reuse_matches_fp(self):
        """5 requests through 2 slots: every slot is vacated and re-admitted
        with a fresh long prompt (prefill overwrites the previous tenant's
        quantized entries in place); outputs must track the fp-cache pool."""
        cfg = _demo_cfg(layers=2, d_model=96, experts=4)
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, cfg.vocab_size, size=40).tolist() for _ in range(5)]

        def run(kv_bits):
            eng = ContinuousEngine(cfg, params, slots=2, capacity=48, kv_cache_bits=kv_bits)
            for pr in prompts:
                eng.submit(Request(prompt=pr, max_new_tokens=6))
            done = eng.run_until_done()
            return eng, done

        eng_q, q_done = run(8)
        _, fp_done = run(0)
        assert set(q_done) == set(fp_done) == set(range(5))
        # pooled caches stayed quantized through admission + decode + reuse
        leaf = eng_q.caches["seg0"]["pos0"]["self"]["k"]
        assert isinstance(leaf, QuantizedKV)
        tot = match = 0
        for rid in fp_done:
            a, b = fp_done[rid].tokens, q_done[rid].tokens
            assert len(a) == len(b)
            tot += len(a)
            match += sum(int(x == y) for x, y in zip(a, b))
        assert match / tot >= 0.9, f"agreement {match}/{tot}"
