"""Training substrate: AdamW vs numpy reference, schedules, checkpoint
roundtrip, data determinism, staged-KD distillation, convergence."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.registry import all_configs, make_reduced
from repro.data.synthetic import MarkovLM, batches
from repro.models.model import init_params
from repro.training.distill import (
    KDConfig,
    kd_alpha,
    kd_kl,
    make_distill_step,
    make_student_config,
)
from repro.training.optimizer import AdamWConfig, adamw_update, global_norm, init_adamw
from repro.training.schedule import warmup_cosine
from repro.training.trainer import TrainConfig, cross_entropy, train_loop
from repro.data.pipeline import data_stream


class TestAdamW:
    def test_matches_numpy_reference(self):
        cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0, grad_clip=0.0)
        p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
        g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
        st = init_adamw(p)
        p2, st2, _ = adamw_update(cfg, g, st, p, jnp.asarray(1.0))
        # numpy
        m = 0.1 * np.asarray(g["w"])
        v = 0.01 * np.asarray(g["w"]) ** 2
        mhat = m / (1 - 0.9)
        vhat = v / (1 - 0.99)
        want = np.asarray(p["w"]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
        np.testing.assert_allclose(np.asarray(p2["w"]), want, atol=1e-6)

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0)
        p = {"w": jnp.zeros((4,))}
        g = {"w": jnp.full((4,), 100.0)}
        st = init_adamw(p)
        _, st2, stats = adamw_update(cfg, g, st, p, jnp.asarray(1.0))
        assert float(stats["grad_norm"]) == pytest.approx(200.0, rel=1e-4)
        # post-clip effective norm is 1.0 -> m = 0.1 * g_clipped
        np.testing.assert_allclose(np.asarray(st2.m["w"]), 0.1 * 100.0 / 200.0, atol=1e-5)

    def test_weight_decay_only_matrices(self):
        cfg = AdamWConfig(lr=1.0, weight_decay=0.5, grad_clip=0.0)
        p = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
        g = {"mat": jnp.zeros((2, 2)), "vec": jnp.zeros((2,))}
        st = init_adamw(p)
        p2, _, _ = adamw_update(cfg, g, st, p, jnp.asarray(1.0))
        assert float(p2["mat"][0, 0]) == pytest.approx(0.5)
        assert float(p2["vec"][0]) == pytest.approx(1.0)  # no decay on vectors

    def test_global_norm(self):
        t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        assert float(global_norm(t)) == pytest.approx(5.0)


class TestSchedule:
    def test_warmup_then_decay(self):
        s = lambda t: float(warmup_cosine(t, warmup_steps=10, decay_steps=110, min_ratio=0.1))
        assert s(0) == 0.0
        assert s(5) == pytest.approx(0.5)
        assert s(10) == pytest.approx(1.0, abs=1e-3)
        assert s(110) == pytest.approx(0.1, abs=1e-3)
        assert s(60) < s(20)


class TestCrossEntropy:
    def test_uniform_logits(self):
        V = 16
        logits = jnp.zeros((2, 4, V))
        labels = jnp.zeros((2, 4), jnp.int32)
        assert float(cross_entropy(logits, labels)) == pytest.approx(np.log(V), rel=1e-5)

    def test_perfect_prediction(self):
        logits = jnp.full((1, 2, 8), -30.0).at[:, :, 3].set(30.0)
        labels = jnp.full((1, 2), 3, jnp.int32)
        assert float(cross_entropy(logits, labels)) < 1e-5


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = make_reduced(all_configs()["llama3-8b"])
        params = init_params(cfg, jax.random.PRNGKey(0))
        ckpt.save(str(tmp_path / "c"), params, step=42)
        loaded, step = ckpt.load(str(tmp_path / "c"), params)
        assert step == 42
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            params, loaded,
        )

    def test_manifest_exists(self, tmp_path):
        ckpt.save(str(tmp_path / "c"), {"x": jnp.ones((3,))}, step=1)
        assert os.path.exists(tmp_path / "c" / "manifest.json")


class TestData:
    def test_deterministic(self):
        b1 = next(batches(64, 4, 16, seed=3))
        b2 = next(batches(64, 4, 16, seed=3))
        np.testing.assert_array_equal(b1[0], b2[0])

    def test_labels_are_shifted_tokens(self):
        toks, labels = next(batches(64, 2, 16, seed=0))
        np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])

    def test_learnable_structure(self):
        lm = MarkovLM(64, seed=0)
        assert lm.conditional_entropy() < np.log(64)  # well below uniform


class TestDistill:
    def test_kd_alpha_staged(self):
        kdc = KDConfig(alpha=0.7, kd_stop_step=100)
        assert float(kd_alpha(kdc, jnp.asarray(50))) == pytest.approx(0.7)
        assert float(kd_alpha(kdc, jnp.asarray(100))) == 0.0
        kdc_full = KDConfig(alpha=0.7, kd_stop_step=-1)
        assert float(kd_alpha(kdc_full, jnp.asarray(10_000))) == pytest.approx(0.7)

    def test_kl_zero_when_equal(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 16))
        assert float(kd_kl(logits, logits, 1.0)) == pytest.approx(0.0, abs=1e-6)

    def test_kl_positive(self):
        a = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 16))
        b = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16))
        assert float(kd_kl(a, b, 1.0)) > 0.0

    def test_student_depth_reduction(self):
        teacher = all_configs()["nlg-1.3b-prmoe-64-128"]
        student = make_student_config(teacher, 0.875)
        assert student.num_layers == 21  # 24 -> 21, the paper's 12.5%

    def test_distill_step_runs(self):
        tcfg = make_reduced(all_configs()["llama4-maverick-400b-a17b"])
        scfg = make_student_config(tcfg, 0.5)
        tp = init_params(tcfg, jax.random.PRNGKey(0))
        sp = init_params(scfg, jax.random.PRNGKey(1))
        opt = init_adamw(sp)
        step = jax.jit(make_distill_step(scfg, tcfg, TrainConfig(lr=1e-3, warmup_steps=1, decay_steps=10), KDConfig(alpha=1.0, kd_stop_step=5)))
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, scfg.vocab_size)
        sp2, opt2, m = step(sp, opt, tp, toks, toks)
        assert np.isfinite(float(m["loss"]))
        assert float(m["kl"]) > 0.0


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = make_reduced(all_configs()["glm4-9b"]).replace(vocab_size=128)
    it = data_stream(128, 8, 32, seed=0)
    _, _, hist = train_loop(
        cfg, TrainConfig(lr=2e-3, warmup_steps=5, decay_steps=80), it, 80, log_every=79,
        log_fn=lambda *_: None,
    )
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def test_grad_cast_dtype():
    """bf16 backward toggle: cotangents pinned to the primal dtype (the CPU
    dry-run backend hides this via float-normalization, so it is asserted
    here at JAX level — see EXPERIMENTS.md §Perf P1 iter 2)."""
    import jax.numpy as jnp
    from repro.models.modules import grad_cast

    x = jnp.ones((8,), jnp.bfloat16)
    g = jax.grad(lambda x: jnp.sum(grad_cast(x).astype(jnp.float32) ** 2))(x)
    assert g.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(g, np.float32), 2.0)
