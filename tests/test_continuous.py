"""Continuous batching: ragged decode with per-row positions must agree with
independent single-request decoding, and the slot scheduler must serve
staggered traffic correctly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import all_configs, make_reduced
from repro.models.model import (
    decode_step,
    forward,
    init_caches,
    init_params,
    prefill,
    prefill_into_slot,
    ragged_decode_step,
)
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine, EngineConfig, Request


@pytest.fixture(scope="module")
def setup():
    cfg = make_reduced(all_configs()["glm4-9b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestRaggedDecode:
    def test_uniform_ragged_matches_decode(self, setup):
        """Per-row positions with a uniform batch == the uniform decode path."""
        cfg, params = setup
        B, S = 3, 10
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
        caches = init_caches(cfg, B, capacity=S + 4)
        lg, caches = prefill(cfg, params, toks[:, :S], caches)
        lg_u, c_u = decode_step(cfg, params, toks[:, S:], jnp.asarray(S, jnp.int32), caches)
        lg_r, c_r = ragged_decode_step(
            cfg, params, toks[:, S:], jnp.full((B,), S, jnp.int32), jnp.ones((B,), bool), caches
        )
        np.testing.assert_allclose(np.asarray(lg_u), np.asarray(lg_r), atol=1e-5)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5),
            c_u, c_r,
        )

    def test_inactive_rows_untouched(self, setup):
        cfg, params = setup
        B, S = 2, 8
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab_size)
        caches = init_caches(cfg, B, capacity=S + 4)
        _, caches = prefill(cfg, params, toks[:, :S], caches)
        active = jnp.asarray([True, False])
        _, c2 = ragged_decode_step(
            cfg, params, toks[:, S:], jnp.full((B,), S, jnp.int32), active, caches
        )
        for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(c2)):
            np.testing.assert_array_equal(np.asarray(a)[:, 1], np.asarray(b)[:, 1])

    def test_staggered_positions_match_independent(self, setup):
        """Two requests at different positions decoded in one ragged batch
        must equal each decoded alone."""
        cfg, params = setup
        cap = 20
        p0 = [3, 5, 7, 9, 11]
        p1 = [2, 4, 6]
        # independent single-request decoding
        singles = []
        for p in (p0, p1):
            c = init_caches(cfg, 1, cap)
            lg, c = prefill(cfg, params, jnp.asarray([p], jnp.int32), c)
            lg, c = decode_step(cfg, params, jnp.asarray([[1]], jnp.int32),
                                jnp.asarray(len(p), jnp.int32), c)
            singles.append(np.asarray(lg[0]))
        # pooled: admit both via prefill_into_slot, ragged-decode together
        pool = init_caches(cfg, 2, cap)
        for i, p in enumerate((p0, p1)):
            toks = jnp.asarray([p], jnp.int32)
            pos = jnp.arange(len(p), dtype=jnp.int32)[None]
            _, pool = prefill_into_slot(cfg, params, toks, pos, jnp.asarray(i, jnp.int32), pool)
        lg, pool = ragged_decode_step(
            cfg, params, jnp.asarray([[1], [1]], jnp.int32),
            jnp.asarray([len(p0), len(p1)], jnp.int32), jnp.ones((2,), bool), pool,
        )
        np.testing.assert_allclose(np.asarray(lg[0]), singles[0], atol=2e-4)
        np.testing.assert_allclose(np.asarray(lg[1]), singles[1], atol=2e-4)


class TestContinuousEngine:
    def test_matches_static_engine_greedy(self, setup):
        cfg, params = setup
        prompts = [[1, 2, 3, 4], [9, 8, 7], [5, 5, 5, 5, 5]]
        n_new = 5
        static = Engine(cfg, params, EngineConfig(max_batch=1, max_prefill=16, max_decode=n_new))
        want = [static.generate([Request(prompt=p, max_new_tokens=n_new)])[0].tokens for p in prompts]

        eng = ContinuousEngine(cfg, params, slots=2, capacity=32)
        ids = [eng.submit(Request(prompt=p, max_new_tokens=n_new)) for p in prompts]
        done = eng.run_until_done()
        got = [done[i].tokens for i in ids]
        assert got == want, (got, want)

    def test_admission_after_completion(self, setup):
        """More requests than slots: later requests admitted as slots free."""
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, slots=2, capacity=32)
        ids = [eng.submit(Request(prompt=[i + 1, i + 2], max_new_tokens=3)) for i in range(5)]
        done = eng.run_until_done()
        assert sorted(done) == sorted(ids)
        assert all(len(r.tokens) == 3 for r in done.values())
