"""Draft-then-verify speculative decoding (serving/spec.py + the engine's
``_spec_decode_tick``): the token-exact parity oracle tier plus the
rollback-invariant engine fuzz.

Greedy speculation must be EXACT — the drafter moves the accept rate, never
the output — so every test here decodes the same requests through a
non-speculative ``ContinuousEngine`` and a speculative one and asserts the
token streams are identical:

  * self-draft oracle (drafter == target: every window fully accepts) and a
    fresh-init drafter (near-zero accept: every window rolls back) across
    glm4 (fully paged), gemma3 (window-ring mix) and recurrentgemma (LRU
    state — the commit pass + drafter-resync path);
  * the serving feature cross-product speculation must compose with:
    int8 KV pages, prefix sharing (shared pages fork at the window boundary,
    commit by refcount handoff), chunked AND batched admission prefill;
  * window geometry: k=1 degenerate, k spanning a page boundary, k clamped
    by the remaining budget (including budget 1 => k=0 verify-only windows),
    eos landing mid-window (the accepted suffix past eos must be truncated);
  * scheduling: a starved pool forcing mid-speculation preemptions, and
    fork admissions (submit_n) whose shared tail page CoW-forks on spec
    windows.

The fuzz tier drives a low-accept drafter + tiny oversubscribed pool for
hundreds of random-shaped requests and asserts the pool invariants the
rollback machinery must preserve: ``pool.check()`` green after drain, zero
leaked fork pages (free_count == n_pages), prefix index fully evicted —
with output parity on top, so "no leak" can't be bought with wrong tokens.
"""
import jax
import numpy as np
import pytest

from repro.configs.registry import all_configs, make_reduced
from repro.models.model import init_params
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request
from repro.serving.spec import accept_length

from tests._hyp import given, settings, st

PRE = [7, 7, 3, 5, 1, 2, 9, 4]  # 2 full pages at page_size=4 — shared preamble

ARCHS = ["glm4-9b", "gemma3-27b", "recurrentgemma-2b"]

_SETUP = {}


def _setup(arch):
    """Reduced config + target params (seed 0) + drafter params (seed 1),
    cached per arch — params are shared read-only across engines."""
    if arch not in _SETUP:
        cfg = make_reduced(all_configs()[arch])
        _SETUP[arch] = (cfg, init_params(cfg, jax.random.PRNGKey(0)),
                        init_params(cfg, jax.random.PRNGKey(1)))
    return _SETUP[arch]


def _prompts(n=3, lo=3, hi=12, seed=0, vocab=64):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def _serve(cfg, params, prompts, n_new, *, n_samples=1, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("capacity", 64)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 4)
    eng = ContinuousEngine(cfg, params, **kw)
    if n_samples > 1:
        ids = [rid for p in prompts
               for rid in eng.submit_n(Request(prompt=p, max_new_tokens=n_new),
                                       n_samples)]
    else:
        ids = [eng.submit(Request(prompt=p, max_new_tokens=n_new))
               for p in prompts]
    done = eng.run_until_done()
    return [done[i].tokens for i in ids], eng


def _assert_parity_and_drained(cfg, params, prompts, n_new, *, spec_kw,
                               base_kw=None, n_samples=1):
    """The oracle: speculative output == non-speculative output, and the
    speculative engine's pool/prefix fully drained (no leaked fork pages)."""
    base_kw = dict(base_kw or {})
    base, _ = _serve(cfg, params, prompts, n_new, n_samples=n_samples,
                     **base_kw)
    spec, eng = _serve(cfg, params, prompts, n_new, n_samples=n_samples,
                       **base_kw, **spec_kw)
    assert base == spec, (
        f"speculative greedy decode diverged from the non-speculative "
        f"engine\nbase={base}\nspec={spec}")
    eng.pool.check()
    assert eng.pool.free_count == eng.n_pages, "leaked fork pages"
    if eng.prefix is not None:
        assert len(eng.prefix) == 0, "prefix index not fully evicted"
    return eng


# ---------------------------------------------------------------------------
# accept_length: the pure accept rule
# ---------------------------------------------------------------------------


def test_accept_length_rule():
    g = [5, 6, 7, 8, 9]
    assert accept_length([], g) == 0
    assert accept_length([5, 6, 7], g) == 3  # full accept
    assert accept_length([5, 6, 0], g) == 2
    assert accept_length([0, 6, 7], g) == 0  # first token already wrong
    assert accept_length([5, 0, 7], g) == 1  # post-mismatch agreement ignored


# ---------------------------------------------------------------------------
# Parity: drafter quality moves the accept rate, never the tokens
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_parity_self_oracle(arch):
    """drafter == target: every draft token must be accepted (the verify
    argmax IS the drafter argmax), every window emits k+1 tokens."""
    cfg, params, _ = _setup(arch)
    eng = _assert_parity_and_drained(
        cfg, params, _prompts(), 10,
        spec_kw=dict(spec_draft=(cfg, params), spec_k=3))
    sp = [m["spec"] for m in eng.metrics_log if "spec" in m]
    drafted = sum(s["drafted"] for s in sp)
    assert drafted > 0
    assert sum(s["accepted"] for s in sp) == drafted, \
        "self-draft oracle must fully accept every window"


@pytest.mark.parametrize("arch", ARCHS)
def test_parity_low_accept_drafter(arch):
    """Fresh-init drafter: near-zero accept, every window rolls back pages —
    output must still be token-exact.  recurrentgemma additionally runs the
    partial-accept drafter-resync path (irreversible recurrent state)."""
    cfg, params, dparams = _setup(arch)
    eng = _assert_parity_and_drained(
        cfg, params, _prompts(), 10,
        spec_kw=dict(spec_draft=(cfg, dparams), spec_k=3))
    sp = [m["spec"] for m in eng.metrics_log if "spec" in m]
    assert sum(s["accepted"] for s in sp) < sum(s["drafted"] for s in sp), \
        "a fresh-init drafter should not fully accept (rollback untested)"
    if arch == "recurrentgemma-2b":
        assert sum(s.get("resyncs", 0) for s in sp) > 0, \
            "recurrent drafter partial accepts must resync"


@pytest.mark.parametrize("arch", ["glm4-9b", "gemma3-27b"])
@pytest.mark.parametrize("kv_bits", [0, 8])
def test_parity_int8_kv(arch, kv_bits):
    cfg, params, dparams = _setup(arch)
    _assert_parity_and_drained(
        cfg, params, _prompts(), 8,
        base_kw=dict(kv_cache_bits=kv_bits),
        spec_kw=dict(spec_draft=(cfg, dparams), spec_k=3))


@pytest.mark.parametrize("prefill_mode", ["chunked", "batched"])
def test_parity_prefill_modes(prefill_mode):
    cfg, params, dparams = _setup("glm4-9b")
    _assert_parity_and_drained(
        cfg, params, _prompts(n=4), 9,
        base_kw=dict(prefill_mode=prefill_mode, slots=2),  # forces queueing
        spec_kw=dict(spec_draft=(cfg, dparams), spec_k=3))


@pytest.mark.parametrize("arch", ["glm4-9b", "gemma3-27b"])
def test_parity_prefix_sharing(arch):
    """Prompts repeating a full-page preamble share pages; fork admissions
    (submit_n) share ALL prompt pages.  Speculation must CoW-fork the shared
    boundary page per window and commit by refcount handoff."""
    cfg, params, dparams = _setup(arch)
    prompts = [PRE + [11, 12], PRE + [11, 12], PRE + [13]]
    _assert_parity_and_drained(
        cfg, params, prompts, 9,
        base_kw=dict(prefix_sharing=True),
        spec_kw=dict(spec_draft=(cfg, dparams), spec_k=3))


def test_parity_fork_admissions():
    cfg, params, dparams = _setup("glm4-9b")
    eng = _assert_parity_and_drained(
        cfg, params, [PRE + [11, 12]], 9, n_samples=3,
        base_kw=dict(prefix_sharing=True),
        spec_kw=dict(spec_draft=(cfg, dparams), spec_k=3))
    assert eng.cow_copies > 0, \
        "3 samples decoding off one set of prompt pages must fork"


def test_parity_k1_degenerate():
    cfg, params, dparams = _setup("glm4-9b")
    eng = _assert_parity_and_drained(
        cfg, params, _prompts(), 8,
        spec_kw=dict(spec_draft=(cfg, dparams), spec_k=1))
    sp = [m["spec"] for m in eng.metrics_log if "spec" in m]
    assert all(s["drafted"] <= s["windows"] for s in sp)


def test_parity_k_spans_page_boundary():
    """k+1 = 7 window positions over page_size-4 pages: every window covers
    two or three table entries, so commits and rollbacks constantly split
    across the boundary."""
    cfg, params, dparams = _setup("glm4-9b")
    _assert_parity_and_drained(
        cfg, params, _prompts(), 14,
        spec_kw=dict(spec_draft=(cfg, dparams), spec_k=6))


def test_parity_budget_clamp():
    """max_new below k: the window clamps to the remaining budget (k=0 pure
    verify on the final token), and never emits past the budget."""
    cfg, params, dparams = _setup("glm4-9b")
    for n_new in (1, 2, 3):
        base, _ = _serve(cfg, params, _prompts(), n_new)
        spec, _ = _serve(cfg, params, _prompts(), n_new,
                         spec_draft=(cfg, dparams), spec_k=4)
        assert base == spec
        assert all(len(t) == n_new for t in spec)


def test_parity_eos_mid_window():
    """Pick a token the run actually emits as eos: the speculative engine
    must truncate the accepted suffix at eos exactly where the
    one-token-at-a-time engine stops."""
    cfg, params, _ = _setup("glm4-9b")
    probe, _ = _serve(cfg, params, _prompts(), 10)
    eos = probe[0][4]  # 5th emitted token => eos lands mid-window at k=3
    _assert_parity_and_drained(
        cfg, params, _prompts(), 10,
        base_kw=dict(eos_id=eos),
        spec_kw=dict(spec_draft=(cfg, params), spec_k=3))


def test_parity_cross_family_drafter():
    """A drafter of a different ARCHITECTURE (gemma3 window-ring drafting
    for the fully-paged glm4 target) — exercises the drafter abstraction
    end-to-end; reduced configs share the token space."""
    tcfg, tparams, _ = _setup("glm4-9b")
    dcfg, dparams, _ = _setup("gemma3-27b")
    if dcfg.vocab_size != tcfg.vocab_size:
        pytest.skip("reduced vocabs diverged; cross-family needs one space")
    _assert_parity_and_drained(
        tcfg, tparams, _prompts(), 9,
        spec_kw=dict(spec_draft=(dcfg, dparams), spec_k=3))


def test_parity_under_preemption():
    """A pool provisioned well below the worst case forces preemptions mid
    run (some mid-speculation: window allocation preempts the youngest
    slot); re-admitted requests must resume token-exact and the drafter's
    watermark must survive the slot churn."""
    cfg, params, dparams = _setup("glm4-9b")
    kw = dict(slots=3, capacity=32, page_size=4, n_pages=14)
    base, b_eng = _serve(cfg, params, _prompts(n=5, seed=3), 12, **kw)
    spec, eng = _serve(cfg, params, _prompts(n=5, seed=3), 12,
                       spec_draft=(cfg, dparams), spec_k=3, **kw)
    assert base == spec
    assert eng.preemptions > 0, "pool was meant to starve (tune n_pages)"
    eng.pool.check()
    assert eng.pool.free_count == eng.n_pages


# ---------------------------------------------------------------------------
# Rollback-invariant engine fuzz: random shapes, starved pool, bad drafter
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_fuzz_rollback_invariants(seed):
    """Random request shapes through a low-accept drafter over a tiny
    oversubscribed pool: hundreds of verify windows, nearly all rolling
    back, interleaved with forced preemptions — after drain the pool must
    be byte-for-byte clean (check() green, zero leaked forks, prefix index
    empty) and the stream token-exact vs the non-speculative engine."""
    cfg, params, dparams = _setup("glm4-9b")
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(1, 14))).tolist()
               for _ in range(6)]
    n_new = int(rng.integers(4, 16))
    k = int(rng.integers(1, 5))
    kw = dict(slots=3, capacity=32, page_size=4,
              n_pages=int(rng.integers(12, 20)),
              prefix_sharing=bool(rng.integers(0, 2)))
    base, _ = _serve(cfg, params, prompts, n_new, **kw)
    spec, eng = _serve(cfg, params, prompts, n_new,
                       spec_draft=(cfg, dparams), spec_k=k, **kw)
    assert base == spec
    sp = [m["spec"] for m in eng.metrics_log if "spec" in m]
    assert sum(s["windows"] for s in sp) >= 10
    eng.pool.check()
    assert eng.pool.free_count == eng.n_pages, "leaked fork pages"
    if eng.prefix is not None:
        assert len(eng.prefix) == 0


# ---------------------------------------------------------------------------
# Config validation: the unsupported corners must refuse loudly
# ---------------------------------------------------------------------------


def test_spec_requires_paged():
    cfg, params, _ = _setup("glm4-9b")
    with pytest.raises(ValueError, match="paged"):
        ContinuousEngine(cfg, params, paged=False,
                         spec_draft=(cfg, params))


def test_spec_requires_greedy():
    cfg, params, _ = _setup("glm4-9b")
    with pytest.raises(ValueError, match="greedy"):
        ContinuousEngine(cfg, params, paged=True, page_size=4,
                         temperature=0.7, spec_draft=(cfg, params))


def test_spec_requires_matching_vocab():
    cfg, params, _ = _setup("glm4-9b")
    import dataclasses
    bad = dataclasses.replace(cfg, vocab_size=cfg.vocab_size + 1)
    with pytest.raises(ValueError, match="vocab"):
        ContinuousEngine(cfg, params, paged=True, page_size=4,
                         spec_draft=(bad, params))


def test_spec_k_must_be_positive():
    cfg, params, _ = _setup("glm4-9b")
    with pytest.raises(ValueError, match="spec_k"):
        ContinuousEngine(cfg, params, paged=True, page_size=4,
                         spec_draft=(cfg, params), spec_k=0)
