"""Paged KV-cache block pool + scheduler: allocator invariants, the Pallas
page-gather kernel vs its einsum ref, model-level paged-vs-contiguous
parity, scheduler behavior (fragmentation, preemption round-trip, page
reuse, free-block admission), and the scheduler-bug regressions fixed in
the same PR (prompt-truncation clamp, per-chunk PRNG folding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import all_configs, make_reduced
from repro.models.model import (
    init_caches,
    init_paged_caches,
    init_params,
    paged_prefill_into_slot,
    paged_ragged_decode_step,
    prefill_into_slot,
    ragged_decode_step,
)
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine, EngineConfig, Request
from repro.serving.kv_pool import BlockTables, KVBlockPool


@pytest.fixture(scope="module")
def setup():
    cfg = make_reduced(all_configs()["glm4-9b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# Host-side allocator
# ---------------------------------------------------------------------------


class TestBlockPool:
    def test_alloc_free_reuse(self):
        pool = KVBlockPool(4, 8)
        a = pool.alloc(3, owner=0)
        assert len(a) == 3 and pool.free_count == 1
        pool.free(a[:2])
        assert pool.free_count == 3
        b = pool.alloc(3, owner=1)
        assert len(b) == 3 and pool.free_count == 0
        # freed pages were recycled, not duplicated
        assert len(set(b) | set(a[2:])) == 4

    def test_alloc_all_or_nothing(self):
        pool = KVBlockPool(4, 8)
        assert pool.alloc(5, owner=0) is None
        assert pool.free_count == 4  # nothing was handed out
        assert pool.alloc(4, owner=0) is not None

    def test_double_free_raises(self):
        pool = KVBlockPool(4, 8)
        a = pool.alloc(2, owner=0)
        pool.free(a)
        with pytest.raises(ValueError, match="double free"):
            pool.free(a[:1])

    def test_release_owner_is_preemption_safe(self):
        pool = KVBlockPool(8, 4)
        pool.alloc(3, owner=0)
        pool.alloc(2, owner=1)
        assert len(pool.release(0)) == 3
        assert pool.free_count == 6
        assert pool.release(0) == []  # stale release frees nothing
        assert pool.release(7) == []  # unknown owner is a no-op

    def test_accounting(self):
        pool = KVBlockPool(10, 16)
        assert pool.pages_for(0) == 0
        assert pool.pages_for(1) == 1
        assert pool.pages_for(16) == 1
        assert pool.pages_for(17) == 2
        pool.alloc(5, owner=2)
        assert pool.used_count == 5 and pool.occupancy == 0.5
        assert sorted(pool.owned_by(2)) == sorted(pool.owned_by(2))


class TestBlockTables:
    def test_append_reset(self):
        bt = BlockTables(2, 4)
        bt.append(0, [7, 3])
        assert bt.n_mapped(0) == 2 and bt.n_mapped(1) == 0
        bt.append(0, [1])
        assert list(bt.row(0)[:3]) == [7, 3, 1]
        bt.reset(0)
        assert bt.n_mapped(0) == 0

    def test_overflow_raises(self):
        bt = BlockTables(1, 2)
        bt.append(0, [0, 1])
        with pytest.raises(ValueError, match="overflow"):
            bt.append(0, [2])


# ---------------------------------------------------------------------------
# Pallas page-gather kernel vs einsum ref
# ---------------------------------------------------------------------------


def _toy_pool(quantized):
    key = jax.random.PRNGKey(0)
    B, Hkv, G, dh, ps, nt, Pt = 3, 2, 2, 8, 4, 5, 12  # Pt-1 = trash page
    q = jax.random.normal(key, (B, Hkv, G, dh), jnp.float32)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (Pt, ps, Hkv, dh), jnp.float32)
    vf = jax.random.normal(jax.random.fold_in(key, 2), (Pt, ps, Hkv, dh), jnp.float32)
    kpos = np.full((Pt, ps), -1, np.int32)
    tables = np.full((B, nt), -1, np.int32)
    seqs = {0: ([3, 7, 0], 10), 1: ([5, 9], 6), 2: ([1], 2)}
    for b, (pages, n) in seqs.items():
        tables[b, : len(pages)] = pages
        for t in range(n):
            kpos[pages[t // ps], t % ps] = t
    tbl = jnp.asarray(np.where(tables < 0, Pt - 1, tables), jnp.int32)
    qpos = jnp.asarray([[seqs[b][1] - 1] for b in range(B)], jnp.int32)
    if quantized:
        from repro.quant.kv import kv_quantize_values

        kq, ks = kv_quantize_values(kf)
        vq, vs = kv_quantize_values(vf)
    else:
        kq, ks, vq, vs = kf, None, vf, None
    return q, kq, ks, vq, vs, jnp.asarray(kpos), tbl, qpos


class TestPagedKernel:
    @pytest.mark.parametrize("quantized", [False, True])
    def test_kernel_matches_ref(self, quantized):
        from repro.kernels.attention_paged import (
            paged_decode_attention,
            paged_decode_attention_ref,
        )

        args = _toy_pool(quantized)
        out_k = paged_decode_attention(*args, scale=0.35, interpret=True)
        out_r = paged_decode_attention_ref(*args, scale=0.35)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)

    def test_kernel_window_softcap(self):
        from repro.kernels.attention_paged import (
            paged_decode_attention,
            paged_decode_attention_ref,
        )

        args = _toy_pool(False)
        kw = dict(scale=0.35, causal=True, window=3, softcap=5.0)
        out_k = paged_decode_attention(*args, interpret=True, **kw)
        out_r = paged_decode_attention_ref(*args, **kw)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)

    def test_unmapped_entries_contribute_nothing(self):
        """Shrinking a row's mapped pages must equal zero-padding: -1 table
        entries (clamped to the trash page) are fully masked."""
        from repro.kernels.attention_paged import paged_decode_attention_ref

        q, kq, ks, vq, vs, kpos, tbl, qpos = _toy_pool(False)
        out = paged_decode_attention_ref(q, kq, ks, vq, vs, kpos, tbl, qpos, scale=0.35)
        # row 2 uses 1 page; widen its view to 5 (all trash beyond page 0)
        assert np.isfinite(np.asarray(out)).all()
        out2 = paged_decode_attention_ref(
            q, kq, ks, vq, vs, kpos, tbl.at[2, 1:].set(kq.shape[0] - 1), qpos, scale=0.35
        )
        np.testing.assert_allclose(np.asarray(out[2]), np.asarray(out2[2]), atol=1e-6)


# ---------------------------------------------------------------------------
# Model-level parity: paged vs contiguous caches
# ---------------------------------------------------------------------------


class TestPagedModelParity:
    @pytest.mark.parametrize("kv_bits", [0, 8])
    def test_staggered_decode_matches_contiguous(self, setup, kv_bits):
        """Two requests at different positions, admitted via page-scatter
        prefill and ragged-decoded through block tables, must produce the
        same logits as the contiguous slot-pool path."""
        cfg, params = setup
        cap, ps = 20, 4
        p0, p1 = [3, 5, 7, 9, 11], [2, 4, 6]

        contig = init_caches(cfg, 2, cap, kv_bits=kv_bits)
        paged = init_paged_caches(cfg, 2, cap, n_pages=10, page_size=ps, kv_bits=kv_bits)
        pool = KVBlockPool(10, ps)
        tables = BlockTables(2, -(-cap // ps))
        for i, p in enumerate((p0, p1)):
            toks = jnp.asarray([p], jnp.int32)
            pos = jnp.arange(len(p), dtype=jnp.int32)[None]
            slot = jnp.asarray(i, jnp.int32)
            _, contig = prefill_into_slot(cfg, params, toks, pos, slot, contig)
            tables.append(i, pool.alloc(pool.pages_for(len(p)), owner=i))
            _, paged = paged_prefill_into_slot(
                cfg, params, toks, pos, slot, paged, jnp.asarray(tables.row(i)),
                capacity=cap, kv_bits=kv_bits,
            )
        toks = jnp.asarray([[1], [1]], jnp.int32)
        positions = jnp.asarray([len(p0), len(p1)], jnp.int32)
        active = jnp.ones((2,), bool)
        # grow tables for the decode write position
        for i, p in enumerate((p0, p1)):
            if tables.n_mapped(i) <= len(p) // ps:
                tables.append(i, pool.alloc(1, owner=i))
        lg_c, _ = ragged_decode_step(cfg, params, toks, positions, active, contig)
        lg_p, _ = paged_ragged_decode_step(
            cfg, params, toks, positions, active, paged, jnp.asarray(tables.table)
        )
        np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_p), atol=2e-4)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


def _serve(cfg, params, prompts, n_new, **kw):
    eng = ContinuousEngine(cfg, params, **kw)
    ids = [eng.submit(Request(prompt=p, max_new_tokens=n_new)) for p in prompts]
    done = eng.run_until_done()
    return [done[i].tokens for i in ids], eng


class TestPagedEngine:
    @pytest.mark.parametrize("prefix", [False, True])
    @pytest.mark.parametrize("kv_bits", [0, 8])
    def test_matches_contiguous_greedy(self, setup, kv_bits, prefix):
        """Acceptance: identical greedy tokens, paged vs contiguous, fp and
        int8 KV — and unchanged when prefix sharing rides along (prompts 1/3
        share a full page, exercising the CoW path against the contiguous
        oracle too)."""
        cfg, params = setup
        # 3 slots: prompts 1 and 3 are in flight together, so the shared
        # [1,2,3,4] page is still live (and indexed) at prompt 3's admission
        prompts = [[1, 2, 3, 4], [9, 8, 7], [1, 2, 3, 4, 5]]
        want, _ = _serve(cfg, params, prompts, 5, slots=3, capacity=32,
                         kv_cache_bits=kv_bits)
        got, eng = _serve(cfg, params, prompts, 5, slots=3, capacity=32,
                          kv_cache_bits=kv_bits, paged=True, page_size=4,
                          n_pages=24, prefix_sharing=prefix)
        assert got == want, (got, want)
        assert eng.pool.free_count == eng.n_pages  # everything returned
        if prefix:
            assert eng.prefix_hits >= 1  # [1,2,3,4] page re-used by prompt 3

    @pytest.mark.parametrize("prefix", [False, True])
    def test_window_arch_mixes_rings_and_pages(self, prefix):
        """Sliding-window layers keep per-slot rings while global layers
        page — parity must hold on a local+global arch (gemma3), with and
        without prefix sharing of the global-layer pages."""
        cfg = make_reduced(all_configs()["gemma3-27b"])  # window 8 reduced
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompts = [[1, 2, 3, 4, 5, 6], [9, 8, 7], [1, 2, 3, 4, 9]]
        want, _ = _serve(cfg, params, prompts, 6, slots=3, capacity=24)
        got, eng = _serve(cfg, params, prompts, 6, slots=3, capacity=24,
                          paged=True, page_size=4, n_pages=18,
                          prefix_sharing=prefix)
        assert got == want, (got, want)
        if prefix:
            assert eng.prefix_hits >= 1

    @pytest.mark.parametrize("prefill_mode", ["chunked", "scatter"])
    def test_fragmentation_many_short_one_long(self, setup, prefill_mode):
        """The paged pool serves many short requests plus one long one from
        HALF the contiguous reservation (slots*capacity would need 64 pages'
        worth; the pool holds 20) — the fragmentation win, token-exact.
        Parametrized over the admission path so the retained scatter oracle
        keeps scheduler coverage too."""
        cfg, params = setup
        prompts = [[7, 7, 7] for _ in range(6)] + [[1, 2, 3, 4, 5, 6, 7, 8]]
        n_new = [3] * 6 + [20]
        want_eng = ContinuousEngine(cfg, params, slots=4, capacity=32)
        got_eng = ContinuousEngine(cfg, params, slots=4, capacity=32,
                                   paged=True, page_size=2, n_pages=20,
                                   prefill_mode=prefill_mode)
        outs = []
        for eng in (want_eng, got_eng):
            ids = [eng.submit(Request(prompt=p, max_new_tokens=n))
                   for p, n in zip(prompts, n_new)]
            done = eng.run_until_done()
            outs.append([done[i].tokens for i in ids])
        assert outs[0] == outs[1]
        assert got_eng.pool.free_count == 20

    @pytest.mark.parametrize("prefill_mode", ["chunked", "scatter"])
    def test_preemption_round_trip(self, setup, prefill_mode):
        """A pool too small for all admitted sequences preempts the youngest
        slot back to the queue; resumed decoding is token-exact.  Runs under
        both admission paths — preemption + re-admission is exactly where
        the scatter oracle's temp-prefill machinery could rot unseen."""
        cfg, params = setup
        prompts = [[i + 1] * 6 for i in range(3)]
        want, _ = _serve(cfg, params, prompts, 8, slots=3, capacity=32,
                         paged=True, page_size=4, n_pages=64,
                         prefill_mode=prefill_mode)
        got, eng = _serve(cfg, params, prompts, 8, slots=3, capacity=32,
                          paged=True, page_size=4, n_pages=8,
                          prefill_mode=prefill_mode)
        assert eng.preemptions >= 1
        assert got == want, (got, want)

    def test_page_pressure_batched_readmission(self, setup):
        """Regression: a completion that unblocks a queued request mid-tick
        must not feed the freshly admitted slot a token sampled from its
        pre-admission (inactive-row) logits.  4 long prompts through 2 slots
        with a pool that forces preemption and staggered re-admission must
        match each request served alone."""
        cfg, params = setup
        prompts = [[10 + i] * 40 for i in range(4)]
        solo = []
        for p in prompts:
            got, _ = _serve(cfg, params, [p], 8, slots=1, capacity=64,
                            paged=True, page_size=4, n_pages=16)
            solo.append(got[0])
        got, eng = _serve(cfg, params, prompts, 8, slots=2, capacity=64,
                          paged=True, page_size=4, n_pages=20)
        assert eng.preemptions >= 1
        assert got == solo, (got, solo)

    def test_double_preemption_resumes_exactly(self, setup):
        """Regression: preempting the SAME request twice must not duplicate
        its generated prefix in the rebuilt context (prompt and generated are
        re-queued separately, not as a fused context)."""
        cfg, params = setup
        p = [1, 2, 3, 4, 5, 6]
        want, _ = _serve(cfg, params, [p], 10, slots=1, capacity=32,
                         paged=True, page_size=4)
        eng = ContinuousEngine(cfg, params, slots=1, capacity=32,
                               paged=True, page_size=4)
        rid = eng.submit(Request(prompt=p, max_new_tokens=10))
        eng.step(); eng.step()
        eng._preempt(0)          # kick it back to the queue mid-flight
        eng.step(); eng.step()   # re-admitted, decodes a little more
        eng._preempt(0)          # and again
        done = eng.run_until_done()
        assert eng.preemptions == 2
        assert done[rid].tokens == want[0], (done[rid].tokens, want[0])

    def test_admission_by_free_block_count(self, setup):
        """A free slot alone is not enough: the second request waits in the
        queue until the first request's pages come back."""
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, slots=2, capacity=16,
                               paged=True, page_size=4, n_pages=4)
        eng.submit(Request(prompt=list(range(1, 13)), max_new_tokens=4))
        eng.submit(Request(prompt=list(range(20, 32)), max_new_tokens=4))
        # both slots are free, but a 12-token prompt takes 3 of 4 pool pages,
        # so the second request cannot be admitted yet
        assert sum(s.active for s in eng.slots) == 1
        assert len(eng.queue) == 1
        done = eng.run_until_done()
        assert len(done) == 2 and all(len(r.tokens) == 4 for r in done.values())

    def test_page_reuse_is_clean(self, setup):
        """Regression: recycled pages must not leak the previous occupant's
        K/V (stale pos entries).  Back-to-back traffic through one engine
        must match a fresh engine per request."""
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, slots=1, capacity=32,
                               paged=True, page_size=4, n_pages=8)
        outs = []
        for p in ([1, 2, 3, 4, 5, 6, 7], [9, 9, 8, 8, 7]):
            rid = eng.submit(Request(prompt=p, max_new_tokens=6))
            outs.append(eng.run_until_done()[rid].tokens)
        for p, got in zip(([1, 2, 3, 4, 5, 6, 7], [9, 9, 8, 8, 7]), outs):
            want, _ = _serve(cfg, params, [p], 6, slots=1, capacity=32,
                             paged=True, page_size=4, n_pages=8)
            assert got == want[0], (p, got, want[0])

    def test_step_metrics_surface(self, setup):
        cfg, params = setup
        _, eng = _serve(cfg, params, [[1, 2, 3]], 3, slots=2, capacity=16,
                        paged=True, page_size=4)
        assert eng.metrics_log, "step() should record per-tick metrics"
        m = eng.last_metrics
        for key in ("tick", "active_slots", "queue_depth", "tok_per_s",
                    "free_pages", "page_occupancy", "preemptions"):
            assert key in m, key
        assert m["free_pages"] == eng.n_pages


class TestSchedulerRegressions:
    def test_admit_truncation_clamps_budget(self, setup):
        """Regression: max_new_tokens >= capacity used to flip the truncation
        index positive and keep the WRONG end of the prompt.  The clamped
        request must behave exactly like its explicit equivalent (last
        context token, capacity-1 budget)."""
        cfg, params = setup
        prompt = list(range(100, 112))  # 12 tokens, capacity 8
        got, _ = _serve(cfg, params, [prompt], 20, slots=1, capacity=8)
        assert len(got[0]) == 7  # budget clamped to capacity - 1
        want, _ = _serve(cfg, params, [prompt[-1:]], 7, slots=1, capacity=8)
        assert got[0] == want[0], (got[0], want[0])

    def test_admit_truncation_keeps_prompt_tail(self, setup):
        """When only part of the prompt fits, the kept part is the LAST
        (newest) tokens."""
        cfg, params = setup
        prompt = [11, 12, 13, 14, 15, 16]
        got, _ = _serve(cfg, params, [prompt], 4, slots=1, capacity=8)
        want, _ = _serve(cfg, params, [prompt[-4:]], 4, slots=1, capacity=8)
        assert got[0] == want[0]

    def test_engine_chunks_do_not_replay_sampling_noise(self, setup):
        """Regression: Engine.generate reused the identical PRNG key for
        every max_batch chunk, so chunk 2+ replayed chunk 1's noise."""
        cfg, params = setup
        ec = EngineConfig(max_batch=1, max_prefill=16, max_decode=12,
                          temperature=1.0)
        eng = Engine(cfg, params, ec)
        reqs = [Request(prompt=[1, 2, 3], max_new_tokens=12) for _ in range(2)]
        out = eng.generate(reqs, seed=0)
        assert out[0].tokens != out[1].tokens
        # chunk 0 must still follow the unfolded key: identical to a
        # single-request call (back-compat with pre-fix sampling streams)
        solo = eng.generate(reqs[:1], seed=0)
        assert out[0].tokens == solo[0].tokens
