"""Attention unit tests: GQA == expanded MHA, RoPE properties, sliding
window masks, chunked == full attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a single-draw fallback shim

from repro.configs.base import AttnSpec, ModelConfig
from repro.models.attention import (
    _sdpa,
    _window_causal_mask,
    attend_chunked,
    attend_full,
    attention,
    init_attention,
)
from repro.models.modules import apply_rope


def _cfg(H=4, Hkv=2, dh=16, d=64):
    return ModelConfig(
        name="t", family="dense", source="x", d_model=d, num_heads=H, num_kv_heads=Hkv,
        head_dim=dh, vocab_size=64, segments=(), param_dtype="float32", compute_dtype="float32",
    )


def _qkv(B=2, S=16, H=4, Hkv=2, dh=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, dh))
    return q, k, v


class TestSDPA:
    def test_gqa_equals_expanded_mha(self):
        q, k, v = _qkv()
        B, S = q.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        mask = _window_causal_mask(pos, pos, 0, True)
        out_gqa = _sdpa(q, k, v, mask, 0.25, 0.0)
        # expand kv to full heads and compute with Hkv == H
        k2 = jnp.repeat(k, 2, axis=2)
        v2 = jnp.repeat(v, 2, axis=2)
        out_mha = _sdpa(q, k2, v2, mask, 0.25, 0.0)
        np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha), atol=1e-5)

    def test_causality(self):
        """Changing future K/V must not change current output."""
        q, k, v = _qkv(S=8)
        pos = jnp.arange(8)[None]
        spec = AttnSpec(kind="global")
        y1 = attend_full(q, k, v, pos, pos, spec, 0.25)
        k2 = k.at[:, 5:].set(99.0)
        v2 = v.at[:, 5:].set(-99.0)
        y2 = attend_full(q, k2, v2, pos, pos, spec, 0.25)
        np.testing.assert_allclose(np.asarray(y1[:, :5]), np.asarray(y2[:, :5]), atol=1e-6)

    def test_window_mask(self):
        q_pos = jnp.arange(8)[None]
        m = _window_causal_mask(q_pos, q_pos, 3, True)[0, 0, 0]
        m = np.asarray(m)
        for i in range(8):
            for j in range(8):
                expect = (j <= i) and (i - j < 3)
                assert m[i, j] == expect, (i, j)

    def test_softcap_bounds_logits(self):
        q, k, v = _qkv(S=4)
        pos = jnp.arange(4)[None]
        spec = AttnSpec(kind="global", logit_softcap=5.0)
        y = attend_full(q * 100, k * 100, v, pos, pos, spec, 0.25)
        assert np.isfinite(np.asarray(y)).all()


class TestChunked:
    @pytest.mark.parametrize("kind,window", [("global", 0), ("local", 512), ("local", 100)])
    def test_chunked_equals_full(self, kind, window):
        B, S, H, Hkv, dh = 1, 2048, 2, 1, 8
        q, k, v = _qkv(B, S, H, Hkv, dh, seed=3)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        spec = AttnSpec(kind=kind, window=window)
        y_full = attend_full(q, k, v, pos, pos, spec, 0.3)
        y_chunk = attend_chunked(q, k, v, pos, pos, spec, 0.3, q_chunk=512)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_full), atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(window=st.sampled_from([64, 200, 513]), seed=st.integers(0, 20))
    def test_property_local_window_chunks(self, window, seed):
        B, S = 1, 1024
        q, k, v = _qkv(B, S, 2, 1, 8, seed=seed)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        spec = AttnSpec(kind="local", window=window)
        y_full = attend_full(q, k, v, pos, pos, spec, 0.3)
        y_chunk = attend_chunked(q, k, v, pos, pos, spec, 0.3, q_chunk=256)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_full), atol=1e-4)


class TestRoPE:
    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        dh = 32
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, dh))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, dh))
        def score(m, n):
            qm = apply_rope(q, jnp.asarray([[m]]), 10000.0)
            kn = apply_rope(k, jnp.asarray([[n]]), 10000.0)
            return float(jnp.sum(qm * kn))
        assert abs(score(3, 1) - score(10, 8)) < 1e-4
        assert abs(score(0, 0) - score(7, 7)) < 1e-4

    def test_norm_preserved(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 16))
        y = apply_rope(x, jnp.arange(4)[None], 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1), np.linalg.norm(np.asarray(y), axis=-1),
            atol=1e-5,
        )


class TestAttentionLayer:
    def test_cross_attention_ignores_causal(self):
        cfg = _cfg()
        spec = AttnSpec(kind="cross", causal=False, use_rope=False)
        params = init_attention(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
        mem = jax.random.normal(jax.random.PRNGKey(2), (2, 10, 64))
        y, _ = attention(cfg, spec, params, x, jnp.arange(4)[None], memory=mem, mode="train")
        assert y.shape == (2, 4, 64)
        # without positional encoding, cross attention is permutation-
        # invariant over the memory sequence
        y2, _ = attention(cfg, spec, params, x, jnp.arange(4)[None], memory=mem[:, ::-1], mode="train")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-5)
